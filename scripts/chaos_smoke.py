#!/usr/bin/env python
"""Seeded fault-plan smoke: chaos-vs-clean identity + allocator balance.

Run via ``scripts/tier1.sh --chaos`` (or directly with ``PYTHONPATH=src``).
For each engine configuration, drains a small deterministic request mix
once cleanly and once under each seeded :class:`FaultPlan` (an
OutOfPages spike, a drafter failure burst mid-spec, a NaN-logit
injection, a page-copier failure), then checks the PR-8 headline
invariant — **identity under chaos**:

  * every surviving request's tokens are bit-identical to the clean run
    (quarantined ``error`` rows are the only permitted casualties);
  * the allocator is balanced afterwards (ledger audit clean, no retired
    rid holding pages, live pages == cache-held pages);
  * zero post-warmup XLA traces, faults included.

Exits 1 on any mismatch, printing the offending config/plan/rid.
"""

import sys

import numpy as np


CONFIGS = {
    "chunked": dict(chunk_tokens=8, flat=False),
    "flat-spec-cache": dict(chunk_tokens=8, spec_tokens=3,
                            prefix_cache=True),
}

PLANS = {
    "oom-spike": [(0, "oom"), (1, "oom"), (2, "oom")],
    "drafter-burst": [(s, "drafter") for s in (1, 2, 3, 5, 7)],
    "nan-logits": [(3, "nan")],
    "copier-failure": [(1, "copier"), (3, "copier")],
}


def _requests(vocab, seed=7):
    rng = np.random.Generator(np.random.Philox(seed))
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    return [(rng.integers(1, vocab, size=l).astype(np.int32), n)
            for l, n in zip(lens, news)]


def _drain(engine, reqs, plan=None, *, greedy=True, seed=0):
    for prompt, n in reqs:
        engine.add_request(prompt, n)
    if plan is None:
        fin = engine.drain(greedy=greedy, seed=seed)
    else:
        with plan.on(engine):
            fin = engine.drain(greedy=greedy, seed=seed)
    return {r.rid: (list(r.out_tokens), r.finish_reason) for r in fin}


def main() -> int:
    from repro.analysis.aliasing import check_pool_consistency
    from repro.analysis.runner import build_model
    from repro.serving.engine import Engine
    from repro.serving.faults import FaultEvent, FaultPlan

    model, params = build_model(slots=3)
    reqs = _requests(model.cfg.vocab)
    failures = 0

    for cname, kwargs in CONFIGS.items():
        clean_eng = Engine(model, params, max_slots=3, **kwargs)
        clean = _drain(clean_eng, reqs)
        for pname, events in PLANS.items():
            eng = Engine(model, params, max_slots=3, **kwargs)
            eng.warmup()
            traces = sum(model.trace_counts.values())
            plan = FaultPlan([FaultEvent(s, k) for s, k in events])
            out = _drain(eng, reqs, plan)
            here = f"{cname} / {pname}"

            survivors = casualties = 0
            for rid, (toks, reason) in sorted(out.items()):
                if reason == "error":
                    casualties += 1
                    continue
                survivors += 1
                if (toks, reason) != clean[rid]:
                    print(f"FAIL {here}: rid {rid} diverged — "
                          f"{(toks, reason)} != clean {clean[rid]}")
                    failures += 1
            if set(out) != set(clean):
                print(f"FAIL {here}: lost requests "
                      f"{sorted(set(clean) - set(out))}")
                failures += 1
            findings = check_pool_consistency(eng, here)
            for f in findings:
                print(f"FAIL {here}: allocator audit: {f.message}")
                failures += 1
            live = sum(len(s.pages) for s in eng.pool.sequences())
            cached = (len(set(eng.prefix_cache.pages()))
                      if eng.prefix_cache is not None else 0)
            if eng.pool.num_used != cached or live != cached:
                print(f"FAIL {here}: allocator unbalanced "
                      f"(used={eng.pool.num_used}, live={live}, "
                      f"cached={cached})")
                failures += 1
            retraces = sum(model.trace_counts.values()) - traces
            if retraces:
                print(f"FAIL {here}: {retraces} post-warmup XLA traces")
                failures += 1
            res = eng.stats()["resilience"]
            print(f"ok   {here}: {survivors} identical survivors, "
                  f"{casualties} quarantined, fired={plan.fired}, "
                  f"quarantines={res['quarantines']}, "
                  f"spec_auto_disables={res['spec_auto_disables']}")

    if failures:
        print(f"chaos smoke: {failures} failure(s)")
        return 1
    print("chaos smoke: identity under chaos holds; allocator balanced; "
          "zero post-warmup traces")
    return 0


if __name__ == "__main__":
    sys.exit(main())
