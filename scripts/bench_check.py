#!/usr/bin/env python
"""Bench regression gate — compare ``latest`` vs ``history[]`` medians.

``benchmarks/bench_serving.py`` appends one timestamped report to
``BENCH_serving.json``'s ``history[]`` per invocation and mirrors the
newest into ``latest``.  This gate recomputes the **median** of each
key ratio over the prior history (the newest entry is excluded — the
run under test must not vote for its own baseline) and exits 1 when
``latest`` regresses any of them by more than ``--tolerance`` (15%
default):

  =============================================  =================
  ratio                                          regression means
  =============================================  =================
  throughput continuous/static (per layout)      dropped
  chunked.throughput_ratio                       dropped
  flat.offline_throughput_ratio                  dropped
  speculative.ngram.decode_tokens_per_row_step   dropped
  prefix_cache[mono/greedy].prefill_ratio        **rose** (lower
                                                 is better: it is
                                                 the fraction of
                                                 prefill work left
                                                 after cache hits)
  attribution.{flat,chunked}.mfu / .mbu          dropped (model-
                                                 FLOPs / bandwidth
                                                 utilization)
  attribution.{flat,chunked}.padding_waste_ratio **rose** (lower is
                                                 better: padded-
                                                 position device
                                                 seconds over total)
  =============================================  =================

Medians (not means) so one noisy CI run cannot shift the baseline, and
ratios (not absolute tok/s) so the gate is machine-portable.  Missing
file, metric, or short history (< ``--min-history`` baseline samples
after excluding the newest entry) skips that check with a note and
exits 0 — the gate only ever fails on *evidence* of a regression.

    python scripts/bench_check.py                     # default file
    python scripts/bench_check.py --file other.json --tolerance 0.10
"""

import argparse
import json
import statistics
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# (label, path through the report dict, higher_is_better)
CHECKS = [
    ("throughput fixed continuous/static",
     ("throughput", "fixed/continuous", "fixed/static"), True),
    ("throughput scalable continuous/static",
     ("throughput", "scalable/continuous", "scalable/static"), True),
    ("chunked throughput ratio",
     ("chunked", "throughput_ratio"), True),
    ("flat offline throughput ratio",
     ("flat", "offline_throughput_ratio"), True),
    ("spec ngram decode tokens/row-step",
     ("speculative", "ngram", "decode_tokens_per_row_step"), True),
    ("prefix-cache prefill ratio (mono/greedy)",
     ("prefix_cache", "mono/greedy", "prefill_ratio"), False),
    # attribution section (repro.obs.attrib): model-FLOPs and bandwidth
    # utilization must not drop; the padding-waste ratio (padded-position
    # device seconds / total device seconds) must not rise
    ("attribution flat mfu", ("attribution", "flat", "mfu"), True),
    ("attribution flat mbu", ("attribution", "flat", "mbu"), True),
    ("attribution flat padding-waste ratio",
     ("attribution", "flat", "padding_waste_ratio"), False),
    ("attribution chunked mfu", ("attribution", "chunked", "mfu"), True),
    ("attribution chunked padding-waste ratio",
     ("attribution", "chunked", "padding_waste_ratio"), False),
]


def _extract(report, path):
    """Resolve a metric path; the 3-element throughput paths are a
    numerator/denominator pair under one section."""
    if path[0] == "throughput":
        sec = report.get("throughput")
        if not isinstance(sec, dict):
            return None
        num, den = sec.get(path[1]), sec.get(path[2])
        if not num or not den:
            return None
        return num / den
    node = report
    for key in path:
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node if isinstance(node, (int, float)) else None


def check(data, *, tolerance=0.15, min_history=2, out=print):
    """Return the number of regressions (0 == gate passes)."""
    latest = data.get("latest")
    history = data.get("history", [])
    if not isinstance(latest, dict):
        out("bench_check: no 'latest' report — skipping gate")
        return 0
    # The newest history entry is this run's own report; baseline on
    # what came before it.
    baseline = [h.get("report", {}) for h in history[:-1]]

    failures = 0
    for label, path, higher_better in CHECKS:
        cur = _extract(latest, path)
        if cur is None:
            out(f"  skip  {label}: absent from latest")
            continue
        past = [v for v in (_extract(r, path) for r in baseline)
                if v is not None]
        if len(past) < min_history:
            out(f"  skip  {label}: {len(past)} baseline sample(s) "
                f"(< {min_history})")
            continue
        med = statistics.median(past)
        if med == 0:
            out(f"  skip  {label}: zero baseline median")
            continue
        change = cur / med - 1.0
        regressed = (change < -tolerance) if higher_better \
            else (change > tolerance)
        tag = "FAIL" if regressed else "ok"
        out(f"  {tag:<5} {label}: latest {cur:.4f} vs median {med:.4f} "
            f"over {len(past)} run(s) ({change:+.1%})")
        failures += regressed
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--file", type=Path,
                    default=REPO / "BENCH_serving.json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed relative regression (default 0.15)")
    ap.add_argument("--min-history", type=int, default=2,
                    help="baseline samples required to gate a metric")
    args = ap.parse_args()

    if not args.file.exists():
        print(f"bench_check: {args.file} not found — skipping gate")
        return 0
    try:
        data = json.loads(args.file.read_text())
    except (json.JSONDecodeError, OSError) as e:
        print(f"bench_check: cannot read {args.file} ({e}) — skipping gate")
        return 0

    print(f"bench_check: {args.file.name}, tolerance "
          f"{args.tolerance:.0%}, baseline = history medians")
    failures = check(data, tolerance=args.tolerance,
                     min_history=args.min_history)
    if failures:
        print(f"bench_check: {failures} regression(s) beyond "
              f"{args.tolerance:.0%} — failing")
        return 1
    print("bench_check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
