#!/usr/bin/env python
"""AST invariant lint (analysis pass 4) — stdlib ``ast``, no jax import.

Enforces the syntactic repo rules over ``src/repro/serving/``,
``src/repro/obs/`` and ``src/repro/kernels/`` (see
:mod:`repro.analysis.ast_lint`): allocator privacy, usable-pages
capacity asserts, no unseeded randomness, monotonic clocks in
serving/obs, kernel ref-oracles under test.  Exit 1 on any finding.

    python scripts/lint_invariants.py                 # default tree
    python scripts/lint_invariants.py src/repro       # a wider sweep
"""

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: serving + kernels "
                         "+ obs)")
    ap.add_argument("--no-oracles", action="store_true",
                    help="skip the kernel-oracle rule (tests dir scan)")
    args = ap.parse_args()

    from repro.analysis.ast_lint import lint_kernel_oracles, lint_paths

    serving = REPO / "src" / "repro" / "serving"
    kernels = REPO / "src" / "repro" / "kernels"
    obs = REPO / "src" / "repro" / "obs"
    paths = args.paths or [serving, kernels, obs]
    findings = lint_paths(paths, serving_root=serving,
                          clock_roots=(serving, obs))
    if not args.no_oracles and (REPO / "tests").is_dir():
        findings += lint_kernel_oracles(kernels, REPO / "tests")

    for f in findings:
        print(f.format())
    print(f"{len(findings)} finding(s)" if findings else "OK — no findings")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
