#!/usr/bin/env python
"""Attribution-report smoke: exposition formats + completeness, end to end.

Run via ``scripts/tier1.sh --report`` (or directly with ``PYTHONPATH=src``).
Drains a small deterministic request mix on a telemetry-enabled, warmed
engine per configuration (chunked and flat — warmup builds the roofline
cost model), writes the HTML/Prometheus report pair to a temp dir, and
checks the PR headline invariants:

  * attribution completeness — every per-step record's
    ``sched + device + draft + host`` reconstructs the measured wall
    within float tolerance, and the drain totals inherit the identity;
  * the Prometheus text passes :func:`repro.obs.export.lint_prometheus`
    (naming, TYPE-before-sample, sample uniqueness, counter ``_total``
    naming and non-negativity);
  * the HTML report is a self-contained single file (waterfall,
    per-family table, latency percentiles, alert log; no ``<script>``);
  * the cost model is warmup-only: it exists after ``warmup()``, covers
    every family label the drain measured, and the drain triggers zero
    post-warmup XLA traces with attribution on.

Exits 1 on any violation, printing the offending config/check.
"""

import sys
import tempfile

import numpy as np


CONFIGS = {
    "chunked": dict(chunk_tokens=8, flat=False),
    "flat": dict(chunk_tokens=8, token_budget=16),
}


def _requests(vocab, seed=11):
    rng = np.random.Generator(np.random.Philox(seed))
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    return [(rng.integers(1, vocab, size=l).astype(np.int32), n)
            for l, n in zip(lens, news)]


def main() -> int:
    from repro.analysis.runner import build_model
    from repro.obs.export import lint_prometheus
    from repro.serving.engine import Engine

    model, params = build_model(slots=3)
    reqs = _requests(model.cfg.vocab)
    failures = 0

    def fail(where, msg):
        nonlocal failures
        failures += 1
        print(f"  FAIL  {where}: {msg}")

    for cname, kwargs in CONFIGS.items():
        eng = Engine(model, params, max_slots=3, page_tokens=8,
                     telemetry=True, **kwargs)
        eng.warmup()
        if eng.cost_model is None:
            fail(cname, "warmup() built no cost model")
            continue
        traces = sum(model.trace_counts.values())
        for prompt, n in reqs:
            eng.add_request(prompt, n)
        eng.drain()

        if sum(model.trace_counts.values()) != traces:
            fail(cname, "attribution retraced post-warmup")

        recs = list(eng.obs.step_records)
        if not recs:
            fail(cname, "drain produced no attribution records")
        for i, rec in enumerate(recs):
            parts = (rec["sched"] + rec["device"] + rec["draft"]
                     + rec["host"])
            if abs(parts - rec["wall"]) > 1e-9 + 1e-6 * rec["wall"]:
                fail(cname, f"step {i}: components {parts:.9f} != "
                            f"wall {rec['wall']:.9f}")
        summary = eng.obs.attribution_summary()
        tot = summary["totals"]
        comp = (tot["sched_s"] + tot["device_s"] + tot["draft_s"]
                + tot["host_s"])
        if abs(comp - tot["wall_s"]) > 1e-9 + 1e-6 * tot["wall_s"]:
            fail(cname, f"totals: components {comp:.9f} != "
                        f"wall {tot['wall_s']:.9f}")
        measured = set(summary["families"])
        modelled = set(eng.cost_model.families)
        if not measured <= modelled:
            fail(cname, f"families outside the warmup cost model: "
                        f"{sorted(measured - modelled)}")
        if not (0 < summary.get("mfu", 0) < 1):
            fail(cname, f"mfu out of range: {summary.get('mfu')}")

        with tempfile.TemporaryDirectory() as tmp:
            tel = eng.telemetry(report=f"{tmp}/drain")
            prom = open(tel["report"]["prom"]).read()
            page = open(tel["report"]["html"]).read()
        problems = lint_prometheus(prom)
        for p in problems:
            fail(cname, f"prometheus lint: {p}")
        for marker in ("Attribution waterfall", "Per-family predicted vs",
                       "Latency percentiles", "Alerts"):
            if marker not in page:
                fail(cname, f"HTML report missing {marker!r}")
        if "<script" in page or "http://" in page or "https://" in page:
            fail(cname, "HTML report is not self-contained")

        print(f"  ok    {cname}: {tot['steps']} steps, "
              f"{len(measured)} families, mfu {summary['mfu']:.2e}, "
              f"prom {len(prom)} B, html {len(page)} B")

    if failures:
        print(f"report_smoke: {failures} failure(s)")
        return 1
    print("report_smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
