#!/usr/bin/env bash
# Tier-1 verification — the ROADMAP command, verbatim.
# Run from the repo root:  ./scripts/tier1.sh
# The full (slow-included) sweep:  ./scripts/tier1.sh -m slow
# With the serving-allocator smoke:  ./scripts/tier1.sh --bench-smoke
#   (runs bench_serving.py at toy sizes — 2 slots, tiny pool, long-tail
#   trace at 50% of the eager reservation, the chunked-vs-monolithic
#   prefill A/B, the flat-step section (flat/chunked/monolithic outputs
#   must be token-identical — a flat-vs-chunked mismatch fails the run),
#   the speculative-decoding section, and the prefix-cache
#   section (shared-system-prompt trace: cache-on must be token-identical
#   to cache-off at <= 0.5x the prefill tokens, and a tight-pool
#   preempt-resume must recompute only the uncached suffix) —
#   lazy-allocation/preemption regressions and any chunked-vs-monolithic,
#   spec-vs-baseline, or cache-on-vs-cache-off output mismatch (greedy or
#   sampled) fail the run without the full bench; afterwards
#   scripts/bench_check.py gates the fresh BENCH_serving.json entry
#   against its history medians — >15% regression of a key ratio fails)
# With the layout-contract analyzer:  ./scripts/tier1.sh --analyze
#   (runs all four analysis passes — shape-ladder linter, KV-write
#   aliasing pass, recompile-hazard detector, AST invariant lint — plus
#   a sanitized drain over every engine configuration via
#   scripts/analyze.py; any finding fails the run)
# With the attribution-report smoke:  ./scripts/tier1.sh --report
#   (runs scripts/report_smoke.py — drains a telemetry-enabled, warmed
#   engine per config, then checks attribution completeness (the
#   sched+device+draft+host components reconstruct each step's wall),
#   lints the Prometheus exposition, schema-checks the single-file HTML
#   report, and verifies the warmup-only cost-model contract with zero
#   post-warmup XLA traces; any violation fails the run)
# With the seeded fault-plan smoke:  ./scripts/tier1.sh --chaos
#   (runs scripts/chaos_smoke.py — drains a deterministic request mix
#   clean and under seeded FaultPlans (OutOfPages spike, drafter failure
#   burst, NaN-logit injection, page-copier failure) per engine config;
#   any surviving request diverging from the clean run, an unbalanced
#   allocator, or a post-warmup XLA trace fails the run)
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_SMOKE=0
ANALYZE=0
CHAOS=0
REPORT=0
ARGS=()
for a in "$@"; do
  if [[ "$a" == "--bench-smoke" ]]; then BENCH_SMOKE=1;
  elif [[ "$a" == "--analyze" ]]; then ANALYZE=1;
  elif [[ "$a" == "--chaos" ]]; then CHAOS=1;
  elif [[ "$a" == "--report" ]]; then REPORT=1;
  else ARGS+=("$a"); fi
done

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q --durations=15 ${ARGS[@]+"${ARGS[@]}"}

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serving.py --smoke --skip-throughput
  python scripts/bench_check.py
fi

if [[ "$ANALYZE" == 1 ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/analyze.py
fi

if [[ "$CHAOS" == 1 ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/chaos_smoke.py
fi

if [[ "$REPORT" == 1 ]]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/report_smoke.py
fi
