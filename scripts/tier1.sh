#!/usr/bin/env bash
# Tier-1 verification — the ROADMAP command, verbatim.
# Run from the repo root:  ./scripts/tier1.sh
# The full (slow-included) sweep:  ./scripts/tier1.sh -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
