#!/usr/bin/env python
"""Run the layout-contract analyzer over the full engine matrix.

Exit status 0 iff every pass is green; any finding prints and fails the
run, which is what lets ``scripts/tier1.sh --analyze`` gate a PR on the
serving stack's standing invariants.

    PYTHONPATH=src python scripts/analyze.py            # everything
    PYTHONPATH=src python scripts/analyze.py --static   # no traffic/trace
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--static", action="store_true",
                    help="ladder algebra + AST lint only (no jaxpr traces, "
                         "no sanitized traffic) — seconds instead of minutes")
    ap.add_argument("--no-traffic", action="store_true",
                    help="skip the sanitized drains (keep jaxpr traces)")
    args = ap.parse_args()

    from repro.analysis import run_all
    report = run_all(traffic=not (args.static or args.no_traffic),
                     trace=not args.static,
                     log=lambda m: print(m, flush=True))
    print(report.format())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
