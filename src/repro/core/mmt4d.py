"""Packed matrix multiplication (`linalg.mmt4d` analogue) + fused epilogues.

Computes, on packed operands,

    C_pack[m_o, n_o, :, :] += sum_k A_pack[m_o, k_o, :, :] @ B_pack[n_o, k_o, :, :]^T

This is the jnp formulation used throughout the framework (XLA lowers it to
MXU-shaped dot_generals on TPU and it is what the distributed dry-run
compiles).  The Pallas TPU kernel with explicit BlockSpec VMEM tiling lives
in ``repro.kernels.mmt4d`` and is validated against this formulation.

``Epilogue`` models the paper's fusion story: bias add / activation /
residual executed *in the packed domain* on the mmt4d result, so that no
unpack is needed between a matmul and its pointwise consumers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.layout import LayoutPolicy, PackedLayout
from repro.core import packing

__all__ = ["mmt4d", "Epilogue", "packed_matmul", "matmul"]


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Pointwise epilogue fused into the packed-domain matmul output.

    ``bias`` is an unpacked ``[N]`` vector; it is packed (tiled along n_r)
    and broadcast over the packed output — layout propagation of the
    producer's layout into the consumer (paper §4.3).
    """

    activation: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    has_bias: bool = False

    def apply_packed(self, cp: jnp.ndarray, layout: PackedLayout,
                     bias: Optional[jnp.ndarray]) -> jnp.ndarray:
        if self.has_bias:
            assert bias is not None
            n_o, n_r = cp.shape[-3], cp.shape[-1]
            bp = packing.pad_to_tiles(bias[None, :], 1, layout.n_r)
            bp = bp.reshape(n_o, n_r)  # [N_o, n_r]
            cp = cp + bp[..., :, None, :]  # broadcast over m_o (via leading) & m_r
        if self.activation is not None:
            cp = self.activation(cp)
        return cp

    def apply_unpacked(self, c: jnp.ndarray, bias: Optional[jnp.ndarray]) -> jnp.ndarray:
        if self.has_bias:
            assert bias is not None
            c = c + bias
        if self.activation is not None:
            c = self.activation(c)
        return c


def mmt4d(a_pack: jnp.ndarray, b_pack: jnp.ndarray, *,
          accum_dtype=jnp.float32) -> jnp.ndarray:
    """Packed matmul on packed operands.

    a_pack: [..., M_o, K_o, m_r, k_r]
    b_pack: [..., N_o, K_o, n_r, k_r]
    returns C_pack [..., M_o, N_o, m_r, n_r] in ``a_pack.dtype``'s promoted
    compute dtype (accumulation in ``accum_dtype``).
    """
    # Unbatched RHS (a plain weight) with leading LHS batch dims: fold the
    # lead dims into M_o -- a free (contiguous) reshape in the packed layout.
    if b_pack.ndim == 4 and a_pack.ndim > 4:
        lead = a_pack.shape[:-4]
        m_o = a_pack.shape[-4]
        a2 = a_pack.reshape((-1,) + a_pack.shape[-3:])
        out = mmt4d(a2, b_pack, accum_dtype=accum_dtype)
        return out.reshape(lead + (m_o,) + out.shape[1:])

    # Contract over (K_o, k_r); batch over leading dims.
    nbatch = a_pack.ndim - 4
    assert b_pack.ndim - 4 == nbatch, (a_pack.shape, b_pack.shape)
    # dot_general dims: lhs [..., M_o, K_o, m_r, k_r], rhs [..., N_o, K_o, n_r, k_r]
    lhs_contract = (nbatch + 1, nbatch + 3)
    rhs_contract = (nbatch + 1, nbatch + 3)
    batch_dims = tuple(range(nbatch))
    out = jax.lax.dot_general(
        a_pack, b_pack,
        dimension_numbers=((lhs_contract, rhs_contract), (batch_dims, batch_dims)),
        preferred_element_type=accum_dtype,
    )
    # out: [..., M_o, m_r, N_o, n_r] -> [..., M_o, N_o, m_r, n_r]
    perm = list(range(nbatch)) + [nbatch, nbatch + 2, nbatch + 1, nbatch + 3]
    out = out.transpose(perm)
    return out.astype(a_pack.dtype)


def packed_matmul(a: jnp.ndarray, b: jnp.ndarray, layout: PackedLayout, *,
                  epilogue: Epilogue = Epilogue(), bias: Optional[jnp.ndarray] = None,
                  a_is_packed: bool = False, keep_packed: bool = False) -> jnp.ndarray:
    """pack -> mmt4d -> (epilogue in packed domain) -> unpack.

    The pack/unpack boundary ops are exactly the paper's decomposition; with
    ``a_is_packed`` / ``keep_packed`` callers elide them when the neighbour
    op already speaks the packed layout (propagation).
    """
    m = None if a_is_packed else a.shape[-2]
    n = b.shape[-1]
    a_pack = a if a_is_packed else packing.pack_lhs(a, layout)
    b_pack = packing.pack_rhs(b, layout)
    c_pack = mmt4d(a_pack, b_pack)
    c_pack = epilogue.apply_packed(c_pack, layout, bias)
    if keep_packed:
        return c_pack
    if m is None:
        m = a_pack.shape[-4] * a_pack.shape[-2]
    return packing.unpack_out(c_pack, m, n)


def matmul(a: jnp.ndarray, b: jnp.ndarray, layout: PackedLayout, *,
           epilogue: Epilogue = Epilogue(), bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Policy-dispatched matmul: the single entry point used by model code."""
    if layout.policy is LayoutPolicy.UNPACKED:
        c = jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
        return epilogue.apply_unpacked(c, bias)
    return packed_matmul(a, b, layout, epilogue=epilogue, bias=bias)
