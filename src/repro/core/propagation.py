"""Packed-layout propagation (paper §4.3 "Fusion and layout propagation").

The paper materializes packing as an explicit op so the compiler can hoist /
fuse it across producers and consumers.  In this framework the same role is
played by :class:`PackedArray`: a pytree carrier for an activation tensor
living in packed layout.  Pointwise ops, bias adds, residual adds and
normalizations are implemented *directly on the packed representation*, so a
chain  ``linear -> norm -> act -> linear``  executes entirely in the packed
domain — the intermediate ``unpack∘pack`` pairs cancel exactly (on TPU they
are exactly inverse transposes; see DESIGN.md §2 chain-compatibility).

Padding correctness: packed tiles are zero-padded (paper's padding
semantics).  Reductions over the feature dim therefore sum zeros — harmless —
but must divide by the *true* feature size, which :class:`PackedArray`
tracks (``k``).  Ops that are not padding-neutral (softmax, top-k) must
unpack first; ``PackedArray`` deliberately does not implement them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.layout import PackedLayout
from repro.core import packing

__all__ = ["PackedArray", "pack_activation"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedArray:
    """An activation tensor in packed layout.

    ``data``: [..., M_o, K_o, m_r, k_r] — trailing two logical dims were
    (M = tokens/rows, K = features).  ``m``/``k`` are the true (unpadded)
    logical sizes; ``layout`` is static metadata.
    """

    data: jnp.ndarray
    m: int
    k: int
    layout: PackedLayout

    # -- pytree plumbing (layout/sizes are static aux data) --
    def tree_flatten(self):
        return (self.data,), (self.m, self.k, self.layout)

    @classmethod
    def tree_unflatten(cls, aux, children):
        m, k, layout = aux
        return cls(data=children[0], m=m, k=k, layout=layout)

    # -- basic properties --
    @property
    def dtype(self):
        return self.data.dtype

    @property
    def lead_shape(self):
        return self.data.shape[:-4]

    def astype(self, dtype) -> "PackedArray":
        return self._with(self.data.astype(dtype))

    def _with(self, data) -> "PackedArray":
        return PackedArray(data=data, m=self.m, k=self.k, layout=self.layout)

    # -- pointwise ops in the packed domain --
    def elementwise(self, fn: Callable[[jnp.ndarray], jnp.ndarray]) -> "PackedArray":
        return self._with(fn(self.data))

    def __add__(self, other: "PackedArray") -> "PackedArray":
        assert isinstance(other, PackedArray) and other.layout == self.layout
        return self._with(self.data + other.data)

    def __mul__(self, other) -> "PackedArray":
        if isinstance(other, PackedArray):
            return self._with(self.data * other.data)
        return self._with(self.data * other)

    def _feature_vec(self, v: jnp.ndarray) -> jnp.ndarray:
        """Tile an unpacked [K] vector to broadcast against packed data:
        [K] -> [K_o, 1, k_r] (broadcasts over M_o via leading, m_r via 1)."""
        k_o, k_r = self.data.shape[-3], self.data.shape[-1]
        vp = packing.pad_to_tiles(v[None, :], 1, self.layout.k_r).reshape(k_o, k_r)
        return vp[:, None, :]

    def scale_features(self, v: jnp.ndarray) -> "PackedArray":
        """x * v with v an unpacked per-feature vector (e.g. norm gain)."""
        return self._with(self.data * self._feature_vec(v))

    def add_features(self, v: jnp.ndarray) -> "PackedArray":
        """x + v (e.g. bias) — note: also writes into feature padding, which
        is then ignored by construction downstream (consumer matmuls contract
        against RHS rows that are zero in the padded region)."""
        return self._with(self.data + self._feature_vec(v))

    # -- reductions over the (padded) feature dim, padding-corrected --
    def _sum_features(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(x, axis=(-3, -1), keepdims=True)  # over (K_o, k_r)

    def rms_norm(self, gain: jnp.ndarray | None, eps: float = 1e-6,
                 upcast: bool = True) -> "PackedArray":
        x = self.data.astype(jnp.float32) if upcast else self.data
        ms = self._sum_features(x * x) / self.k  # true feature count
        y = x * jax.lax.rsqrt(ms + eps)
        out = self._with(y.astype(self.dtype))
        if gain is not None:
            out = out.scale_features(gain.astype(self.dtype))
        return out

    def layer_norm(self, gain: jnp.ndarray | None, bias: jnp.ndarray | None,
                   eps: float = 1e-5, upcast: bool = True) -> "PackedArray":
        """LayerNorm in the packed domain.

        Mean subtraction would poison the feature padding (pad slots would
        become ``-mean``), so the centered value is re-masked with the
        feature-padding mask before variance/output — keeping the padding
        explicitly zero, as the layout contract requires.
        """
        x = self.data.astype(jnp.float32) if upcast else self.data
        mask = self._feature_mask()
        mean = self._sum_features(x) / self.k
        xc = (x - mean) * mask
        var = self._sum_features(xc * xc) / self.k
        y = xc * jax.lax.rsqrt(var + eps)
        out = self._with(y.astype(self.dtype))
        if gain is not None:
            out = out.scale_features(gain.astype(self.dtype))
        if bias is not None:
            out = out.add_features(bias.astype(self.dtype))
            out = out._with(out.data * mask.astype(out.dtype))
        return out

    def _feature_mask(self) -> jnp.ndarray:
        """[K_o, 1, k_r] mask of true (non-padding) feature slots."""
        k_o, k_r = self.data.shape[-3], self.data.shape[-1]
        idx = jnp.arange(k_o * k_r).reshape(k_o, k_r)
        return (idx < self.k).astype(jnp.float32)[:, None, :]

    # -- boundary ops --
    def unpack(self) -> jnp.ndarray:
        return packing.unpack_lhs(self.data, self.m, self.k)


def pack_activation(x: jnp.ndarray, layout: PackedLayout) -> PackedArray:
    """Pack an activation [..., M, K] into LHS layout (tokens x features)."""
    return PackedArray(data=packing.pack_lhs(x, layout), m=x.shape[-2],
                       k=x.shape[-1], layout=layout)
