"""Core library: scalable packed layouts (the paper's contribution) in JAX.

Public surface:
  - hardware.HardwareSpec / query        — runtime hardware descriptor (VL analogue)
  - layout.make_layout / LayoutPolicy    — VL-parametric tile functions
  - packing.pack_lhs/pack_rhs/unpack_out — explicit layout transformation
  - mmt4d.mmt4d / packed_matmul          — compute on packed operands
  - propagation.PackedArray              — packed-domain pointwise/norm ops
  - linear.linear_apply / MatmulContext  — the model-facing matmul entry point
"""

from repro.core.hardware import HardwareSpec, presets, query
from repro.core.layout import LayoutPolicy, PackedLayout, make_layout, MICROKERNELS
from repro.core.mmt4d import Epilogue, mmt4d, packed_matmul, matmul
from repro.core.propagation import PackedArray, pack_activation
from repro.core.linear import (MatmulContext, linear_init, linear_apply,
                               batched_linear_apply, prepack_params)
from repro.core import packing

__all__ = [
    "HardwareSpec", "presets", "query",
    "LayoutPolicy", "PackedLayout", "make_layout", "MICROKERNELS",
    "Epilogue", "mmt4d", "packed_matmul", "matmul",
    "PackedArray", "pack_activation",
    "MatmulContext", "linear_init", "linear_apply", "batched_linear_apply",
    "prepack_params", "packing",
]
