"""Pack / unpack transformations (paper §4.1, `linalg.pack`/`unpack` analogue).

Packing is an *explicit data transformation*, not a logical view: the packed
tensor is materialized with tiles contiguous in memory (on TPU this makes
every tile a native (sublane, lane) hardware tile).  Padding semantics are
built in: out-of-bounds elements of partial tiles are stored as explicit
zeros so the compute kernel runs unmasked (paper §4.3).

These are the pure-jnp formulations that (a) serve as the oracle for the
Pallas kernels in ``repro.kernels.{pack,unpack}`` and (b) are what the
distributed dry-run lowers through XLA (pack lowers to pad+reshape+transpose,
which XLA fuses into neighbouring ops — the IREE fusion analogue).

Leading batch dims are supported: ``pack_lhs`` on ``[..., M, K]`` packs the
trailing two dims, mapping the paper's 2-D formulation over expert/batch
stacks (used by the MoE batched matmuls).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import PackedLayout

__all__ = [
    "pad_to_tiles",
    "pack_lhs",
    "pack_rhs",
    "pack_out",
    "unpack_out",
    "unpack_lhs",
]


def pad_to_tiles(x: jnp.ndarray, t0: int, t1: int) -> jnp.ndarray:
    """Zero-pad the trailing two dims of ``x`` up to multiples of (t0, t1)."""
    d0, d1 = x.shape[-2], x.shape[-1]
    p0 = (-d0) % t0
    p1 = (-d1) % t1
    if p0 == 0 and p1 == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, p0), (0, p1)]
    return jnp.pad(x, pad)


def _pack2d(x: jnp.ndarray, t0: int, t1: int) -> jnp.ndarray:
    """[..., D0, D1] -> [..., D0/t0, D1/t1, t0, t1] (materialized tiles)."""
    x = pad_to_tiles(x, t0, t1)
    *lead, d0, d1 = x.shape
    x = x.reshape(*lead, d0 // t0, t0, d1 // t1, t1)
    # [..., o0, t0, o1, t1] -> [..., o0, o1, t0, t1]
    perm = list(range(len(lead))) + [len(lead), len(lead) + 2, len(lead) + 1, len(lead) + 3]
    return x.transpose(perm)


def _unpack2d(xp: jnp.ndarray, d0: int, d1: int) -> jnp.ndarray:
    """Inverse of :func:`_pack2d`; slices away the tile padding."""
    *lead, o0, o1, t0, t1 = xp.shape
    perm = list(range(len(lead))) + [len(lead), len(lead) + 2, len(lead) + 1, len(lead) + 3]
    x = xp.transpose(perm).reshape(*lead, o0 * t0, o1 * t1)
    return x[..., :d0, :d1]


def pack_lhs(a: jnp.ndarray, layout: PackedLayout) -> jnp.ndarray:
    """A[..., M, K] -> A_pack[..., M_o, K_o, m_r, k_r]."""
    return _pack2d(a, layout.m_r, layout.k_r)


def pack_rhs(b: jnp.ndarray, layout: PackedLayout) -> jnp.ndarray:
    """B[..., K, N] -> B_pack[..., N_o, K_o, n_r, k_r] (transposed packing).

    mmt4d convention: the RHS is packed along N-major so that the microkernel
    reads contiguous ``n_r x k_r`` blocks (paper Listing 2 reads B as
    contiguous vectors of length VL).
    """
    bt = jnp.swapaxes(b, -1, -2)  # [..., N, K]
    return _pack2d(bt, layout.n_r, layout.k_r)


def pack_out(c: jnp.ndarray, layout: PackedLayout) -> jnp.ndarray:
    """C[..., M, N] -> C_pack[..., M_o, N_o, m_r, n_r]."""
    return _pack2d(c, layout.m_r, layout.n_r)


def unpack_out(cp: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """C_pack[..., M_o, N_o, m_r, n_r] -> C[..., M, N]."""
    return _unpack2d(cp, m, n)


def unpack_lhs(ap: jnp.ndarray, m: int, k: int) -> jnp.ndarray:
    """A_pack[..., M_o, K_o, m_r, k_r] -> A[..., M, K]."""
    return _unpack2d(ap, m, k)
