"""Packed linear layers — the framework's single matmul entry point.

Every weight matmul in every model goes through :func:`linear_apply` (or
:func:`batched_linear_apply` for expert-stacked weights), dispatching on the
:class:`MatmulContext` policy:

  - ``scalable`` / ``fixed``: pack -> mmt4d -> unpack with the corresponding
    layout (paper pipeline).  When handed/asked-for a :class:`PackedArray`,
    pack/unpack at the boundary are elided (layout propagation).
  - ``unpacked``: plain XLA dot (baseline).

Weights are stored *unpacked* in the parameter pytree (optimizer- and
checkpoint-friendly); ``pack_rhs`` of a step-constant weight is CSE'd /
fused by XLA within a step, and the serving path can materialize packed
weights once via :func:`prepack_params` (paper: packing "treated as a
standalone operation on the full operands").
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.hardware import HardwareSpec, query
from repro.core.layout import LayoutPolicy, PackedLayout, make_layout
from repro.core.mmt4d import Epilogue, mmt4d, matmul as policy_matmul
from repro.core import packing
from repro.core.propagation import PackedArray, pack_activation

__all__ = [
    "MatmulContext",
    "linear_init",
    "linear_apply",
    "batched_linear_apply",
    "prepack_params",
]


@dataclasses.dataclass(frozen=True)
class MatmulContext:
    """Layout policy + hardware descriptor threaded through model code.

    ``mesh_axes``: when set (distributed lowering), model code emits
    explicit tensor-parallel sharding constraints (Megatron-style col/row)
    inside scanned layer bodies — GSPMD propagation alone loses the TP
    factorization through scan body parameters (measured 8x compute waste
    on the 256-chip mesh; §Perf iteration 4).
    """

    policy: LayoutPolicy = LayoutPolicy.SCALABLE
    hw: Optional[HardwareSpec] = None
    propagate: bool = True   # carry PackedArray across pointwise ops when possible
    kernel: str = "mxu_outer_product"
    mesh_axes: Optional[tuple] = None
    dp_size: int = 1
    tp_size: int = 1
    moe_local: bool = False  # per-DP-shard MoE dispatch (RunConfig knob)

    def layout(self, dtype) -> PackedLayout:
        return make_layout(self.policy, self.hw or query(), dtype, kernel=self.kernel)

    @property
    def packed(self) -> bool:
        return self.policy is not LayoutPolicy.UNPACKED

    @property
    def tp_axis(self) -> Optional[str]:
        if self.mesh_axes and "model" in self.mesh_axes:
            return "model"
        return None

    @property
    def dp_axes(self) -> tuple:
        return tuple(a for a in ("pod", "data") if self.mesh_axes
                     and a in self.mesh_axes)

    def constrain(self, x, spec_tail: tuple):
        """with_sharding_constraint over the TRAILING dims of ``x`` (leading
        dims unconstrained).  No-op outside distributed lowering."""
        if self.tp_axis is None or x is None:
            return x
        from jax.sharding import PartitionSpec as P
        nd = x.ndim
        lead = (None,) * (nd - len(spec_tail))
        return jax.lax.with_sharding_constraint(x, P(*lead, *spec_tail))


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None) -> dict:
    scale = (d_in ** -0.5) if scale is None else scale
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _maybe_packed_weight(params: dict, layout: PackedLayout):
    """Return (b_pack, n) using a pre-packed weight if present.

    ``w_n`` stores the true (unpadded) output dim as the SHAPE of an empty
    array — shapes stay static under jit, values become tracers."""
    if "w_pack" in params:
        wp = params["w_pack"]
        return wp, params["w_n"].shape[0]
    w = params["w"]
    return packing.pack_rhs(w, layout), w.shape[-1]


def linear_apply(params: dict, x: Union[jnp.ndarray, PackedArray], ctx: MatmulContext,
                 *, activation: Optional[Callable] = None,
                 keep_packed: bool = False,
                 tp: Optional[str] = None) -> Union[jnp.ndarray, PackedArray]:
    """y = act(x @ W + b), policy-dispatched, propagation-aware.

    x: [..., M, K] array or PackedArray of the same logical shape.
    ``tp``: Megatron-style tensor parallelism of this matmul — "col" (out
    dim sharded over the model axis) or "row" (contraction dim sharded;
    output partial-summed).  Only consulted under distributed lowering
    (``ctx.mesh_axes``); anchors GSPMD inside scanned bodies.
    Returns [..., M, N] (or a PackedArray thereof when ``keep_packed``).
    """
    epi = Epilogue(activation=activation, has_bias="b" in params)
    bias = params.get("b")
    mdl = ctx.tp_axis
    if not ctx.packed:
        assert not isinstance(x, PackedArray)
        w = params["w"]
        if mdl and tp == "col":
            w = ctx.constrain(w, (None, mdl))
        elif mdl and tp == "row":
            w = ctx.constrain(w, (mdl, None))
            x = ctx.constrain(x, (None, mdl))
        out = policy_matmul(x, w, ctx.layout(x.dtype), epilogue=epi, bias=bias)
        if mdl and tp == "col":
            out = ctx.constrain(out, (None, mdl))
        return out

    if isinstance(x, PackedArray):
        layout = x.layout
        a_pack, m = x.data, x.m
    else:
        layout = ctx.layout(x.dtype)
        a_pack, m = packing.pack_lhs(x, layout), x.shape[-2]

    b_pack, n = _maybe_packed_weight(params, layout)
    if mdl and tp == "col":
        # B_pack [N_o, K_o, n_r, k_r]: shard output tiles over model
        b_pack = ctx.constrain(b_pack, (mdl, None, None, None))
    elif mdl and tp == "row":
        # contraction tiles over model; LHS K_o must match
        b_pack = ctx.constrain(b_pack, (None, mdl, None, None))
        a_pack = ctx.constrain(a_pack, (None, mdl, None, None))
    c_pack = mmt4d(a_pack, b_pack)
    if mdl and tp == "col":
        c_pack = ctx.constrain(c_pack, (None, mdl, None, None))
    c_pack = epi.apply_packed(c_pack, layout, bias)

    if keep_packed and ctx.propagate:
        if not layout.chain_compatible:
            # Fixed-tile fallback: output tile shape != input tile shape, so
            # the result must be round-tripped through the unpacked domain
            # before the next matmul (this is precisely the repacking cost
            # the scalable layout avoids -- visible in the benchmarks).
            c = packing.unpack_out(c_pack, m, n)
            return pack_activation(c, layout)
        return PackedArray(data=c_pack, m=m, k=n, layout=layout)
    return packing.unpack_out(c_pack, m, n)


def batched_linear_apply(params: dict, x: jnp.ndarray, ctx: MatmulContext,
                         *, activation: Optional[Callable] = None) -> jnp.ndarray:
    """Expert-stacked linear: x [E, C, K] @ w [E, K, N] -> [E, C, N].

    The packed formulation maps the paper's 2-D layouts over the leading
    expert dim (tiles stay 2-D; the expert dim shards over the model axis).
    """
    w = params["w"]
    epi = Epilogue(activation=activation, has_bias="b" in params)
    bias = params.get("b")
    mdl = ctx.tp_axis
    if mdl:  # expert parallelism: anchor the expert dim to the model axis
        from jax.sharding import PartitionSpec as P
        w = jax.lax.with_sharding_constraint(w, P(mdl, None, None))
        x = jax.lax.with_sharding_constraint(x, P(mdl, None, None))
    if not ctx.packed:
        c = jnp.einsum("eck,ekn->ecn", x, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        return epi.apply_unpacked(c, bias)
    layout = ctx.layout(x.dtype)
    a_pack = packing.pack_lhs(x, layout)       # [E, C_o, K_o, m_r, k_r]
    b_pack = packing.pack_rhs(w, layout)       # [E, N_o, K_o, n_r, k_r]
    c_pack = mmt4d(a_pack, b_pack)             # [E, C_o, N_o, m_r, n_r]
    c_pack = epi.apply_packed(c_pack, layout, bias)
    out = packing.unpack_out(c_pack, x.shape[-2], w.shape[-1])
    if mdl:
        from jax.sharding import PartitionSpec as P
        out = jax.lax.with_sharding_constraint(out, P(mdl, None, None))
    return out


def prepack_params(params, ctx: MatmulContext, dtype=None):
    """Serving-path weight packing: replace every linear's ``w`` with
    ``w_pack`` materialized once (amortized packing, paper §4.1)."""
    if not ctx.packed:
        return params

    def rec(p):
        if isinstance(p, dict):
            if "w" in p and isinstance(p["w"], jnp.ndarray) and p["w"].ndim == 2:
                w = p["w"] if dtype is None else p["w"].astype(dtype)
                layout = ctx.layout(w.dtype)
                out = {k: rec(v) for k, v in p.items() if k != "w"}
                out["w_pack"] = packing.pack_rhs(w, layout)
                # static metadata: encode the unpadded out-dim as a shape
                out["w_n"] = jnp.zeros((w.shape[-1], 0), jnp.int8)
                return out
            return {k: rec(v) for k, v in p.items()}
        return p

    return rec(params)
