"""Scalable packed layouts (paper §4.2).

A packed layout reorganizes a matrix into register-level tiles:

    A  in R^{M x K}            (row-major)
    A_pack in R^{ceil(M/m_r) x ceil(K/k_r) x m_r x k_r}
    A_pack[i_o, k_o, i_i, k_i] = A[i_o*m_r + i_i, k_o*k_r + k_i]

The paper's contribution is to make the tile sizes *functions of the hardware
vector length* instead of compile-time constants:

    m_r = f_m(VL),  n_r = f_n(VL),  k_r = f_k(VL)

This module defines those functions for the TPU microkernel family (see
DESIGN.md §2 for the SVE→TPU mapping), a registry of microkernels, and the
three code-generation *policies* the benchmarks compare:

  - ``scalable``: tile sizes derived from the queried :class:`HardwareSpec`
    (the paper's approach — SVE-analogue).
  - ``fixed``: tile sizes are compile-time constants chosen for a reference
    128-bit-era target (the NEON-analogue baseline).
  - ``unpacked``: no packing at all; plain ``jnp.dot`` (the eager-analogue
    baseline).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable

import jax.numpy as jnp

from repro.core.hardware import HardwareSpec, query, sublane_packing

__all__ = [
    "LayoutPolicy",
    "Microkernel",
    "PackedLayout",
    "MICROKERNELS",
    "make_layout",
    "ceil_div",
    "round_up",
]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


class LayoutPolicy(str, enum.Enum):
    SCALABLE = "scalable"   # paper: tiles = f(HardwareSpec)   (SVE analogue)
    FIXED = "fixed"         # baseline: compile-time constants (NEON analogue)
    UNPACKED = "unpacked"   # baseline: no data tiling         (eager analogue)


@dataclasses.dataclass(frozen=True)
class Microkernel:
    """A microkernel family: tile-size functions of the hardware descriptor.

    ``f_m/f_n/f_k`` receive ``(hw, dtype)`` and return the register-level
    tile sizes.  The paper's representative SVE kernel is
    ``(m_r, n_r, k_r) = (8, 2*VL, 1)``; the TPU outer-product family is
    ``(sublanes*pack(dt)*s_m, lanes*s_n, mxu_k*s_k)``.
    """

    name: str
    f_m: Callable[[HardwareSpec, jnp.dtype], int]
    f_n: Callable[[HardwareSpec, jnp.dtype], int]
    f_k: Callable[[HardwareSpec, jnp.dtype], int]

    def tiles(self, hw: HardwareSpec, dtype) -> tuple[int, int, int]:
        dtype = jnp.dtype(dtype)
        return (self.f_m(hw, dtype), self.f_n(hw, dtype), self.f_k(hw, dtype))


def _mxu_outer_product(s_m: int = 1, s_n: int = 1, s_k: int = 1) -> Microkernel:
    """TPU MXU outer-product microkernel family.

    - ``m_r = sublanes * pack(dt) * s_m``: one native second-minor tile per
      unroll step (fp32: 8, bf16: 16, int8: 32) — dtype scaling, the analogue
      of SVE's elements-per-register scaling.
    - ``n_r = lanes * s_n``: the direct ``VL`` analogue (paper: ``n_r = 2VL``).
    - ``k_r = mxu_k * s_k``: systolic contraction depth.

    With ``s_n == s_k`` the output tile ``(m_r, n_r)`` coincides with the
    LHS-input tile ``(m_r, k_r)`` of a consumer matmul, which is what makes
    packed-layout propagation across chained matmuls *free* on TPU
    (DESIGN.md §2).
    """
    return Microkernel(
        name=f"mxu_outer_product_{s_m}x{s_n}x{s_k}",
        f_m=lambda hw, dt: hw.sublanes * sublane_packing(dt) * s_m,
        f_n=lambda hw, dt: hw.lanes * s_n,
        f_k=lambda hw, dt: hw.mxu_k * s_k,
    )


def _fixed_reference() -> Microkernel:
    """NEON-analogue: constants tuned once for a 128-lane-era target and then
    frozen, regardless of what hardware the code actually runs on."""
    return Microkernel(
        name="fixed_8x128x128",
        f_m=lambda hw, dt: 8,
        f_n=lambda hw, dt: 128,
        f_k=lambda hw, dt: 128,
    )


MICROKERNELS: dict[str, Microkernel] = {
    "mxu_outer_product": _mxu_outer_product(),
    "mxu_outer_product_2x": _mxu_outer_product(s_m=2),
    "fixed_8x128x128": _fixed_reference(),
}


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """A concrete (instantiated) packed layout for one matmul.

    Produced by :func:`make_layout` from (policy, hardware, dtype).  All
    shape arithmetic for pack/unpack/mmt4d flows through this object so that
    tile sizes appear in exactly one place — the compiler-pipeline discipline
    the paper argues for.
    """

    policy: LayoutPolicy
    kernel_name: str
    m_r: int
    n_r: int
    k_r: int
    dtype: str

    # ---- shape arithmetic (padding semantics, paper §4.3) ----
    def outer(self, dim: int, tile: int) -> int:
        return ceil_div(dim, tile)

    def packed_lhs_shape(self, m: int, k: int) -> tuple[int, int, int, int]:
        return (self.outer(m, self.m_r), self.outer(k, self.k_r), self.m_r, self.k_r)

    def packed_rhs_shape(self, k: int, n: int) -> tuple[int, int, int, int]:
        # RHS is packed transposed (mmt4d convention): [N_o, K_o, n_r, k_r].
        return (self.outer(n, self.n_r), self.outer(k, self.k_r), self.n_r, self.k_r)

    def packed_out_shape(self, m: int, n: int) -> tuple[int, int, int, int]:
        return (self.outer(m, self.m_r), self.outer(n, self.n_r), self.m_r, self.n_r)

    @property
    def chain_compatible(self) -> bool:
        """True iff an mmt4d *output* tile is a valid LHS *input* tile, i.e.
        packed-layout propagation across chained matmuls is a no-op."""
        return self.n_r == self.k_r

    def flops(self, m: int, n: int, k: int) -> int:
        """FLOPs actually executed on packed (padded) operands."""
        mp = self.outer(m, self.m_r) * self.m_r
        np_ = self.outer(n, self.n_r) * self.n_r
        kp = self.outer(k, self.k_r) * self.k_r
        return 2 * mp * np_ * kp


def make_layout(
    policy: LayoutPolicy | str = LayoutPolicy.SCALABLE,
    hw: HardwareSpec | None = None,
    dtype=jnp.float32,
    kernel: str = "mxu_outer_product",
) -> PackedLayout:
    """Instantiate a packed layout.

    Under the SCALABLE policy, tile sizes are queried from the hardware
    descriptor at instantiation time — the ``svcntw()`` moment.  Under FIXED,
    the frozen reference constants are used no matter the hardware.
    """
    policy = LayoutPolicy(policy)
    dtype = jnp.dtype(dtype)
    if policy is LayoutPolicy.UNPACKED:
        return PackedLayout(policy=policy, kernel_name="xla_dot", m_r=1, n_r=1, k_r=1,
                            dtype=dtype.name)
    if policy is LayoutPolicy.FIXED:
        mk = MICROKERNELS["fixed_8x128x128"]
        hw = hw or query()
        m_r, n_r, k_r = mk.tiles(hw, dtype)
        return PackedLayout(policy=policy, kernel_name=mk.name, m_r=m_r, n_r=n_r,
                            k_r=k_r, dtype=dtype.name)
    hw = hw or query()
    mk = MICROKERNELS[kernel]
    m_r, n_r, k_r = mk.tiles(hw, dtype)
    return PackedLayout(policy=policy, kernel_name=mk.name, m_r=m_r, n_r=n_r, k_r=k_r,
                        dtype=dtype.name)
