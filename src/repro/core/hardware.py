"""Hardware descriptors — the TPU analogue of SVE's runtime vector-length query.

The paper's central premise is that the hardware vector length ``VL`` is a
*runtime* constant (``svcntw()``), not a compile-time constant, and that data
layouts must therefore be *functions of a hardware descriptor* rather than
baked-in numbers.  On TPU the corresponding implementation-defined parameters
are the lane count of the vector/matrix units, the sublane depth, the dtype
packing factor, and the MXU contraction depth.  This module is the single
place those parameters are queried; everything else in the framework treats
them symbolically (via :class:`HardwareSpec`), exactly as the paper treats
``VL``.

``presets`` additionally contains *scaled* variants (``tpu_vl256``,
``tpu_vl512``) used by the Fig-3-analogue scaling study: the same layout and
kernel code instantiated at a wider "vector length", mirroring the paper's
gem5 SVE-128/256/512 experiment.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HardwareSpec",
    "presets",
    "query",
    "dtype_bits",
    "sublane_packing",
]


def dtype_bits(dtype) -> int:
    """Bit width of an element of ``dtype``."""
    return np.dtype(jnp.dtype(dtype)).itemsize * 8


def sublane_packing(dtype) -> int:
    """How many elements of ``dtype`` pack into one 32-bit sublane word.

    This is the TPU analogue of "more SVE elements per vector for narrower
    types": fp32 native tiles are (8,128); bf16 (16,128); int8/fp8 (32,128).
    """
    return max(1, 32 // dtype_bits(dtype))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Implementation-defined hardware parameters (the ``VL`` of the paper).

    Attributes:
      name: preset identifier.
      lanes: minor-dim lane count of the vector unit.  The direct analogue of
        the paper's ``VL`` (in elements).  128 on all shipped TPUs; the
        scaling-study presets widen it.
      sublanes: native sublane count for a 32-bit element (8 on TPU).
      mxu_k: contraction depth of the systolic array (granularity at which
        the MXU consumes the K dimension).
      vmem_bytes: per-core VMEM capacity (drives BlockSpec sizing).
      hbm_bw: HBM bandwidth, bytes/s/chip (roofline memory term).
      flops_bf16 / flops_f32: peak FLOP/s per chip.
      ici_bw: inter-chip link bandwidth, bytes/s/link (roofline collective
        term).
      hbm_bytes: HBM capacity per chip.
    """

    name: str
    lanes: int = 128
    sublanes: int = 8
    mxu_k: int = 128
    vmem_bytes: int = 16 * 2**20
    hbm_bw: float = 819e9
    flops_bf16: float = 197e12
    flops_f32: float = 98.5e12
    ici_bw: float = 50e9
    hbm_bytes: int = 16 * 2**30

    def vl(self, dtype=jnp.float32) -> int:
        """Vector length in elements (minor dim) — the ``svcntw()`` analogue.

        On TPU the minor (lane) dim is dtype-independent; dtype width shows
        up as sublane packing instead (see :func:`sublane_packing`).
        """
        del dtype
        return self.lanes

    def native_tile(self, dtype) -> tuple[int, int]:
        """The native (second-minor, minor) memory tile for ``dtype``."""
        return (self.sublanes * sublane_packing(dtype), self.lanes)

    def peak_flops(self, dtype) -> float:
        return self.flops_f32 if dtype_bits(dtype) >= 32 else self.flops_bf16

    def scaled(self, factor: int) -> "HardwareSpec":
        """A hypothetical implementation with ``factor``× wider vectors.

        Used by the VL-scaling study: like moving SVE-128 → SVE-512, compute
        throughput scales with width while memory bandwidth does not.
        """
        return dataclasses.replace(
            self,
            name=f"{self.name}_vl{self.lanes * factor}",
            lanes=self.lanes * factor,
            mxu_k=self.mxu_k * factor,
            flops_bf16=self.flops_bf16 * factor,
            flops_f32=self.flops_f32 * factor,
        )


# TPU v5e is the primary target (the brief's roofline constants).
_TPU_V5E = HardwareSpec(name="tpu_v5e")

presets: dict[str, HardwareSpec] = {
    "tpu_v5e": _TPU_V5E,
    # v4-like: bigger VMEM, different peak -- demonstrates portability of the
    # layout code across generations (same lanes, different everything else).
    "tpu_v4": HardwareSpec(
        name="tpu_v4",
        vmem_bytes=32 * 2**20,
        hbm_bw=1228e9,
        flops_bf16=275e12,
        flops_f32=137.5e12,
        hbm_bytes=32 * 2**30,
    ),
    # Scaling-study presets (Fig 3 analogue): hypothetical wider-vector
    # implementations.  Only lane count / MXU depth / peak FLOPs change, the
    # memory system is held fixed -- the same controlled experiment as the
    # paper's gem5 study (which scaled only the vector width).
    "tpu_vl128": _TPU_V5E,
    "tpu_vl256": _TPU_V5E.scaled(2),
    "tpu_vl512": _TPU_V5E.scaled(4),
}


def query(name: Optional[str] = None) -> HardwareSpec:
    """Query the hardware descriptor at run time (``svcntw()`` analogue).

    Resolution order: explicit ``name`` → ``$REPRO_HW`` → the actual JAX
    backend (TPU kind if on TPU) → tpu_v5e default (this container is CPU;
    v5e is the modelled target).
    """
    if name is None:
        name = os.environ.get("REPRO_HW")
    if name is not None:
        if name not in presets:
            raise KeyError(f"unknown hardware preset {name!r}; have {sorted(presets)}")
        return presets[name]
    dev = jax.devices()[0]
    if dev.platform == "tpu":  # pragma: no cover - no TPU in this container
        kind = getattr(dev, "device_kind", "").lower()
        if "v4" in kind:
            return presets["tpu_v4"]
        return presets["tpu_v5e"]
    return presets["tpu_v5e"]
