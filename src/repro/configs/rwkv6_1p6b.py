"""RWKV-6 Finch 1.6B [arXiv:2404.05892]: attention-free, data-dependent
decay; channel-mix d_ff=7168."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536, d_head=64,
        rope="none", norm="layernorm", act="relu", glu=False,
        block_pattern=("rwkv",), rwkv_head_dim=64)
