"""OLMo-1B [arXiv:2402.00838; hf]: dense MHA, non-parametric LayerNorm."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304, d_head=128,
        norm="layernorm_np", act="silu", glu=True, tie_embeddings=True)
