"""ChatGLM3-6B [arXiv:2406.12793; hf]: GQA kv=2, 2d (partial) RoPE, QKV bias."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024, d_head=128,
        rope="partial2d", rope_pct=0.5, attn_bias=True,
        norm="rmsnorm", act="silu", glu=True)
