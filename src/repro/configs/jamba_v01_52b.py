"""Jamba-v0.1 52B [arXiv:2403.19887]: attn:mamba 1:7 interleave, MoE 16e
top-2 on every 2nd layer (period-8 block pattern, attn at index 4)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, d_head=128,
        norm="rmsnorm", act="silu", glu=True,
        block_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        moe=True, n_experts=16, top_k=2, d_ff_expert=14336, moe_every=2,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2)
