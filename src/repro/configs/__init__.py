from repro.configs.base import ModelConfig, RunConfig, ShapeSpec, SHAPES, reduced_config
from repro.configs.registry import ARCHS, ASSIGNED, get_config, cells, cell_status

__all__ = ["ModelConfig", "RunConfig", "ShapeSpec", "SHAPES", "reduced_config",
           "ARCHS", "ASSIGNED", "get_config", "cells", "cell_status"]
