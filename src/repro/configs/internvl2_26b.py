"""InternVL2-26B [arXiv:2404.16821]: InternLM2-20B LM backbone; InternViT
frontend stubbed as 256 precomputed patch embeddings."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553, d_head=128,
        norm="rmsnorm", act="silu", glu=True, frontend="vision",
        vision_tokens=256)
