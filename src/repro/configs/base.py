"""Configuration system: model configs, shape specs, run configs.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is a :class:`ShapeSpec`.  ``RunConfig`` carries the execution knobs
(layout policy, dtype, parallelism, remat/microbatching) that the §Perf
iterations sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "RunConfig", "SHAPES", "reduced_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads

    # attention details
    rope: str = "neox"            # neox | partial2d | none
    rope_theta: float = 1e4
    rope_pct: float = 1.0         # fraction of head dim rotated (chatglm: 0.5)
    qk_norm: bool = False         # qwen3
    attn_bias: bool = False       # qwen2 QKV bias
    norm: str = "rmsnorm"         # rmsnorm | layernorm | layernorm_np (non-parametric)
    act: str = "silu"
    glu: bool = True              # gated (SwiGLU-style) MLP
    tie_embeddings: bool = False

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1            # MoE FFN on every k-th layer (jamba: 2)
    dense_residual: bool = False  # arctic: parallel dense-FFN residual branch
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # per-layer mixer pattern, cycled over layers ("attn" | "mamba" | "rwkv")
    block_pattern: Tuple[str, ...] = ("attn",)

    # mamba (jamba values)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # rwkv6
    rwkv_head_dim: int = 64

    # enc-dec (whisper): decoder layers = n_layers, encoder layers below
    encoder_layers: int = 0

    # modality frontend stub: number of stub embedding tokens / frame factor
    frontend: str = "none"        # none | audio | vision
    vision_tokens: int = 256      # vlm: stubbed patch-embedding tokens
    audio_downsample: int = 4     # audio: encoder frames = seq_len // this

    # attention scaling behaviour for huge context
    attention: str = "full"       # full | (sub-quadratic mixers live in block_pattern)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(1, self.n_heads))

    @property
    def layer_types(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can run the long_500k cell (SSM/hybrid)."""
        return any(t != "attn" for t in self.layer_types)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def moe_on_layer(self, i: int) -> bool:
        return self.moe and ((i + 1) % self.moe_every == 0)

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D) ----
    def param_counts(self) -> dict:
        d, dh = self.d_model, self.d_head
        hq, hkv = self.n_heads, self.n_kv_heads
        counts = {"embed": self.vocab * d,
                  "lm_head": 0 if self.tie_embeddings else self.vocab * d}
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        dense_ffn = (3 if self.glu else 2) * d * self.d_ff
        expert_ffn = (3 if self.glu else 2) * d * self.d_ff_expert
        mamba_inner = self.mamba_expand * d
        mamba = (d * 2 * mamba_inner + mamba_inner * self.mamba_d_conv
                 + mamba_inner * (2 * self.mamba_d_state + -(-d // 16))
                 + (-(-d // 16)) * mamba_inner + mamba_inner * d)
        rwkv = 4 * d * d + d * d + 2 * d * d  # r,k,v,g,o + channel-mix approx

        total = counts["embed"] + counts["lm_head"]
        active = total
        for i, t in enumerate(self.layer_types):
            if t == "attn":
                total += attn; active += attn
            elif t == "mamba":
                total += mamba; active += mamba
            elif t == "rwkv":
                total += rwkv; active += rwkv
            if self.moe_on_layer(i):
                total += self.n_experts * expert_ffn + d * self.n_experts
                active += self.top_k * expert_ffn
                if self.dense_residual:
                    total += dense_ffn; active += dense_ffn
            else:
                total += dense_ffn; active += dense_ffn
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + dense_ffn)
            total += enc; active += enc
            dec_cross = self.n_layers * attn  # cross-attention blocks
            total += dec_cross; active += dec_cross
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs (the §Perf sweep space)."""

    layout_policy: str = "scalable"     # scalable | fixed | unpacked
    propagate: bool = True              # packed-layout propagation across ops
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    microbatch: int = 0                 # 0 = no grad accumulation
    remat: bool = True
    # parallelism
    fsdp: bool = True                   # shard params/opt state over data axis
    seq_shard_kv: bool = True           # shard decode KV along sequence
    moe_local_dispatch: bool = False    # per-DP-shard MoE sort/capacity
    # optimizer
    lr: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_8bit: bool = False
    grad_compression: bool = False
    # numerics
    z_loss: float = 1e-4


def reduced_config(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """A small same-family config for CPU smoke tests.

    Preserves the architectural features (GQA ratio, qk-norm, pattern, MoE
    top-k, enc-dec structure) while shrinking every dimension.
    """
    pat = cfg.block_pattern
    n_layers = layers if layers is not None else max(2, min(len(pat), 8))
    hq = max(2, min(4, cfg.n_heads))
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    hkv = max(1, hq // min(ratio, hq))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=hq,
        n_kv_heads=hkv,
        d_head=16,
        d_ff=128,
        d_ff_expert=96 if cfg.moe else 0,
        n_experts=min(4, cfg.n_experts) if cfg.moe else 0,
        top_k=min(2, cfg.top_k) if cfg.moe else 0,
        vocab=512,
        encoder_layers=2 if cfg.encoder_layers else 0,
        mamba_d_state=8,
        rwkv_head_dim=16,
        vision_tokens=8,
    )
