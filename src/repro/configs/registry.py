"""Architecture registry and the assigned (arch x shape) cell matrix."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator, Optional

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec

__all__ = ["ARCHS", "get_config", "cells", "cell_status", "ASSIGNED"]

_MODULES = {
    "qwen2-7b": "qwen2_7b",
    "qwen3-8b": "qwen3_8b",
    "olmo-1b": "olmo_1b",
    "chatglm3-6b": "chatglm3_6b",
    "whisper-small": "whisper_small",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "arctic-480b": "arctic_480b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "internvl2-26b": "internvl2_26b",
    # the paper's own end-to-end model (extra, beyond the assigned ten)
    "smollm2-135m": "smollm2_135m",
}

ASSIGNED = [a for a in _MODULES if a != "smollm2-135m"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.config()


ARCHS = dict(_MODULES)


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs, reason-if-skipped) for one (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("skip: pure full-attention arch at 524288 ctx "
                       "(assignment rule: long_500k only for SSM/hybrid)")
    return True, ""


def cells(include_skipped: bool = False,
          archs: Optional[list[str]] = None) -> Iterator[tuple[str, str, bool, str]]:
    """Yield (arch, shape, runs, reason) over the assigned 40-cell matrix."""
    for arch in (archs or ASSIGNED):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            runs, reason = cell_status(cfg, shape)
            if runs or include_skipped:
                yield arch, shape.name, runs, reason
