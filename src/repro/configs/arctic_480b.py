"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 128 experts
top-2 with a parallel dense-FFN residual branch."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, d_head=128,
        norm="rmsnorm", act="silu", glu=True,
        moe=True, n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True)
