"""Qwen3-235B-A22B MoE [hf:Qwen/Qwen3-30B-A3B family]: 128 experts, top-8,
GQA kv=4, qk_norm, expert d_ff=1536."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
        n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, d_head=128,
        qk_norm=True, rope_theta=1e6, norm="rmsnorm", act="silu", glu=True,
        moe=True, n_experts=128, top_k=8, d_ff_expert=1536)
