"""Whisper-small [arXiv:2212.04356]: enc-dec, conv frontend stubbed.

Shape mapping (DESIGN.md §4): encoder consumes stubbed frame embeddings
[B, seq_len/4, d]; decoder consumes seq_len tokens.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, d_head=64,
        encoder_layers=12, rope="none", norm="layernorm", act="gelu", glu=False,
        attn_bias=True, tie_embeddings=True, frontend="audio", audio_downsample=4)
