"""SmolLM2-135M [hf:HuggingFaceTB/SmolLM2-135M] — the paper's own
end-to-end model (Table 2 index 1, Fig. 3)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm2-135m", family="dense", n_layers=30, d_model=576,
        n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152, d_head=64,
        norm="rmsnorm", act="silu", glu=True, tie_embeddings=True)
