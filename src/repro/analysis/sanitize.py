"""Opt-in runtime sanitizer for the pool write path (``REPRO_SANITIZE=1``).

Passes 1–2 prove the *static* halves of the layout/aliasing contracts;
this hook enforces the *dynamic* halves on every real step, at host
speed, before the device call runs:

* every valid write destination this step touches is a live, in-range,
  **private** page — ``ref == 1`` — so an in-place write to a shared
  page (the CoW-before-write violation) fails loudly at the step that
  would corrupt another request's KV, naming the page, its refcount and
  the owning request ids;
* no valid position routes to trash page 0 (that is a block table not
  covering the write window: tokens silently dropped);
* the step width is a member of the declared shape ladder and
  ``m_r``-aligned (tile-whole writes) — the runtime twin of the shape
  linter, catching widths produced by state mutated after construction;
* the resilience contract (PR 8): a retired rid — finished, cancelled,
  timed out, shed or quarantined — is never still scheduled and never
  still holds pages (zero-leak-on-cancel), and a quarantined request's
  privately-held pages are actually free after
  ``cancel(cache_pages=False)`` — quarantined KV can never have reached
  the prefix cache.

Destinations are recomputed host-side through the same addressing rules
the device scatters use (for the flat step, literally
:func:`repro.kernels.ragged_attn.ref.flat_write_destinations` — the
write half of the oracle), so the sanitizer can't drift from the kernel
contract without the identity tests failing too.

Install via ``REPRO_SANITIZE=1`` in the environment (every ``Engine``
self-installs at construction) or explicitly::

    from repro.analysis.sanitize import install
    san = install(engine)      # idempotent; returns the StepSanitizer

Warmup traffic is inherently clean (``new_counts == 0`` / ``row_ids ==
-1`` everywhere), so installing before warmup costs only the host check.
"""

from __future__ import annotations

from typing import Optional, Set

import numpy as np

from repro.kernels.ragged_attn.ref import flat_write_destinations

__all__ = ["SanitizerError", "StepSanitizer", "install"]


class SanitizerError(AssertionError):
    """A runtime layout/aliasing contract violation on the pool write path."""


class StepSanitizer:
    """Host-side pre-step checker wrapped around an engine's jitted steps."""

    def __init__(self, engine):
        self.engine = engine
        self.pool = engine.pool
        self.m_r = engine._bucket
        self.checks = 0            # steps inspected
        self.pages_checked = 0     # (page, step) write destinations audited
        self.cancels_checked = 0   # quarantine/cancel page audits
        self.paged_widths: Optional[Set[int]] = self._declared_paged_widths()
        self.flat_widths: Optional[Set[int]] = (
            set(engine._flat_shapes()) if engine.flat else None)

    def _declared_paged_widths(self) -> Optional[Set[int]]:
        eng = self.engine
        if eng.chunked:
            widths = set(eng._chunk_shapes()) | {1}
            if eng.spec_tokens is not None:
                widths.add(eng.spec_tokens + 1)
            return widths
        if eng._bucket == 1:
            return None            # hybrids prefill at exact lengths
        widths = {1}
        if eng.spec_tokens is not None:
            widths.add(eng.spec_tokens + 1)
        l, seen = eng._bucket, set()
        while True:
            b = eng._prefill_bucket(l)
            if b in seen:
                break
            seen.add(b)
            l = b + 1
        return widths | seen

    # ------------------------------------------------------------------
    def _fail(self, message: str) -> None:
        raise SanitizerError(f"REPRO_SANITIZE: {message}")

    def _check_width(self, s: int, ladder: Optional[Set[int]],
                     kind: str) -> None:
        if ladder is None:
            return
        if s not in ladder:
            self._fail(
                f"{kind} step width {s} is not in the declared shape "
                f"ladder {sorted(ladder)} — an un-warmed width retraces "
                f"XLA and breaks tile-whole writes")

    def _check_pages(self, pages, where: str) -> None:
        pool = self.pool
        for p in np.unique(np.asarray(pages)):
            p = int(p)
            self.pages_checked += 1
            if p == 0:
                self._fail(
                    f"{where}: a valid position writes trash page 0 — the "
                    f"block table does not cover the write window; these "
                    f"tokens would be silently dropped")
            if not 0 < p < pool.num_pages:
                self._fail(f"{where}: write destination page {p} is outside "
                           f"the pool (num_pages={pool.num_pages})")
            ref = pool.ref(p)
            if ref == 0:
                self._fail(
                    f"{where}: write into unallocated page {p} (ref=0) — "
                    f"the block table references a freed page")
            if ref > 1:
                self._fail(
                    f"{where}: in-place write to page {p} with ref={ref} "
                    f"(holders: requests {pool.holders(p)}) — shared pages "
                    f"are read-only; PagedKVPool.cow() must split the page "
                    f"before any write or every holder's KV is corrupted")

    def check_retired(self) -> None:
        """Zero-leak-on-cancel: a retired rid (finished, cancelled, timed
        out, shed, quarantined) must be gone from the schedule and must
        hold no pages.  Runs before every step, so a leak is caught at
        the step after the retirement that caused it."""
        retired = getattr(self.engine, "_retired_rids", None)
        if not retired:
            return
        sched = self.engine.scheduler
        for r in list(sched.running.values()) + list(sched.waiting):
            if r.rid in retired:
                self._fail(f"retired rid {r.rid} is still scheduled "
                           f"(status={r.status}) — cancel/finish must "
                           f"remove the request from the scheduler")
        for s in self.pool.sequences():
            if s.owner in retired and s.pages:
                self._fail(
                    f"retired rid {s.owner} still holds pages {s.pages} — "
                    f"zero-leak-on-cancel violated (release() must run on "
                    f"every retirement path)")

    # ------------------------------------------------------------------
    def check_paged(self, token, block_tables, lens, new_counts) -> None:
        token = np.asarray(token)
        bt = np.asarray(block_tables)
        lens = np.asarray(lens)
        counts = np.asarray(new_counts)
        self.checks += 1
        b, s = token.shape
        self._check_width(s, self.paged_widths, "paged")
        t = self.pool.page_tokens
        for bi in range(b):
            n = int(counts[bi])
            if n <= 0:
                continue             # inert row: every write trash-routed
            pos = int(lens[bi]) + np.arange(n)
            slot = np.minimum(pos // t, bt.shape[1] - 1)
            self._check_pages(
                bt[bi, slot],
                f"paged step row {bi} (lens={int(lens[bi])}, "
                f"new_count={n})")

    def check_flat(self, token, block_tables, row_ids, q_pos) -> None:
        token = np.asarray(token)
        bt = np.asarray(block_tables)
        row_ids = np.asarray(row_ids)
        q_pos = np.asarray(q_pos)
        self.checks += 1
        w = token.shape[1]
        self._check_width(w, self.flat_widths, "flat")
        if self.m_r > 1 and w % self.m_r != 0:
            self._fail(f"flat step width {w} is not m_r-aligned "
                       f"(m_r={self.m_r}) — tile writes would be partial")
        pages, _off, valid = flat_write_destinations(bt, row_ids, q_pos,
                                                     self.pool.page_tokens)
        if valid.any():
            rows = sorted(int(r) for r in np.unique(row_ids[valid]))
            self._check_pages(pages[valid],
                              f"flat step (rows {rows}, "
                              f"{int(valid.sum())} valid tokens)")


def install(engine) -> StepSanitizer:
    """Wrap ``engine._paged_step`` / ``engine._flat_step`` with pre-call
    contract checks.  Idempotent per engine."""
    existing = getattr(engine, "sanitizer", None)
    if existing is not None:
        return existing
    san = StepSanitizer(engine)

    orig_paged = engine._paged_step

    def paged_checked(params, caches, token, bt, lens, counts, idx=None):
        san.check_retired()
        san.check_paged(token, bt, lens, counts)
        return orig_paged(params, caches, token, bt, lens, counts, idx)

    engine._paged_step = paged_checked
    if engine._flat_step is not None:
        orig_flat = engine._flat_step

        def flat_checked(params, caches, token, bt, row_ids, q_pos, idx):
            san.check_retired()
            san.check_flat(token, bt, row_ids, q_pos)
            return orig_flat(params, caches, token, bt, row_ids, q_pos, idx)

        engine._flat_step = flat_checked

    # quarantine audit: a cancel(cache_pages=False) is the engine saying
    # "this KV is poisoned" — pages the request held privately must end
    # the call free (a nonzero ref would mean the poisoned KV slipped
    # into the prefix cache or another block table)
    orig_cancel = engine.scheduler.cancel

    def cancel_checked(rid, reason="cancelled", *, cache_pages=True):
        solo = []
        if not cache_pages:
            live = ([r for r in engine.scheduler.waiting if r.rid == rid] +
                    [r for r in engine.scheduler.running.values()
                     if r.rid == rid])
            if live and live[0].pages is not None:
                solo = [p for p in live[0].pages.pages
                        if engine.pool.ref(p) == 1]
        out = orig_cancel(rid, reason, cache_pages=cache_pages)
        if solo:
            san.cancels_checked += 1
            for p in solo:
                if engine.pool.ref(p) != 0:
                    san._fail(
                        f"quarantined page {p} of rid {rid} survived "
                        f"cancel(cache_pages=False) with "
                        f"ref={engine.pool.ref(p)} (holders: "
                        f"{engine.pool.holders(p)}) — quarantined KV must "
                        f"never reach the prefix cache")
        return out

    engine.scheduler.cancel = cancel_checked
    engine.sanitizer = san
    return san
