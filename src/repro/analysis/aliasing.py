"""Pass 2 — the KV-write aliasing pass.

Proves, from the jaxpr alone, that every write into the paged KV pool is
*guarded*: its destination index is computed from the block-table gather
(so a row can only write its own pages) **and** carries the trash-page
route (``jnp.where(valid, page, 0)`` — invalid positions land on page 0,
never on live KV).  Runs on the unit updates
(:func:`repro.models.attention.paged_kv_update` /
:func:`flat_paged_kv_update`) and on the full fused step jaxprs, where
the pool scatters live inside the layer ``scan``.

The complementary *dynamic* half — a write into a page with ``ref > 1``
is impossible without a preceding ``cow()`` — cannot be read off a jaxpr
(refcounts are host state), so it is split into
:func:`check_pool_consistency`, a ledger audit run after traffic: every
live page's refcount must equal the number of sequences holding it plus
its prefix-cache node (if any), the free list must be disjoint from live
pages, and the trash page must never be held.  Together with the
``REPRO_SANITIZE`` runtime hook (``analysis.sanitize``, which asserts
``ref == 1`` at the moment of each in-place write) this closes the CoW
contract end to end.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Set

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_tools import TRASH_LABEL, TaintWalker, WriteSite
from repro.analysis.report import Finding

__all__ = ["taint_step", "lint_kv_writes", "lint_engine_aliasing",
           "check_pool_consistency"]

_PASS = "kv-aliasing"

# labels a guarded pool write's *indices* must carry: provenance through
# the block-table gather, and the validity-predicated zero route
REQUIRED_INDEX_LABELS = frozenset({"block_tables", TRASH_LABEL})


def _leaf_labels(args: Sequence, role_of_arg: dict) -> List[Optional[Set[str]]]:
    """Per-flat-leaf label sets for a positional arg tuple.
    ``role_of_arg``: arg position -> role string, or callable(path_str) ->
    role (for pytree args like the cache dict where only ``*_pages``
    leaves are the pool)."""
    labels = []
    leaves, _ = jax.tree_util.tree_flatten_with_path(tuple(args))
    for path, _leaf in leaves:
        idx = path[0].idx
        role = role_of_arg.get(idx)
        if callable(role):
            role = role(jax.tree_util.keystr(path))
        labels.append({role} if role else set())
    return labels


def taint_step(fn, abstract_args: tuple, role_of_arg: dict) -> TaintWalker:
    """Trace ``fn`` at the given ``ShapeDtypeStruct`` args and taint-walk
    the closed jaxpr with the given arg roles."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return TaintWalker().run(closed, _leaf_labels(abstract_args, role_of_arg))


def lint_kv_writes(walker: TaintWalker, family: str,
                   *, expect_writes: int = 1) -> List[Finding]:
    """Judge the walker's recorded write sites against the pool contract."""
    f: List[Finding] = []
    pool_writes = [w for w in walker.write_sites if w.writes("pages")]
    if len(pool_writes) < expect_writes:
        f.append(Finding(
            _PASS, "missing-write", family,
            f"found {len(pool_writes)} pool write(s), expected >= "
            f"{expect_writes} — either the analyzer lost the pages label "
            f"or a write was restructured past the walker; the pass is "
            f"only meaningful when it sees the writes it judges"))
    for w in pool_writes:
        missing = REQUIRED_INDEX_LABELS - w.index_labels
        if missing:
            f.append(Finding(
                _PASS, "unguarded-write", f"{w.prim} @ {w.where}",
                f"{family}: pool write indices lack {sorted(missing)} "
                f"(have {sorted(w.index_labels)}; jaxpr path {w.path}) — "
                f"every KV write must be addressed through the block-table "
                f"gather and route invalid rows to trash page 0",
                detail={"labels": sorted(w.index_labels)}))
        if w.mode and "PROMISE_IN_BOUNDS" in w.mode:
            f.append(Finding(
                _PASS, "unsafe-scatter-mode", f"{w.prim} @ {w.where}",
                f"{family}: pool scatter compiled with PROMISE_IN_BOUNDS — "
                f"an out-of-ladder index would write out of bounds instead "
                f"of dropping; pool writes must stay FILL_OR_DROP"))
    return f


def _attention_unit_walkers(engine):
    """Taint-walk the unit KV-update functions at this engine's shapes."""
    from repro.models import attention
    model = engine.model
    cfg = model.cfg
    S = jax.ShapeDtypeStruct
    i32, dt = jnp.int32, model.compute_dtype
    pool = engine.pool
    t = pool.page_tokens
    cache = {"k_pages": S((pool.num_pages, t, cfg.n_kv_heads, cfg.d_head), dt),
             "v_pages": S((pool.num_pages, t, cfg.n_kv_heads, cfg.d_head), dt)}
    b, mp = engine.slots, engine.max_pages
    out = []
    s = engine.chunk_tokens or engine._bucket
    kv = S((b, s, cfg.n_kv_heads, cfg.d_head), dt)
    out.append(("paged_kv_update", taint_step(
        lambda c, k, v, bt, ln, nc: attention.paged_kv_update(
            c, k, v, block_tables=bt, lens=ln, new_counts=nc),
        (cache, kv, kv, S((b, mp), i32), S((b,), i32), S((b,), i32)),
        {0: lambda p: "pages" if "_pages" in p else None,
         3: "block_tables", 4: "validity", 5: "validity"}), 2))
    if engine.flat:
        w = engine._flat_shapes()[0]
        kvf = S((1, w, cfg.n_kv_heads, cfg.d_head), dt)
        out.append(("flat_paged_kv_update", taint_step(
            lambda c, k, v, bt, r, q: attention.flat_paged_kv_update(
                c, k, v, block_tables=bt, row_ids=r, q_pos=q),
            (cache, kvf, kvf, S((b, mp), i32), S((w,), i32), S((w,), i32)),
            {0: lambda p: "pages" if "_pages" in p else None,
             3: "block_tables", 4: "validity", 5: "validity"}), 2))
    return out


def lint_engine_aliasing(engine, label: str = "engine") -> List[Finding]:
    """Run pass 2 on one engine: the unit updates, plus one full fused-step
    jaxpr per active step family (widest shape — the scatters are identical
    across ladder widths, so one representative keeps the pass fast)."""
    f: List[Finding] = []
    model = engine.model
    here = f"{label} ({model.cfg.name})"
    for name, walker, expect in _attention_unit_walkers(engine):
        f.extend(lint_kv_writes(walker, f"{here} {name}",
                                expect_writes=expect))

    params = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        engine.params)
    caches = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        engine.caches)
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32
    b, mp = engine.slots, engine.max_pages
    cache_role = {1: lambda p: "pages" if "_pages" in p else None}
    # one pool K + one pool V scatter per layer scan body = 2 sites
    if engine.flat:
        w = engine._flat_shapes()[0]
        walker = taint_step(
            model.flat_decode_step,
            (params, caches, S((1, w), i32), S((b, mp), i32),
             S((w,), i32), S((w,), i32), S((b,), i32)),
            {**cache_role, 3: "block_tables", 4: "validity", 5: "validity"})
        f.extend(lint_kv_writes(walker, f"{here} flat_decode_step[1,{w}]",
                                expect_writes=2))
    else:
        s = engine.chunk_tokens if engine.chunked else 1
        walker = taint_step(
            model.paged_decode_step,
            (params, caches, S((b, s), i32), S((b, mp), i32),
             S((b,), i32), S((b,), i32), None),
            {**cache_role, 3: "block_tables", 4: "validity", 5: "validity"})
        f.extend(lint_kv_writes(walker, f"{here} paged_decode_step[{b},{s}]",
                                expect_writes=2))
    return f


def check_pool_consistency(engine, label: str = "engine") -> List[Finding]:
    """Dynamic half of the aliasing contract: audit the pool ledger
    against its holders (live sequences + prefix-cache nodes), and the
    resilience contract's zero-leak-on-cancel: a retired rid — finished,
    cancelled, timed out, shed or quarantined — may not hold pages."""
    f: List[Finding] = []
    pool = engine.pool
    here = f"{label} pool"

    retired = getattr(engine, "_retired_rids", set())
    for seq in pool.sequences():
        if seq.owner in retired and seq.pages:
            f.append(Finding(
                _PASS, "retired-holds-pages", here,
                f"retired rid {seq.owner} still holds pages {seq.pages} — "
                f"zero-leak-on-cancel violated (every retirement path must "
                f"release the block table)"))
    ledger = pool.ledger()
    refs, free = ledger["refs"], ledger["free"]

    live_and_free = set(refs) & set(free)
    if live_and_free:
        f.append(Finding(_PASS, "ledger-free-live", here,
                         f"pages {sorted(live_and_free)} are on the free "
                         f"list while refcounted live — the next alloc "
                         f"would hand one page to two requests"))
    if 0 in refs or 0 in free:
        f.append(Finding(_PASS, "ledger-trash", here,
                         "trash page 0 appears in the allocator ledger — "
                         "it must never be allocated or freed"))
    for p, r in sorted(refs.items()):
        if r < 1:
            f.append(Finding(_PASS, "ledger-refcount", here,
                             f"page {p} live with ref={r}"))

    held: dict = {}
    for seq in pool.sequences():
        for p in seq.pages:
            held[p] = held.get(p, 0) + 1
    cached = set()
    if engine.prefix_cache is not None:
        cached = set(engine.prefix_cache.pages())
    for p in sorted(set(held) | cached | set(refs)):
        want = held.get(p, 0) + (1 if p in cached else 0)
        have = refs.get(p, 0)
        if want != have:
            f.append(Finding(
                _PASS, "ledger-mismatch", here,
                f"page {p}: ref={have} but held by {held.get(p, 0)} "
                f"sequence(s) (requests {pool.holders(p)}) "
                f"{'+ prefix cache ' if p in cached else ''}— a stale "
                f"refcount makes CoW-before-write undecidable"))
    return f
