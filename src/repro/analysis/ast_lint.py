"""Pass 4 — AST invariant lint (stdlib ``ast``, no runtime, no deps).

Repo rules that no runtime test can see, enforced syntactically over
``src/repro/serving/`` and ``src/repro/kernels/``:

* **allocator-privacy** — the free list and refcount dict
  (``._free``/``._ref``) are mutated *only* inside ``kv_cache.py``.  A
  ``pool._free.append(p)`` anywhere else bypasses the double-free check
  and the refcount ledger; reads are allowed (stats, analysis), writes
  are not.
* **capacity-asserts** — scheduler-side admission/growth asserts must
  reason in ``usable_pages``/``num_available`` (free + reclaimable
  prefix-cache pages), never raw ``free_pages``/``num_free``: an assert
  on the raw free list spuriously fires exactly when the cache is doing
  its job holding spare pages.
* **unseeded-randomness** — no hidden-global-RNG draws (stdlib
  ``random.*`` module functions, ``np.random.*`` legacy functions,
  ``default_rng()``/``RandomState()`` with no seed).  Serving is
  deterministic by construction — token-identity contracts and the
  (seed, rid, position) sampling rule both die the day an unseeded draw
  sneaks in.  Explicit generators (``np.random.Philox(seed)``,
  ``jax.random`` keys) are fine.
* **kernel-oracle** — every Pallas kernel package
  (``kernels/*/kernel.py``) keeps a ``ref.py`` jnp oracle *and* some
  test imports it (the module, or a name it defines): the oracle is the
  kernel's spec, and an unimported spec rots.
* **monotonic-clock** — wall-time *measurement* in ``src/repro/serving``
  and ``src/repro/obs`` must use ``time.perf_counter()``, never
  ``time.time()``: telemetry spans, step timings, and latency
  histograms subtract clock readings, and the wall clock can step
  backwards under NTP adjustment, silently producing negative spans.
  Deadline arithmetic against a caller-provided ``now=`` is untouched —
  the rule flags only ``time.time()`` call sites.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional

from repro.analysis.report import Finding

__all__ = ["lint_paths", "lint_file", "lint_kernel_oracles"]

_PASS = "ast-lint"

_PRIVATE_ATTRS = frozenset({"_free", "_ref"})
_MUTATORS = frozenset({"append", "extend", "insert", "pop", "remove",
                       "clear", "update", "setdefault", "popitem",
                       "__setitem__", "sort", "reverse"})
_RAW_CAPACITY = frozenset({"free_pages", "num_free"})

_NP_UNSEEDED = frozenset({
    "random", "rand", "randn", "randint", "random_integers", "choice",
    "shuffle", "permutation", "uniform", "normal", "standard_normal",
    "beta", "binomial", "poisson", "exponential", "gamma", "sample",
    "ranf", "random_sample", "bytes", "seed",
})
_STDLIB_UNSEEDED = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "getrandbits",
    "seed", "betavariate", "expovariate",
})


def _dotted(node) -> Optional[List[str]]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: Path, *, allocator_owner: bool,
                 serving_file: bool, clock_file: bool = False):
        self.path = path
        self.allocator_owner = allocator_owner
        self.serving_file = serving_file
        self.clock_file = clock_file         # monotonic-clock rule applies
        self.findings: List[Finding] = []
        self._numpy_aliases = {"numpy"}      # names that mean the numpy module
        self._stdlib_random_aliases = set()  # names that mean stdlib random
        self._time_aliases = set()           # names that mean the time module
        self._walltime_names = set()         # names bound to time.time itself

    def _add(self, rule: str, node, message: str) -> None:
        self.findings.append(Finding(
            _PASS, rule, f"{self.path}:{getattr(node, 'lineno', '?')}",
            message))

    # ---- import tracking (for the randomness rule) -------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = a.asname or a.name.split(".")[0]
            if a.name == "random":
                self._stdlib_random_aliases.add(name)
            elif a.name.split(".")[0] == "numpy":
                self._numpy_aliases.add(name)
            elif a.name == "time":
                self._time_aliases.add(name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module in ("random",):
            for a in node.names:
                if a.name in _STDLIB_UNSEEDED:
                    self._add("unseeded-randomness", node,
                              f"'from random import {a.name}' pulls a "
                              f"global-state RNG draw into deterministic "
                              f"serving code — use a seeded "
                              f"np.random.Generator or jax.random key")
        if node.module == "time":
            for a in node.names:
                if a.name == "time":
                    self._walltime_names.add(a.asname or a.name)
        self.generic_visit(node)

    # ---- allocator privacy -------------------------------------------
    def _private_attr(self, node) -> Optional[str]:
        if isinstance(node, ast.Attribute) and node.attr in _PRIVATE_ATTRS:
            return node.attr
        if isinstance(node, ast.Subscript):
            return self._private_attr(node.value)
        return None

    def _flag_mutation(self, node, attr: str) -> None:
        if not self.allocator_owner:
            self._add("allocator-privacy", node,
                      f"mutation of allocator-private '.{attr}' outside "
                      f"kv_cache.py — free-list/refcount writes bypass the "
                      f"double-free check and the ledger; go through "
                      f"PagedKVPool.alloc/share/free/cow")

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            attr = self._private_attr(t)
            if attr:
                self._flag_mutation(node, attr)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        attr = self._private_attr(node.target)
        if attr:
            self._flag_mutation(node, attr)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            attr = self._private_attr(t)
            if attr:
                self._flag_mutation(node, attr)
        self.generic_visit(node)

    # ---- calls: mutating methods + unseeded randomness ---------------
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _MUTATORS):
            attr = self._private_attr(fn.value)
            if attr:
                self._flag_mutation(node, attr)

        parts = _dotted(fn)
        if parts:
            self._check_random_call(node, parts)
            if self.clock_file:
                self._check_clock_call(node, parts)
        self.generic_visit(node)

    def _check_clock_call(self, node, parts: List[str]) -> None:
        wall = ((len(parts) == 2 and parts[0] in self._time_aliases
                 and parts[1] == "time")
                or (len(parts) == 1 and parts[0] in self._walltime_names))
        if wall:
            self._add("monotonic-clock", node,
                      f"'{'.'.join(parts)}(...)' reads the adjustable wall "
                      f"clock — serving/obs wall-time measurement must use "
                      f"time.perf_counter(), which is monotonic (NTP can "
                      f"step time.time() backwards and produce negative "
                      f"spans); deadline math on a caller-supplied now= "
                      f"needs no clock read at all")

    def _check_random_call(self, node, parts: List[str]) -> None:
        head, tail = parts[0], parts[-1]
        if (head in self._stdlib_random_aliases and len(parts) == 2
                and tail in _STDLIB_UNSEEDED):
            self._add("unseeded-randomness", node,
                      f"stdlib '{'.'.join(parts)}(...)' draws from the "
                      f"hidden global RNG — serving determinism needs an "
                      f"explicitly seeded generator")
            return
        is_np_random = (len(parts) >= 3 and head in self._numpy_aliases
                        and parts[1] == "random")
        if not is_np_random:
            return
        if tail in _NP_UNSEEDED:
            self._add("unseeded-randomness", node,
                      f"'{'.'.join(parts)}(...)' uses numpy's legacy "
                      f"global RNG — construct a seeded "
                      f"np.random.Generator(np.random.Philox(seed)) "
                      f"instead")
        elif tail in ("default_rng", "RandomState") and not (node.args or
                                                             node.keywords):
            self._add("unseeded-randomness", node,
                      f"'{'.'.join(parts)}()' without a seed is "
                      f"entropy-seeded — pass an explicit seed")

    # ---- capacity asserts --------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        if self.serving_file:
            for sub in ast.walk(node.test):
                if (isinstance(sub, ast.Attribute)
                        and sub.attr in _RAW_CAPACITY):
                    self._add("capacity-asserts", node,
                              f"assert reasons about raw '.{sub.attr}' — "
                              f"use usable_pages/num_available: the free "
                              f"list legitimately shrinks while the prefix "
                              f"cache holds reclaimable pages, so this "
                              f"assert fires exactly when the cache works")
        self.generic_visit(node)

    def visit_If(self, node: ast.If) -> None:
        # the typed-exception conversion (PR 8) turned failure-path
        # asserts into `if <cond>: raise PoolError/AdmissionError/...` —
        # the capacity rule must follow them there, or the conversion
        # would be a lint escape hatch
        if self.serving_file and any(
                isinstance(b, ast.Raise) for b in node.body):
            for sub in ast.walk(node.test):
                if (isinstance(sub, ast.Attribute)
                        and sub.attr in _RAW_CAPACITY):
                    self._add("capacity-asserts", node,
                              f"raise-guard reasons about raw "
                              f"'.{sub.attr}' — use usable_pages/"
                              f"num_available: the free list legitimately "
                              f"shrinks while the prefix cache holds "
                              f"reclaimable pages, so this guard rejects "
                              f"exactly when the cache works")
        self.generic_visit(node)


def lint_file(path: Path, *, serving_root: Optional[Path] = None,
              clock_roots: tuple = ()) -> List[Finding]:
    """Lint one file.  ``serving_root`` scopes the capacity-asserts rule;
    ``clock_roots`` (directories) scope the monotonic-clock rule — pass
    the serving *and* obs package roots so both stay on
    ``time.perf_counter()``."""
    path = Path(path)
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(_PASS, "syntax", f"{path}:{e.lineno}",
                        f"unparseable: {e.msg}")]
    parents = path.resolve().parents
    serving_file = serving_root is not None and serving_root in parents
    clock_file = any(Path(r) in parents for r in clock_roots)
    linter = _FileLinter(path, allocator_owner=path.name == "kv_cache.py",
                         serving_file=serving_file, clock_file=clock_file)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths, *, serving_root: Optional[Path] = None,
               clock_roots: tuple = ()) -> List[Finding]:
    findings: List[Finding] = []
    for root in paths:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for p in files:
            findings.extend(lint_file(p, serving_root=serving_root,
                                      clock_roots=clock_roots))
    return findings


def _top_level_names(tree: ast.Module) -> set:
    names = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def lint_kernel_oracles(kernels_dir, tests_dir) -> List[Finding]:
    """Every kernel package (has ``kernel.py``) must keep a ``ref.py``
    oracle that some test imports — the module itself or a name defined
    in it."""
    findings: List[Finding] = []
    kernels_dir, tests_dir = Path(kernels_dir), Path(tests_dir)
    test_imports = []          # (module, names) per ImportFrom/Import
    for tf in sorted(tests_dir.glob("**/*.py")):
        try:
            tree = ast.parse(tf.read_text(), filename=str(tf))
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                test_imports.append((node.module,
                                     {a.name for a in node.names}))
            elif isinstance(node, ast.Import):
                for a in node.names:
                    test_imports.append((a.name, set()))

    for pkg in sorted(p for p in kernels_dir.iterdir()
                      if p.is_dir() and (p / "kernel.py").exists()):
        ref = pkg / "ref.py"
        where = str(pkg)
        if not ref.exists():
            findings.append(Finding(
                _PASS, "kernel-oracle", where,
                f"kernel package '{pkg.name}' has no ref.py — every Pallas "
                f"kernel needs a jnp oracle as its executable spec"))
            continue
        ref_names = _top_level_names(ast.parse(ref.read_text()))
        ref_mod = f"repro.kernels.{pkg.name}.ref"
        pkg_mod = f"repro.kernels.{pkg.name}"
        imported = any(
            mod == ref_mod or mod.startswith(ref_mod + ".")
            or (mod == pkg_mod and names & ref_names)
            for mod, names in test_imports)
        if not imported:
            findings.append(Finding(
                _PASS, "kernel-oracle", where,
                f"no test imports {ref_mod} (or a name it defines) — the "
                f"oracle is the kernel's spec and must stay under test"))
    return findings
