"""Analyzer driver: all four passes over the engine configuration matrix.

``run_all`` is what ``scripts/analyze.py`` (and therefore
``scripts/tier1.sh --analyze``) executes: the AST lint over the serving
and kernel trees, then — for each engine configuration the serving stack
actually ships (monolithic, chunked dense, flat, flat+speculative,
chunked+prefix-cache) — the shape-ladder linter, the KV-write aliasing
pass, and (with ``traffic=True``) a sanitized warm drain followed by the
recompile-hazard report and the pool-ledger audit.  One reduced model
backs every engine, so jit programs are shared and the whole matrix runs
in test time.
"""

from __future__ import annotations

from pathlib import Path
from typing import Optional

import numpy as np

from repro.analysis import aliasing, ast_lint, retrace, sanitize, shapes
from repro.analysis.report import AnalysisReport

__all__ = ["build_model", "analyze_engine", "run_all", "CONFIG_MATRIX"]

# every serving configuration family the repo ships; chunk/budget values
# are reduced-scale but exercise full ladders (chunk ladder has >1 rung,
# flat ladder has cap + sub-widths)
CONFIG_MATRIX = [
    ("monolithic", dict()),
    ("chunked-dense", dict(chunk_tokens=16, flat=False)),
    ("flat", dict(chunk_tokens=16)),
    ("flat-spec", dict(chunk_tokens=16, spec_tokens=2)),
    ("chunked-prefix", dict(chunk_tokens=16, flat=False, prefix_cache=True)),
]


def build_model(arch: str = "smollm2-135m", *, layers: int = 2,
                slots: int = 2, max_len: int = 64):
    """The reduced model + params every analyzed engine shares."""
    import jax
    from repro.configs import (RunConfig, ShapeSpec, get_config,
                               reduced_config)
    from repro.models.model import build_model as _build

    cfg = reduced_config(get_config(arch), layers=layers)
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat=False)
    model = _build(cfg, run, ShapeSpec("serve", max_len, slots, "decode"))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _traffic(engine, *, seed: int = 0) -> None:
    """A small deterministic drain exercising admission, chunking, growth,
    (pool permitting) preemption and mid-drain cancellation — mixed prompt
    lengths, shared prefix for the cache configs.  The cancel retires one
    request while another is mid-flight, so the sanitizer's retired-rid
    and zero-leak checks run against real traffic, not just unit tests."""
    rng = np.random.Generator(np.random.Philox(seed))
    shared = rng.integers(1, 50, size=12).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(1, 50, size=5).astype(np.int32)]),
        rng.integers(1, 50, size=21).astype(np.int32),
        np.concatenate([shared, rng.integers(1, 50, size=2).astype(np.int32)]),
        rng.integers(1, 50, size=3).astype(np.int32),
    ]
    budgets = [6, 5, 7, 4]
    rids = [engine.add_request(p, n) for p, n in zip(prompts, budgets)]
    engine.step(greedy=True, seed=seed)
    engine.cancel(rids[2])          # mid-drain: others must be unaffected
    engine.drain(greedy=True, seed=seed)


def analyze_engine(model, params, label: str, engine_kwargs: dict, *,
                   traffic: bool = True, trace: bool = True,
                   report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Passes 1–3 (+ sanitizer + ledger audit) on one configuration."""
    from repro.serving.engine import Engine

    report = report if report is not None else AnalysisReport()
    engine = Engine(model, params, **engine_kwargs)
    report.extend(shapes.lint_engine_shapes(engine, label, trace=trace),
                  section=f"{label}:shapes")
    report.extend(aliasing.lint_engine_aliasing(engine, label),
                  section=f"{label}:aliasing")
    if traffic:
        sanitize.install(engine)
        det = retrace.RetraceDetector(model)
        engine.warmup()
        det.mark()
        _traffic(engine)
        report.extend(det.findings(label), section=f"{label}:retrace")
        report.extend(aliasing.check_pool_consistency(engine, label),
                      section=f"{label}:pool-ledger")
    return report


def _repo_dirs():
    # repro is a namespace package (no __init__), so anchor on a module
    src_pkg = Path(__file__).resolve().parent.parent
    repo = src_pkg.parent.parent
    return (src_pkg / "serving", src_pkg / "kernels", src_pkg / "obs",
            repo / "tests")


def run_ast_lint(report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Pass 4 standalone (also reached via ``scripts/lint_invariants.py``)."""
    report = report if report is not None else AnalysisReport()
    serving_dir, kernels_dir, obs_dir, tests_dir = _repo_dirs()
    report.extend(ast_lint.lint_paths([serving_dir, kernels_dir, obs_dir],
                                      serving_root=serving_dir,
                                      clock_roots=(serving_dir, obs_dir)),
                  section="ast-lint:src")
    if tests_dir.is_dir():
        report.extend(ast_lint.lint_kernel_oracles(kernels_dir, tests_dir),
                      section="ast-lint:kernel-oracles")
    return report


def run_all(*, traffic: bool = True, trace: bool = True,
            matrix=None, log=None) -> AnalysisReport:
    report = AnalysisReport()
    run_ast_lint(report)
    model, params = build_model()
    for label, kwargs in (matrix if matrix is not None else CONFIG_MATRIX):
        if log:
            log(f"analyzing {label} ...")
        analyze_engine(model, params, label, kwargs,
                       traffic=traffic, trace=trace, report=report)
    return report
