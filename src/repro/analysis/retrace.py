"""Pass 3 — the recompile-hazard detector.

``Engine.warmup`` pre-compiles every step shape, and the standing
contract is *zero* XLA traces afterwards.  The existing regression tests
assert that boolean; this pass makes a violation actionable: the model's
``trace_log`` (see :meth:`ReproModel.trace_log`) records per-trace
argument signatures, and :class:`RetraceDetector` diffs every
post-``mark()`` trace against the closest earlier trace of the same
kind, attributing the retrace to the exact argument leaf whose shape,
dtype, or weak_type changed.  The canonical hazard it names: a python
scalar leaking into a step call — warmup traced ``pos: (), int32,
weak_type=False``; the leak retraces at ``weak_type=True``, an invisible
diff in a plain repr but a distinct jit cache key.
"""

from __future__ import annotations

from typing import List

from repro.analysis.report import Finding

__all__ = ["RetraceDetector", "diff_signatures"]

_PASS = "retrace"


def diff_signatures(before: dict, after: dict) -> List[str]:
    """Human-readable per-argument diffs between two trace signatures."""
    out = []
    for key in sorted(set(before) | set(after)):
        b, a = before.get(key), after.get(key)
        if b == a:
            continue
        if b is None:
            out.append(f"{key}: absent -> {a}")
        elif a is None:
            out.append(f"{key}: {b} -> absent")
        else:
            fields = ("shape", "dtype", "weak_type")
            parts = [f"{fn} {bv!r} -> {av!r}"
                     for fn, bv, av in zip(fields, b, a) if bv != av]
            out.append(f"{key}: " + ", ".join(parts))
    return out


class RetraceDetector:
    """Watch a model's jitted steps for post-warmup retraces.

    Usage::

        det = RetraceDetector(model)
        engine.warmup()
        det.mark()          # everything traced so far is legitimate
        ... traffic ...
        findings = det.findings()   # [] unless something retraced
    """

    def __init__(self, model):
        self.model = model
        self._mark = len(model.trace_log)

    def mark(self) -> None:
        self._mark = len(self.model.trace_log)

    def retraces(self) -> List[dict]:
        return self.model.trace_log[self._mark:]

    def findings(self, label: str = "model") -> List[Finding]:
        log = self.model.trace_log
        out: List[Finding] = []
        for i in range(self._mark, len(log)):
            entry = log[i]
            prior = [e for e in log[:i] if e["kind"] == entry["kind"]]
            where = f"{label} jit_step({entry['kind']!r})"
            if not prior:
                out.append(Finding(
                    _PASS, "unwarmed-kind", where,
                    f"first-ever trace of kind {entry['kind']!r} happened "
                    f"after warmup — this step family was never warmed"))
                continue
            # attribute against the *closest* prior signature: the one
            # with the fewest differing leaves is the cache entry this
            # call just missed
            diffs = [(diff_signatures(p["args"], entry["args"]), p)
                     for p in prior]
            diffs.sort(key=lambda d: len(d[0]))
            best, _ = diffs[0]
            out.append(Finding(
                _PASS, "post-warmup-trace", where,
                f"XLA retrace after warmup; closest warmed signature "
                f"differs in {len(best)} leaf/leaves: "
                + "; ".join(best[:4])
                + ("; ..." if len(best) > 4 else ""),
                detail={"kind": entry["kind"], "diff": best}))
        return out
