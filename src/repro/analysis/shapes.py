"""Pass 1 — the shape-ladder linter.

The paper's codegen argument needs a *finite, m_r-aligned, geometric*
set of step shapes: prepacking is amortized only if tile geometry never
changes, and the zero-retrace contract holds only if every runtime shape
is a member of the warmed ladder.  This pass checks that contract twice:

* **Ladder algebra** (no tracing): re-derive each declared ladder from
  the scheduler contract — chunk ladder = ``chunk_tokens`` halved to
  ``m_r``; flat ladder = ``m_r``-aligned budget cap plus the powers of
  two of ``m_r`` below it; monolithic prefill buckets = geometric
  ``m_r``-multiples — and diff it against what the engine actually
  computes (`_chunk_shapes`/`_flat_shapes`/`_prefill_bucket`).  A
  drifted implementation (e.g. a mis-aligned ``chunk_tokens`` hacked in
  after construction) is caught here with the exact offending width.

* **Jaxpr audit** (`jax.make_jaxpr` on the real step functions with
  ``ShapeDtypeStruct`` stand-ins, one trace per step family × ladder
  shape, mirroring ``Engine.warmup``'s enumeration): every aval dim of
  every eqn — including inside ``scan``/``pjit`` sub-jaxprs — must be a
  concrete Python int.  A data-dependent or symbolic dim anywhere in a
  compiled step family breaks the fixed-grid argument; the finding names
  the eqn's primitive and user call site.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_tools import eqn_where, iter_eqns
from repro.analysis.report import Finding
from repro.core.layout import round_up
from repro.serving.kv_cache import fresh_slot_states, prefill_view

__all__ = ["step_families", "lint_engine_shapes", "check_static_dims"]

_PASS = "shape-ladder"


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def step_families(engine) -> List[Tuple[str, object, tuple]]:
    """Every compiled step family × ladder shape this engine can hit,
    as ``(label, step_fn, abstract_args)`` — the same enumeration
    ``Engine.warmup`` compiles, but with ``ShapeDtypeStruct`` stand-ins
    so the linter traces without touching device state."""
    model = engine.model
    params = _sds(engine.params)
    caches = _sds(engine.caches)
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    b, mp = engine.slots, engine.max_pages
    fams = []
    if engine.flat:
        k1s = [1] + ([engine.spec_tokens + 1]
                     if engine.spec_tokens is not None else [])
        for w in engine._flat_shapes():
            for k1 in k1s:
                fams.append((f"flat[1,{w}]/k{k1}", model.flat_decode_step,
                             (params, caches, S((1, w), i32), S((b, mp), i32),
                              S((w,), i32), S((w,), i32), S((b * k1,), i32))))
        return fams
    if engine.chunked:
        for s in engine._chunk_shapes() + [1]:
            fams.append((f"chunk[{b},{s}]", model.paged_decode_step,
                         (params, caches, S((b, s), i32), S((b, mp), i32),
                          S((b,), i32), S((b,), i32), None)))
        if engine.spec_tokens is not None:
            for s in engine._chunk_shapes():
                fams.append((f"chunk[{b},{s}]/verify", model.paged_decode_step,
                             (params, caches, S((b, s), i32), S((b, mp), i32),
                              S((b,), i32), S((b,), i32),
                              S((b, engine.spec_tokens + 1), i32))))
        return fams
    # monolithic: geometric prefill buckets (batch-1 slot view) + decode
    if engine._bucket > 1:
        view = _sds(prefill_view(engine.caches,
                                 fresh_slot_states(engine.caches)))
        l, seen = engine._bucket, set()
        while True:
            bucket = engine._prefill_bucket(l)
            if bucket in seen:
                break
            seen.add(bucket)
            fams.append((f"prefill[1,{bucket}]", model.paged_decode_step,
                         (params, view, S((1, bucket), i32), S((1, mp), i32),
                          S((1,), i32), S((1,), i32), None)))
            l = bucket + 1
    fams.append((f"decode[{b},1]", model.paged_decode_step,
                 (params, caches, S((b, 1), i32), S((b, mp), i32),
                  S((b,), i32), S((b,), i32), None)))
    if engine.spec_tokens is not None:
        k1 = engine.spec_tokens + 1
        fams.append((f"verify[{b},{k1}]", model.paged_decode_step,
                     (params, caches, S((b, k1), i32), S((b, mp), i32),
                      S((b,), i32), S((b,), i32), S((b, k1), i32))))
    return fams


def check_static_dims(closed, family: str) -> List[Finding]:
    """Assert every aval dim in the jaxpr (sub-jaxprs included) is a
    concrete int — no data-dependent / symbolic shapes in a step family."""
    findings = []
    for path, eqn in iter_eqns(closed):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", ())
            for d in shape:
                if not isinstance(d, (int, np.integer)):
                    findings.append(Finding(
                        _PASS, "static-dims",
                        f"{eqn.primitive.name} @ {eqn_where(eqn)}",
                        f"{family}: non-static dim {d!r} in shape "
                        f"{tuple(shape)} (jaxpr path {path}) — every "
                        f"compiled step shape must be a concrete int or "
                        f"the fixed-grid/zero-retrace contract is void"))
    return findings


def _declared_flat_ladder(engine) -> set:
    cap = round_up(max(engine.token_budget,
                       engine.slots * ((engine.spec_tokens or 0) + 1)),
                   engine._bucket)
    ladder = {cap}
    v = engine._bucket
    while v < cap:
        ladder.add(v)
        v *= 2
    return ladder


def _declared_chunk_ladder(engine) -> set:
    m_r = engine._bucket
    ladder, c = {engine.chunk_tokens}, engine.chunk_tokens
    while c % 2 == 0 and c // 2 >= m_r and (c // 2) % m_r == 0:
        c //= 2
        ladder.add(c)
    return ladder


def lint_engine_shapes(engine, label: str = "engine", *,
                       trace: bool = True,
                       max_traces: Optional[int] = None) -> List[Finding]:
    """Run pass 1 on one engine configuration.  ``trace=False`` skips the
    jaxpr audit (ladder algebra only — cheap enough for every test)."""
    f: List[Finding] = []
    m_r = engine._bucket
    here = f"{label} ({engine.model.cfg.name})"

    if engine.pool.page_tokens % max(m_r, 1) != 0:
        f.append(Finding(_PASS, "page-align", here,
                         f"page_tokens={engine.pool.page_tokens} is not a "
                         f"multiple of m_r={m_r} — pages must be whole "
                         f"microkernel tiles"))
    if engine.chunked:
        if engine.chunk_tokens % m_r != 0:
            f.append(Finding(_PASS, "chunk-align", here,
                             f"chunk_tokens={engine.chunk_tokens} is not "
                             f"m_r-aligned (m_r={m_r}) — chunk writes "
                             f"would straddle tiles",
                             detail={"chunk_tokens": engine.chunk_tokens,
                                     "m_r": m_r}))
        if engine.token_budget < m_r:
            f.append(Finding(_PASS, "budget-liveness", here,
                             f"token_budget={engine.token_budget} < m_r="
                             f"{m_r}: plan_chunks rounds grants down to "
                             f"the tile, so prefill could never advance"))
        declared = _declared_chunk_ladder(engine)
        actual = set(engine._chunk_shapes())
        for c in sorted(actual):
            if c % m_r != 0:
                f.append(Finding(_PASS, "chunk-align", here,
                                 f"ladder shape {c} is not m_r-aligned "
                                 f"(m_r={m_r})",
                                 detail={"shape": c, "m_r": m_r}))
        if actual != declared and engine.chunk_tokens % m_r == 0:
            f.append(Finding(_PASS, "chunk-ladder", here,
                             f"chunk ladder {sorted(actual)} != declared "
                             f"geometric ladder {sorted(declared)}"))
        if (engine.spec_tokens is not None
                and engine.chunk_tokens < engine.spec_tokens + 1):
            f.append(Finding(_PASS, "verify-width", here,
                             f"chunk_tokens={engine.chunk_tokens} cannot "
                             f"hold the [{engine.spec_tokens + 1}]-wide "
                             f"verify row"))
    if engine.flat:
        declared = _declared_flat_ladder(engine)
        actual = set(engine._flat_shapes())
        for w in sorted(actual):
            if w % m_r != 0:
                f.append(Finding(_PASS, "flat-align", here,
                                 f"flat width {w} is not m_r-aligned "
                                 f"(m_r={m_r}) — tile writes would be "
                                 f"partial",
                                 detail={"width": w, "m_r": m_r}))
        if actual != declared:
            f.append(Finding(_PASS, "flat-ladder", here,
                             f"flat ladder {sorted(actual)} != declared "
                             f"{sorted(declared)}"))
        for n in {1, m_r, m_r + 1, max(declared), engine.token_budget}:
            if n < 1 or n > max(declared):
                continue
            w = engine._flat_shape(n)
            fits = sorted(x for x in declared if x >= n)
            if w not in declared or w < n or (fits and w != fits[0]):
                f.append(Finding(_PASS, "flat-pick", here,
                                 f"_flat_shape({n}) = {w}, expected the "
                                 f"smallest ladder member >= {n} "
                                 f"({fits[0] if fits else '??'})"))
    if not engine.chunked and m_r > 1:
        cap = round_up(engine.scheduler.max_len, m_r)
        l, seen = m_r, set()
        while True:
            b = engine._prefill_bucket(l)
            if b in seen:
                break
            seen.add(b)
            ok_geo = b == cap or (b % m_r == 0
                                  and (b // m_r & (b // m_r - 1)) == 0)
            if b < l or b > cap or not ok_geo:
                f.append(Finding(_PASS, "prefill-bucket", here,
                                 f"_prefill_bucket({l}) = {b}: must be a "
                                 f"geometric m_r-multiple in [{l}, {cap}]"))
            l = b + 1

    if trace:
        fams = step_families(engine)
        if max_traces is not None:
            fams = fams[:max_traces]
        for fam, fn, abstract_args in fams:
            closed = jax.make_jaxpr(fn)(*abstract_args)
            f.extend(check_static_dims(closed, f"{here} {fam}"))
    return f
