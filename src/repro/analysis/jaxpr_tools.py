"""Jaxpr walking primitives shared by the analysis passes.

Two capabilities, both pure functions of a ``ClosedJaxpr``:

* :func:`iter_eqns` — depth-first traversal of every eqn including those
  inside sub-jaxprs (``pjit``, ``scan``, ``cond`` branches, remat, custom
  derivatives), with a ``path`` string locating each eqn.  The shape
  linter uses it to assert every aval dim is a concrete int.

* :class:`TaintWalker` — forward label propagation ("taint") with a mini
  constant folder.  Seed the top-level invars with role labels (e.g.
  ``pages``, ``block_tables``, ``validity``) and the walker pushes the
  union of input labels onto every eqn's outputs, recursing into
  sub-jaxprs by zipping outer operands onto inner invars.  Two special
  rules carry the serving stack's aliasing contract:

  - ``select_n`` whose predicate is validity-derived and one of whose
    cases is a constant zero gets the extra label ``trash0`` — that is
    the lowered form of ``jnp.where(valid, page, 0)``, the trash-page
    guard.  The zero reaches the select as a bare ``Literal 0`` operand
    of the ``_where`` pjit and then flows through
    ``convert_element_type``/``broadcast_in_dim``, which is why the
    walker needs the constant folder, not just literal inspection at the
    select.
  - every ``scatter*`` / ``dynamic_update_slice`` eqn is recorded as a
    :class:`WriteSite` with the labels of its operand, indices and
    updates plus its gather/scatter mode — the KV-aliasing pass then
    asserts each pool write is indexed by ``{block_tables, trash0}``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Set

import numpy as np

__all__ = ["WriteSite", "TaintWalker", "iter_eqns", "eqn_where",
           "unwrap_jaxpr"]

# roles whose presence in a select_n predicate marks it as the trash guard
VALIDITY_ROLES = frozenset({"validity"})
TRASH_LABEL = "trash0"

# shape-preserving-ish prims through which a known constant keeps its value
# (zero-ness is all we care about, so broadcasts are value-preserving too)
_CONST_TRANSPARENT = frozenset({
    "convert_element_type", "broadcast_in_dim", "reshape", "copy",
    "squeeze", "expand_dims", "stop_gradient",
})
_MAX_CONST_SIZE = 256   # don't drag big arrays through the const env


def unwrap_jaxpr(j):
    """ClosedJaxpr-or-Jaxpr -> (Jaxpr, consts)."""
    inner = getattr(j, "jaxpr", j)
    consts = list(getattr(j, "consts", ()) or ())
    return inner, consts


def _sub_jaxprs(eqn):
    """All jaxpr-valued params of an eqn, as (param_name, jaxpr_like)."""
    out = []
    for name, val in eqn.params.items():
        if hasattr(val, "eqns") or hasattr(val, "jaxpr"):
            out.append((name, val))
        elif isinstance(val, (tuple, list)):
            for i, v in enumerate(val):
                if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                    out.append((f"{name}[{i}]", v))
    return out


def eqn_where(eqn) -> str:
    """Best-effort user-code call site of an eqn, as ``file:line``."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        pass
    return "<unknown call site>"


def iter_eqns(closed, path: str = "top"):
    """Yield ``(path, eqn)`` for every eqn, recursing into sub-jaxprs."""
    inner, _ = unwrap_jaxpr(closed)
    for eqn in inner.eqns:
        yield path, eqn
        for pname, sub in _sub_jaxprs(eqn):
            sub_path = f"{path}/{eqn.primitive.name}" \
                       + (f".{pname}" if pname not in ("jaxpr", "call_jaxpr")
                          else "")
            yield from iter_eqns(sub, sub_path)


@dataclasses.dataclass
class WriteSite:
    """One in-place write eqn (scatter / dynamic_update_slice) seen by the
    taint walker, with the provenance labels of each operand group."""

    prim: str
    path: str
    where: str
    operand_labels: Set[str]
    index_labels: Set[str]
    update_labels: Set[str]
    mode: Optional[str]

    def writes(self, label: str) -> bool:
        return label in self.operand_labels


def _is_literal(v) -> bool:
    return hasattr(v, "val")        # core.Literal; Vars have no .val


def _const_of(v, cenv):
    if _is_literal(v):
        try:
            a = np.asarray(v.val)
            return a if a.size <= _MAX_CONST_SIZE else None
        except Exception:
            return None
    return cenv.get(v)


def _all_zero(a) -> bool:
    return a is not None and bool((np.asarray(a) == 0).all())


class TaintWalker:
    """Forward label propagation over a closed jaxpr (see module doc)."""

    def __init__(self, validity_roles=VALIDITY_ROLES):
        self.validity_roles = frozenset(validity_roles)
        self.write_sites: List[WriteSite] = []
        self.out_labels: List[Set[str]] = []   # labels of top-level outvars

    # -- env helpers ---------------------------------------------------
    @staticmethod
    def _labels(v, env) -> Set[str]:
        if _is_literal(v):
            return set()
        return env.get(v, set())

    def run(self, closed, arg_labels: List[Optional[Set[str]]]):
        """``arg_labels`` aligns with the top-level flat invars."""
        inner, consts = unwrap_jaxpr(closed)
        if len(arg_labels) != len(inner.invars):
            raise ValueError(
                f"taint walk: {len(arg_labels)} labels for "
                f"{len(inner.invars)} invars")
        env, cenv = {}, {}
        for cv, cval in zip(inner.constvars, consts):
            env[cv] = set()
            self._seed_const(cenv, cv, cval)
        for v, lab in zip(inner.invars, arg_labels):
            env[v] = set(lab or ())
        self._walk(inner, env, cenv, "top")
        self.out_labels = [self._labels(ov, env) for ov in inner.outvars]
        return self

    @staticmethod
    def _seed_const(cenv, var, val):
        try:
            a = np.asarray(val)
            if a.size <= _MAX_CONST_SIZE:
                cenv[var] = a
        except Exception:
            pass

    # -- recursion -----------------------------------------------------
    def _recurse(self, sub, in_info, path):
        """in_info: list of (labels, const) aligned with sub's invars.
        Returns (labels, const) per sub outvar."""
        inner, consts = unwrap_jaxpr(sub)
        env, cenv = {}, {}
        for cv, cval in zip(inner.constvars, consts):
            env[cv] = set()
            self._seed_const(cenv, cv, cval)
        for iv, (lab, const) in zip(inner.invars, in_info):
            env[iv] = set(lab or ())
            if const is not None:
                cenv[iv] = const
        self._walk(inner, env, cenv, path)
        return [(self._labels(ov, env), _const_of(ov, cenv))
                for ov in inner.outvars]

    def _walk(self, jaxpr, env, cenv, path):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            in_info = [(self._labels(v, env), _const_of(v, cenv))
                       for v in eqn.invars]
            union: Set[str] = set()
            for lab, _ in in_info:
                union |= lab

            subs = _sub_jaxprs(eqn)
            if prim == "cond" and subs:
                # invars = [pred, *operands]; every branch sees the operands
                out = None
                for pname, br in subs:
                    r = self._recurse(br, in_info[1:], f"{path}/cond.{pname}")
                    if out is None:
                        out = [(set(lab), None) for lab, _ in r]
                    else:
                        for acc, (lab, _) in zip(out, r):
                            acc[0].update(lab)
                pred_labels = in_info[0][0]
                for ov, (lab, _) in zip(eqn.outvars, out or []):
                    env[ov] = lab | pred_labels
                continue

            if subs and len(subs) == 1:
                inner, _ = unwrap_jaxpr(subs[0][1])
                if len(inner.invars) == len(eqn.invars):
                    # pjit / scan / remat / custom_*: positional 1:1 zip of
                    # outer operands onto inner invars and back for outvars
                    r = self._recurse(subs[0][1], in_info, f"{path}/{prim}")
                    if len(r) == len(eqn.outvars):
                        for ov, (lab, const) in zip(eqn.outvars, r):
                            env[ov] = lab
                            if const is not None:
                                cenv[ov] = const
                        continue
            if subs:
                # unknown higher-order prim (while, ...): conservative —
                # every output tainted by every input; no const, no recurse
                # (a pool write hidden here would surface as a missing
                # write site, which the aliasing pass reports)
                for ov in eqn.outvars:
                    env[ov] = set(union)
                continue

            # ---- first-order prims ----
            out_labels = set(union)
            out_const = None

            if prim in _CONST_TRANSPARENT and in_info:
                out_const = in_info[0][1]
            elif prim == "select_n" and len(eqn.invars) >= 3:
                pred_labels = in_info[0][0]
                case_consts = [c for _, c in in_info[1:]]
                if (pred_labels & self.validity_roles
                        and any(_all_zero(c) for c in case_consts)):
                    out_labels.add(TRASH_LABEL)
            elif prim.startswith("scatter"):
                operand_l, idx_l, upd_l = (in_info[0][0],
                                           in_info[1][0],
                                           in_info[2][0] if len(in_info) > 2
                                           else set())
                self.write_sites.append(WriteSite(
                    prim=prim, path=path, where=eqn_where(eqn),
                    operand_labels=operand_l, index_labels=idx_l,
                    update_labels=upd_l,
                    mode=str(eqn.params.get("mode", ""))))
            elif prim == "dynamic_update_slice":
                operand_l, upd_l = in_info[0][0], in_info[1][0]
                idx_l = set()
                for lab, _ in in_info[2:]:
                    idx_l |= lab
                self.write_sites.append(WriteSite(
                    prim=prim, path=path, where=eqn_where(eqn),
                    operand_labels=operand_l, index_labels=idx_l,
                    update_labels=upd_l, mode=None))

            for ov in eqn.outvars:
                env[ov] = out_labels
                if out_const is not None:
                    cenv[ov] = out_const
