"""Findings and reports for the layout-contract analyzer.

Every pass — the shape-ladder linter, the KV-write aliasing pass, the
recompile-hazard detector, the AST invariant lint, and the runtime
sanitizer — speaks one currency: a :class:`Finding` naming the pass, the
rule that fired, *where* (an eqn + call site, a ``file:line``, or an
engine attribute), and a message precise enough to act on.  A pass that
returns no findings is **green**; ``scripts/analyze.py`` exits non-zero
on any finding, which is what lets ``tier1.sh --analyze`` gate a PR on
the serving stack's standing invariants instead of on example-based
tests alone.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

__all__ = ["Finding", "AnalysisReport"]


@dataclasses.dataclass
class Finding:
    """One contract violation.

    ``pass_name``: which analyzer produced it (``shape-ladder``,
    ``kv-aliasing``, ``retrace``, ``ast-lint``, ``sanitize``,
    ``pool-ledger``).  ``rule``: the specific invariant within the pass.
    ``where``: the most precise location available — a ``file:line`` for
    AST findings, ``primitive @ file:line`` for jaxpr eqns, an engine/
    config label otherwise.  ``detail`` carries the evidence (the shape
    that missed the ladder, the argument that forced a retrace, ...).
    """

    pass_name: str
    rule: str
    where: str
    message: str
    detail: Optional[dict] = None

    def format(self) -> str:
        s = f"[{self.pass_name}/{self.rule}] {self.where}: {self.message}"
        if self.detail:
            kv = ", ".join(f"{k}={v!r}" for k, v in sorted(self.detail.items()))
            s += f"  ({kv})"
        return s


class AnalysisReport:
    """An ordered collection of findings across passes and configs."""

    def __init__(self):
        self.findings: List[Finding] = []
        self.sections: List[str] = []     # labels of everything analyzed,
                                          # green or not (coverage record)

    @property
    def ok(self) -> bool:
        return not self.findings

    def extend(self, findings, section: Optional[str] = None) -> None:
        self.findings.extend(findings)
        if section is not None:
            self.sections.append(section)

    def format(self) -> str:
        lines = [f"analyzed: {', '.join(self.sections) or '(nothing)'}"]
        if self.ok:
            lines.append("OK — no findings")
        else:
            lines.append(f"{len(self.findings)} finding(s):")
            lines += ["  " + f.format() for f in self.findings]
        return "\n".join(lines)
