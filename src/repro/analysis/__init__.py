"""Layout-contract analyzer for the serving stack (the verifier layer).

The compiled serving stack rests on contracts MLIR-style codegen would
check in its type system and verifier passes; this package is the
equivalent for the jaxpr/engine stack.  Four passes plus a runtime mode:

1. ``shapes``    — shape-ladder linter (m_r alignment, geometric ladder
                   membership, static dims in every step-family jaxpr);
2. ``aliasing``  — KV-write aliasing pass (every pool write addressed
                   through the block-table gather with the trash-page
                   route) + the dynamic refcount-ledger audit;
3. ``retrace``   — recompile-hazard detector (attributes any post-warmup
                   XLA trace to the argument leaf that caused it);
4. ``ast_lint``  — AST invariant lint (allocator privacy, capacity
                   asserts, unseeded randomness, kernel oracles).

``sanitize`` wires the dynamic halves of 1–2 onto the pool write path at
runtime (``REPRO_SANITIZE=1``).  ``runner.run_all`` drives everything
over the shipped engine-configuration matrix; ``scripts/analyze.py`` /
``scripts/tier1.sh --analyze`` is the CI entry point.
"""

from repro.analysis.aliasing import (check_pool_consistency,
                                     lint_engine_aliasing)
from repro.analysis.ast_lint import (lint_file, lint_kernel_oracles,
                                     lint_paths)
from repro.analysis.report import AnalysisReport, Finding
from repro.analysis.retrace import RetraceDetector
from repro.analysis.runner import analyze_engine, run_all, run_ast_lint
from repro.analysis.sanitize import SanitizerError, StepSanitizer, install
from repro.analysis.shapes import lint_engine_shapes, step_families

__all__ = [
    "AnalysisReport", "Finding",
    "lint_engine_shapes", "step_families",
    "lint_engine_aliasing", "check_pool_consistency",
    "RetraceDetector",
    "lint_paths", "lint_file", "lint_kernel_oracles",
    "SanitizerError", "StepSanitizer", "install",
    "analyze_engine", "run_all", "run_ast_lint",
]
