"""Deterministic, shard-aware, resumable synthetic data pipeline.

Batches are a pure function of (seed, step, shard): a Philox counter-based
generator keyed on those values.  Resumability is therefore trivial — the
only pipeline state is the step counter already stored in the train state —
and every data-parallel rank can generate exactly its own shard without any
coordination (the property a 1000-node input pipeline needs).

The token stream is not uniform noise: a small hash-chain Markov structure
makes next-token prediction learnable, so smoke-training shows a decreasing
loss (examples/train_smollm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec

__all__ = ["SyntheticLM"]


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    shape: ShapeSpec
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    text_len: Optional[int] = None   # vlm: tokens after the vision prefix

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=[np.uint64(self.seed), np.uint64((step << 20) + self.shard_index)]))

    def batch_at(self, step: int) -> dict:
        b = self.shape.global_batch // self.shard_count
        s = self.text_len if self.text_len is not None else self.shape.seq_len
        rng = self._rng(step)
        vocab = self.cfg.vocab
        # learnable structure: tok_{t+1} = (a * tok_t + b) mod V with noise
        a = 31337 % vocab
        start = rng.integers(0, vocab, size=(b, 1), dtype=np.int64)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = start[:, 0]
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, vocab, size=(b, s), dtype=np.int64)
        for t in range(s):
            nxt = (toks[:, t] * 7 + a) % vocab
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        d = self.cfg.d_model
        if self.cfg.family == "encdec":
            enc = self.shape.seq_len // self.cfg.audio_downsample
            batch["frames"] = rng.standard_normal((b, enc, d)).astype(np.float32)
        if self.cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (b, self.cfg.vision_tokens, d)).astype(np.float32)
        return batch
