"""RWKV-6 ("Finch") mixer: attention-free recurrence with data-dependent decay.

Time-mix: per-head matrix-valued state ``S ∈ R^{dh x dh}`` updated as
    S_t = diag(w_t) S_t-1 + k_t v_t^T,    y_t = (S_t-1 + diag(u) k_t v_t^T)^T r_t
with the *data-dependent* per-channel decay ``w_t = exp(-exp(w0 + lora(x)))``
— the Finch hallmark.  Token-shift mixing uses static per-channel lerp
coefficients (the RWKV-5-style simplification; the data-dependent part kept
is the decay, which is what makes RWKV-6 RWKV-6 — noted in DESIGN.md).

All projections are packed-layout matmuls; the recurrence itself is a
chunked ``lax.scan`` (checkpointed per chunk to bound activation memory) —
O(1) state per decoded token, which is why this arch runs the long_500k cell.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.models.common import Stream, maybe_unpack

Array = jnp.ndarray

__all__ = ["rwkv_tm_init", "rwkv_tm_apply", "rwkv_cm_init", "rwkv_cm_apply",
           "init_rwkv_cache"]

_DECAY_LORA = 64
_CHUNK = 128


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    dh = cfg.rwkv_head_dim
    return cfg.d_model // dh, dh


def rwkv_tm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 8)
    lin = lambda k_, o, sc=None: linear_init(k_, d, o, dtype=dtype, scale=sc)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,g,w shift-mix coeffs
        "wr": lin(ks[0], d), "wk": lin(ks[1], d), "wv": lin(ks[2], d),
        "wg": lin(ks[3], d),
        "wo": lin(ks[4], d, d ** -0.5 / max(1, cfg.n_layers) ** 0.5),
        "w0": -6.0 + jnp.zeros((d,), jnp.float32),
        "w_a": (jax.random.normal(ks[5], (d, _DECAY_LORA), jnp.float32) * 0.01),
        "w_b": (jax.random.normal(ks[6], (_DECAY_LORA, d), jnp.float32) * 0.01),
        "u": jnp.zeros((h, dh), jnp.float32),
        "ln_g": jnp.ones((d,), jnp.float32),  # per-head group-norm gain
    }


def rwkv_cm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),  # r,k
        "wr": linear_init(ks[0], d, d, dtype=dtype),
        "wk": linear_init(ks[1], d, f, dtype=dtype),
        "wv": linear_init(ks[2], f, d, dtype=dtype,
                          scale=f ** -0.5 / max(1, cfg.n_layers) ** 0.5),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    return {"tm_shift": jnp.zeros((batch, d), dtype),
            "cm_shift": jnp.zeros((batch, d), dtype),
            "state": jnp.zeros((batch, h, dh, dh), jnp.float32)}


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x_{t-1} along the sequence; first step uses ``prev`` (decode state)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Chunked recurrence.  r,k,v,w: [B,S,H,dh] (fp32); s0: [B,H,dh,dh].

    Returns (y [B,S,H,dh], s_final).
    """
    b, s, h, dh = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp            # [B,H,dh]
        a_t = k_t[..., :, None] * v_t[..., None, :]          # [B,H,dh,dh]
        y_t = jnp.einsum("bhij,bhi->bhj", state + u[..., None] * a_t, r_t)
        state = w_t[..., None] * state + a_t
        return state, y_t

    def chunk_body(state, xs):
        return jax.checkpoint(
            lambda st, x_: jax.lax.scan(step, st, x_))(state, xs)

    n_chunks = max(1, s // _CHUNK)
    if s % _CHUNK == 0 and n_chunks > 1:
        xs = tuple(a.transpose(1, 0, 2, 3).reshape(n_chunks, _CHUNK, b, h, dh)
                   for a in (r, k, v, w))
        state, ys = jax.lax.scan(chunk_body, s0, xs)
        y = ys.reshape(s, b, h, dh).transpose(1, 0, 2, 3)
    else:
        xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
        state, ys = jax.lax.scan(step, s0, xs)
        y = ys.transpose(1, 0, 2, 3)
    return y, state


def rwkv_tm_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig, *,
                  cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    xu = maybe_unpack(x)
    b, s, d = xu.shape
    h, dh = _heads(cfg)

    prev = None if cache is None else cache["tm_shift"]
    xs = _token_shift(xu, prev)
    mu = params["mu"].astype(xu.dtype)
    mix = lambda i: xu + mu[i] * (xs - xu)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))

    r = linear_apply(params["wr"], xr, ctx, tp="col").reshape(b, s, h, dh)
    k = linear_apply(params["wk"], xk, ctx, tp="col").reshape(b, s, h, dh)
    v = linear_apply(params["wv"], xv, ctx, tp="col").reshape(b, s, h, dh)
    g = jax.nn.silu(linear_apply(params["wg"], xg, ctx, tp="col"))

    lora = jnp.tanh(xw.astype(jnp.float32) @ params["w_a"]) @ params["w_b"]
    w = jnp.exp(-jnp.exp(params["w0"] + lora)).reshape(b, s, h, dh)

    s0 = (jnp.zeros((b, h, dh, dh), jnp.float32) if cache is None
          else cache["state"])
    y, s_final = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w, params["u"], s0)

    # per-head group norm, then gate
    mean = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = (y.reshape(b, s, d) * params["ln_g"]).astype(xu.dtype) * g
    out = linear_apply(params["wo"], y, ctx, tp="row")

    new_cache = None
    if cache is not None:
        new_cache = {"tm_shift": xu[:, -1].astype(cache["tm_shift"].dtype),
                     "state": s_final}
    return out, new_cache


def rwkv_cm_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig, *,
                  cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    xu = maybe_unpack(x)
    prev = None if cache is None else cache["cm_shift"]
    xs = _token_shift(xu, prev)
    mu = params["mu"].astype(xu.dtype)
    xr = xu + mu[0] * (xs - xu)
    xk = xu + mu[1] * (xs - xu)
    k = linear_apply(params["wk"], xk, ctx, activation=jax.nn.relu, tp="col")
    k = k * k
    out = jax.nn.sigmoid(linear_apply(params["wr"], xr, ctx)) * \
        linear_apply(params["wv"], k, ctx, tp="row")
    new_cache = None
    if cache is not None:
        new_cache = {"cm_shift": xu[:, -1].astype(cache["cm_shift"].dtype)}
    return out, new_cache
