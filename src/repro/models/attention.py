"""Grouped-query attention with packed-layout projections.

The QKV/O *weight* matmuls run through the packed-layout pipeline (the
paper's scope); the score/context matmuls (`QKᵀ`, `PV`) are
activation-by-activation contractions left to native XLA einsum — the same
boundary the paper draws (DESIGN.md §4).

Supports: GQA/MQA/MHA, qk-norm (qwen3), QKV bias (qwen2/chatglm), partial 2d
RoPE (chatglm), bidirectional (whisper encoder), cross-attention (whisper
decoder), KV-cache decode with a sequence-shardable cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.models.common import Stream, apply_rope, maybe_unpack, norm_apply, norm_init

Array = jnp.ndarray

__all__ = ["attn_init", "attn_apply", "init_kv_cache", "init_paged_kv_cache",
           "core_attention", "paged_kv_update", "flat_paged_kv_update"]


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32, *, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    bias = cfg.attn_bias
    p = {
        "wq": linear_init(ks[0], d, hq * dh, bias=bias, dtype=dtype),
        "wk": linear_init(ks[1], d, hkv * dh, bias=bias, dtype=dtype),
        "wv": linear_init(ks[2], d, hkv * dh, bias=bias, dtype=dtype),
        "wo": linear_init(ks[3], hq * dh, d, dtype=dtype,
                          scale=(hq * dh) ** -0.5 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init("rmsnorm", dh, dtype)
        p["k_norm"] = norm_init("rmsnorm", dh, dtype)
    del cross
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def init_paged_kv_cache(cfg: ModelConfig, num_pages: int, page_tokens: int,
                        dtype) -> dict:
    """Paged pool: KV lives in ``num_pages`` pages of ``page_tokens`` tokens,
    shared by all sequences via per-request block tables (continuous
    batching).  Page 0 is reserved as the trash page — writes for padded /
    inactive positions are routed there so they can never corrupt a live
    request."""
    shp = (num_pages, page_tokens, cfg.n_kv_heads, cfg.d_head)
    return {"k_pages": jnp.zeros(shp, dtype), "v_pages": jnp.zeros(shp, dtype)}


def paged_kv_update(cache: dict, k: Array, v: Array, *, block_tables: Array,
                    lens: Array, new_counts: Array):
    """Scatter this step's K/V into the page pool, gather each row's logical
    KV stream back out.

    cache: {"k_pages","v_pages"} [P, T, Hkv, dh] — the pool (page 0 = trash).
    k, v: [B, S, Hkv, dh] new keys/values; row b's token s sits at logical
    position ``lens[b] + s`` and is valid iff ``s < new_counts[b]`` (prefill
    rows are padded up to a layout-aligned bucket; invalid writes go to the
    trash page).  Rows are fully ragged: one fused step may mix decode rows
    (``new_counts == 1``), chunked-prefill rows (a ``chunk``-token slice of
    a prompt at ``lens[b] = cursor``), and inert rows (``new_counts == 0``)
    — the engine's single fixed-shape step under a token budget, and the
    verify-step shape for speculative decode.
    block_tables: [B, MP] page ids per row, in logical order.
    Returns (new_cache, k_all [B, MP*T, Hkv, dh], v_all, kv_len_mask [B, MP*T]).

    The gathered stream is masked to ``lens + new_counts`` positions, and
    ``core_attention``'s per-row 2-D ``q_pos`` gives causality *within* the
    freshly-written chunk against the paged past — query ``lens[b]+s``
    sees kv positions ``<= lens[b]+s`` only, so chunked prefill logits are
    bitwise those of a monolithic prefill at the same positions.
    """
    kp, vp = cache["k_pages"], cache["v_pages"]
    t = kp.shape[1]
    b, s = k.shape[0], k.shape[1]
    pos = lens[:, None] + jnp.arange(s, dtype=jnp.int32)        # [B,S]
    valid = jnp.arange(s)[None, :] < new_counts[:, None]
    slot = jnp.minimum(pos // t, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, slot, axis=1)
    page = jnp.where(valid, page, 0)
    off = jnp.where(valid, pos % t, 0)
    kp = kp.at[page, off].set(k.astype(kp.dtype))
    vp = vp.at[page, off].set(v.astype(vp.dtype))
    k_all = kp[block_tables].reshape(b, -1, *kp.shape[2:])
    v_all = vp[block_tables].reshape(b, -1, *vp.shape[2:])
    mask = jnp.arange(k_all.shape[1])[None, :] < (lens + new_counts)[:, None]
    return {"k_pages": kp, "v_pages": vp}, k_all, v_all, mask


def flat_paged_kv_update(cache: dict, k: Array, v: Array, *,
                         block_tables: Array, row_ids: Array, q_pos: Array):
    """Scatter one flat ``[1, W]`` token stream's K/V into the page pool.

    Flat-segment layout contract (the token-level analogue of
    :func:`paged_kv_update`'s row contract): position ``i`` of the stream
    belongs to engine row ``row_ids[i]`` (``-1`` = padding, routed to the
    trash page) and sits at absolute position ``q_pos[i]`` of that row, so
    its page is ``block_tables[row_ids[i], q_pos[i] // T]`` at offset
    ``q_pos[i] % T``.  Rows are fully ragged: one step may interleave
    decode segments (1+k positions), chunked-prefill segments, and padding
    up to the ``m_r``-aligned width W.

    cache: {"k_pages","v_pages"} [P, T, Hkv, dh]; k, v: [1, W, Hkv, dh];
    block_tables: [B, MP]; row_ids, q_pos: [W].  Returns the new cache —
    the gather side lives in the ragged-attention op, which reads each
    query's own page stream (kernels/ragged_attn)."""
    kp, vp = cache["k_pages"], cache["v_pages"]
    t = kp.shape[1]
    valid = row_ids >= 0
    row = jnp.maximum(row_ids, 0)
    slot = jnp.minimum(q_pos // t, block_tables.shape[1] - 1)
    page = jnp.where(valid, block_tables[row, slot], 0)
    off = jnp.where(valid, q_pos % t, 0)
    kp = kp.at[page, off].set(k[0].astype(kp.dtype))
    vp = vp.at[page, off].set(v[0].astype(vp.dtype))
    return {"k_pages": kp, "v_pages": vp}


def core_attention(q: Array, k: Array, v: Array, *, causal: bool,
                   q_pos: Array, kv_len_mask: Optional[Array] = None) -> Array:
    """q: [B,Sq,Hq,dh]; k,v: [B,Skv,Hkv,dh].  fp32 softmax; GQA grouping.

    ``q_pos``: [Sq] (shared across batch — train/prefill) or [B,Sq] (decode)
    absolute query positions for the causal mask against kv positions
    0..Skv-1.  Keeping the shared-position case 2-D matters: a
    batch-independent additive mask stays [Sq,Skv] and is fused/hoisted
    cheaply, instead of materializing a [B,h,g,Sq,Skv] predicate in the
    layer-scan carry (§Perf iteration 1).
    ``kv_len_mask``: [B,Skv] optional validity mask (decode: cache slots
    beyond the current position are invalid).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kv_pos = jnp.arange(skv)
    neg = jnp.float32(-1e30)
    if causal:
        if q_pos.ndim == 1:  # additive 2-D mask, batch-independent
            bias = jnp.where(q_pos[:, None] >= kv_pos[None, :], 0.0, neg)
            scores = scores + bias[None, None, None, :, :]
        else:
            m = q_pos[:, None, None, :, None] >= kv_pos[None, None, None, None, :]
            scores = jnp.where(m, scores, neg)
    if kv_len_mask is not None:
        scores = jnp.where(kv_len_mask[:, None, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      q_pos: Array, chunk: int = 512) -> Array:
    """Memory-linear attention: scan over query chunks (scores are
    [B,h,g,chunk,Skv] instead of [B,h,g,Sq,Skv]), each chunk rematerialized
    on the backward pass.  O(chunk*Skv) live score memory — what makes the
    32k prefill and 4k train cells fit HBM (§Perf iteration 2).  Numerics
    identical to :func:`core_attention` (same fp32 softmax)."""
    b, sq, hq, dh = q.shape
    if sq <= chunk or sq % chunk != 0 or q_pos.ndim != 1:
        return core_attention(q, k, v, causal=causal, q_pos=q_pos)
    n = sq // chunk
    qs = q.reshape(b, n, chunk, hq, dh).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(n, chunk)

    @jax.checkpoint
    def one(args):
        q_c, p_c = args
        return core_attention(q_c, k, v, causal=causal, q_pos=p_c)

    out = jax.lax.map(one, (qs, ps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dh)


def attn_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig, *,
               positions: Array, causal: bool = True,
               kv_cache: Optional[dict] = None, cache_pos: Optional[Array] = None,
               kv_source: Optional[Array] = None,
               keep_packed: bool = False, paged: Optional[dict] = None):
    """Returns (out_stream, new_kv_cache).

    Modes:
      - train/prefill: ``kv_cache=None`` — full-sequence attention.
      - decode: ``kv_cache`` given, ``cache_pos`` scalar — writes the new
        K/V at ``cache_pos`` then attends over the cache.
      - paged decode/prefill (continuous batching): ``paged`` given —
        ``kv_cache`` is a page pool and ``paged`` carries
        {block_tables [B,MP], lens [B], new_counts [B]}; every row sits at
        its own position (``positions`` is [B,S]), K/V are scattered into
        the row's pages and attention reads the gathered page stream.
      - flat paged (token-level batching): ``paged`` carries
        {block_tables [B,MP], row_ids [W], q_pos [W]} and x is one
        ``[1, W]`` stream — per-position scatter, then the segment-masked
        ragged-attention op (kernels/ragged_attn) gathers each query's own
        row.
      - cross-attention: ``kv_source`` [B,S_enc,D] — K/V from the encoder
        output (positions/causality ignored; no cache mutation here, whisper
        cross K/V are precomputed per request by the serving engine).
    """
    dh = cfg.d_head
    hq, hkv = cfg.n_heads, cfg.n_kv_heads

    q = maybe_unpack(linear_apply(params["wq"], x, ctx, tp="col"))
    kv_in = x if kv_source is None else kv_source
    kv_tp = "col" if cfg.n_kv_heads >= ctx.tp_size else None
    k = maybe_unpack(linear_apply(params["wk"], kv_in, ctx, tp=kv_tp))
    v = maybe_unpack(linear_apply(params["wv"], kv_in, ctx, tp=kv_tp))

    b, sq = q.shape[0], q.shape[1]
    skv = k.shape[1]
    mdl = ctx.tp_axis
    q = ctx.constrain(q.reshape(b, sq, hq, dh), (None, mdl, None))
    k = k.reshape(b, skv, hkv, dh)
    v = v.reshape(b, skv, hkv, dh)
    if kv_tp == "col":
        k = ctx.constrain(k, (None, mdl, None))
        v = ctx.constrain(v, (None, mdl, None))

    if cfg.qk_norm:
        q = norm_apply(params["q_norm"], q, "rmsnorm")
        k = norm_apply(params["k_norm"], k, "rmsnorm")

    if cfg.rope != "none" and kv_source is None:
        pct = cfg.rope_pct if cfg.rope == "partial2d" else 1.0
        q, k = apply_rope(q, k, positions, theta=cfg.rope_theta, pct=pct)

    new_cache = kv_cache
    kv_len_mask = None
    if paged is not None and "row_ids" in paged:
        from repro.kernels.ragged_attn import ragged_attention
        new_cache = flat_paged_kv_update(
            kv_cache, k, v, block_tables=paged["block_tables"],
            row_ids=paged["row_ids"], q_pos=paged["q_pos"])
        out = ragged_attention(
            q[0], new_cache["k_pages"], new_cache["v_pages"],
            block_tables=paged["block_tables"], row_ids=paged["row_ids"],
            q_pos=paged["q_pos"])[None]
        out = ctx.constrain(out, (None, mdl, None)).reshape(b, sq, hq * dh)
        out = linear_apply(params["wo"], out, ctx, keep_packed=keep_packed,
                           tp="row")
        return out, new_cache
    if paged is not None:
        new_cache, k, v, kv_len_mask = paged_kv_update(
            kv_cache, k, v, block_tables=paged["block_tables"],
            lens=paged["lens"], new_counts=paged["new_counts"])
    elif kv_cache is not None:
        # decode: insert this step's K/V at cache_pos, attend over the cache
        kc = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                          (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                          (0, cache_pos, 0, 0))
        new_cache = {"k": kc, "v": vc}
        k, v = kc, vc
        kv_len_mask = (jnp.arange(k.shape[1]) < cache_pos + sq)[None, :]
        kv_len_mask = jnp.broadcast_to(kv_len_mask, (b, k.shape[1]))

    # positions stay 1-D when shared across the batch (train/prefill):
    # the causal mask then stays 2-D instead of [B,h,g,Sq,Skv] (§Perf it. 1)
    if kv_cache is None and kv_source is None and sq > 512:
        out = chunked_attention(q, k, v, causal=causal, q_pos=positions)
    else:
        out = core_attention(q, k, v, causal=causal and kv_source is None,
                             q_pos=positions, kv_len_mask=kv_len_mask)
    out = ctx.constrain(out, (None, mdl, None)).reshape(b, sq, hq * dh)
    out = linear_apply(params["wo"], out, ctx, keep_packed=keep_packed, tp="row")
    return out, new_cache
