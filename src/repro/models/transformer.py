"""Decoder LM assembly: blocks, scanned layer groups, logits.

Layers are grouped into the repeating pattern period (e.g. jamba's
[mamba x4, attn, mamba x3] with MoE on every 2nd layer => period 8) and the
group stack is a single ``lax.scan`` over stacked parameters — keeping HLO
size independent of depth (94-layer MoE compiles as one group body) and
giving remat a natural checkpoint boundary.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.core.propagation import PackedArray
from repro.models import attention, mamba, moe, rwkv6
from repro.models import mlp as mlp_mod
from repro.models.common import (Stream, constrain_stream, embed_apply,
                                 embed_init, maybe_pack, maybe_unpack,
                                 norm_apply, norm_init, stream_add)

Array = jnp.ndarray

__all__ = ["pattern_period", "block_init", "block_apply", "group_init",
           "layers_init", "layers_apply", "lm_init", "lm_apply", "logits_apply",
           "init_layer_caches", "init_paged_caches", "AUX_ZERO"]

AUX_ZERO = {"load_balance": jnp.float32(0), "router_z": jnp.float32(0),
            "dropped_frac": jnp.float32(0)}


def pattern_period(cfg: ModelConfig) -> int:
    p = len(cfg.block_pattern)
    if cfg.moe:
        p = math.lcm(p, cfg.moe_every)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return p


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, pos: int, dtype, *, cross: bool = False) -> dict:
    t = cfg.layer_types[pos]
    use_moe = cfg.moe_on_layer(pos)
    ks = jax.random.split(key, 5)
    p = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype)}
    if t == "attn":
        p["mixer"] = attention.attn_init(ks[0], cfg, dtype)
    elif t == "mamba":
        p["mixer"] = mamba.mamba_init(ks[0], cfg, dtype)
    elif t == "rwkv":
        p["mixer"] = rwkv6.rwkv_tm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(t)
    if cross:
        p["ln_c"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attention.attn_init(ks[1], cfg, dtype, cross=True)
    p["ln2"] = norm_init(cfg.norm, cfg.d_model, dtype)
    if use_moe:
        p["ffn"] = moe.moe_init(ks[2], cfg, dtype)
    elif t == "rwkv":
        p["ffn"] = rwkv6.rwkv_cm_init(ks[2], cfg, dtype)
    else:
        p["ffn"] = mlp_mod.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg, dtype,
                                    bias=cfg.attn_bias and cfg.family == "encdec")
    return p


def _as_stream_like(out, like: Stream, ctx: MatmulContext) -> Stream:
    if isinstance(like, PackedArray) and not isinstance(out, PackedArray):
        return maybe_pack(out, ctx)
    if not isinstance(like, PackedArray) and isinstance(out, PackedArray):
        return out.unpack()
    return out


def block_apply(p: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig, pos: int,
                *, positions: Array, causal: bool = True,
                cache: Optional[dict] = None, cache_pos: Optional[Array] = None,
                enc_out: Optional[Array] = None,
                cross_kv: Optional[dict] = None,
                paged: Optional[dict] = None) -> Tuple[Stream, Optional[dict], dict]:
    """Pre-norm residual block.  Returns (x', cache', aux)."""
    t = cfg.layer_types[pos]
    use_moe = cfg.moe_on_layer(pos)
    aux = dict(AUX_ZERO)
    keep = isinstance(x, PackedArray)

    h = norm_apply(p["ln1"], x, cfg.norm)
    new_cache: dict = {}
    if t == "attn":
        mix_cache = None if cache is None else cache.get("kv")
        out, kv = attention.attn_apply(
            p["mixer"], h, ctx, cfg, positions=positions, causal=causal,
            kv_cache=mix_cache, cache_pos=cache_pos, keep_packed=keep,
            paged=paged)
        if cache is not None:
            new_cache["kv"] = kv
    elif t == "mamba":
        mix_cache = None if cache is None else cache.get("mamba")
        out, mc = mamba.mamba_apply(p["mixer"], h, ctx, cfg, cache=mix_cache)
        if cache is not None:
            new_cache["mamba"] = mc
    else:  # rwkv
        mix_cache = None if cache is None else \
            {"tm_shift": cache["tm_shift"], "state": cache["state"]}
        out, rc = rwkv6.rwkv_tm_apply(p["mixer"], h, ctx, cfg, cache=mix_cache)
        if cache is not None:
            new_cache.update(rc)
    x = stream_add(x, _as_stream_like(out, x, ctx))

    if "cross" in p:
        hc = norm_apply(p["ln_c"], x, cfg.norm)
        if cross_kv is not None:
            q = maybe_unpack(linear_apply(p["cross"]["wq"], hc, ctx))
            b, sq = q.shape[0], q.shape[1]
            q = q.reshape(b, sq, cfg.n_heads, cfg.d_head)
            if cfg.qk_norm:
                q = norm_apply(p["cross"]["q_norm"], q, "rmsnorm")
            o = attention.core_attention(
                q, cross_kv["k"], cross_kv["v"], causal=False,
                q_pos=jnp.zeros((sq,), jnp.int32))
            out = linear_apply(p["cross"]["wo"], o.reshape(b, sq, -1), ctx,
                               keep_packed=keep)
        else:
            out, _ = attention.attn_apply(
                p["cross"], hc, ctx, cfg, positions=positions, causal=False,
                kv_source=enc_out, keep_packed=keep)
        x = stream_add(x, _as_stream_like(out, x, ctx))

    h2 = norm_apply(p["ln2"], x, cfg.norm)
    if use_moe:
        out2, aux = moe.moe_apply(p["ffn"], h2, ctx, cfg)
    elif t == "rwkv":
        cm_cache = None if cache is None else {"cm_shift": cache["cm_shift"]}
        out2, cmc = rwkv6.rwkv_cm_apply(p["ffn"], h2, ctx, cfg, cache=cm_cache)
        if cache is not None:
            new_cache.update(cmc)
    else:
        out2 = mlp_mod.mlp_apply(p["ffn"], h2, ctx, cfg, keep_packed=keep)
    x = stream_add(x, _as_stream_like(out2, x, ctx))
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# layer stack as scan over pattern groups
# ---------------------------------------------------------------------------

def group_init(key, cfg: ModelConfig, dtype, *, cross: bool = False) -> dict:
    period = pattern_period(cfg)
    ks = jax.random.split(key, period)
    return {f"p{i}": block_init(ks[i], cfg, i, dtype, cross=cross)
            for i in range(period)}


def layers_init(key, cfg: ModelConfig, dtype, *, cross: bool = False) -> dict:
    period = pattern_period(cfg)
    groups = cfg.n_layers // period
    ks = jax.random.split(key, groups)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[group_init(k, cfg, dtype, cross=cross) for k in ks])
    return stacked


def init_layer_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    """Stacked [G, ...] decode caches, structure matching each pattern slot."""
    period = pattern_period(cfg)
    groups = cfg.n_layers // period
    one = {}
    for i in range(period):
        t = cfg.layer_types[i]
        c: dict = {}
        if t == "attn":
            c["kv"] = attention.init_kv_cache(cfg, batch, max_len, dtype)
        elif t == "mamba":
            c["mamba"] = mamba.init_mamba_cache(cfg, batch, dtype)
        else:
            c.update(rwkv6.init_rwkv_cache(cfg, batch, dtype))
        one[f"p{i}"] = c
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (groups,) + x.shape), one)


def init_paged_caches(cfg: ModelConfig, num_pages: int, page_tokens: int,
                      slots: int, dtype) -> dict:
    """Stacked [G, ...] caches for continuous-batching decode.

    Attention K/V lives in a shared paged pool indexed by per-request block
    tables ([G, P, T, Hkv, dh]; page ids are shared across groups and
    pattern slots — one logical page holds a token range's KV for every
    attention layer).  Recurrent mixer state is O(1)/sequence and stays
    per-slot dense ([G, slots, ...])."""
    period = pattern_period(cfg)
    groups = cfg.n_layers // period
    one = {}
    for i in range(period):
        t = cfg.layer_types[i]
        c: dict = {}
        if t == "attn":
            c["kv"] = attention.init_paged_kv_cache(cfg, num_pages,
                                                    page_tokens, dtype)
        elif t == "mamba":
            c["mamba"] = mamba.init_mamba_cache(cfg, slots, dtype)
        else:
            c.update(rwkv6.init_rwkv_cache(cfg, slots, dtype))
        one[f"p{i}"] = c
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (groups,) + x.shape), one)


def layers_apply(params_groups: dict, x: Stream, ctx: MatmulContext,
                 cfg: ModelConfig, run: RunConfig, *, positions: Array,
                 causal: bool = True, caches: Optional[dict] = None,
                 cache_pos: Optional[Array] = None,
                 enc_out: Optional[Array] = None,
                 cross_kv: Optional[dict] = None,
                 paged: Optional[dict] = None):
    """Returns (x', new_caches, aux).

    Modes: train/prefill (``caches=None``; ``enc_out`` optionally closed over
    for cross-attention) and decode (``caches`` stacked [G, ...]; whisper
    decode additionally passes per-layer precomputed ``cross_kv``; paged
    continuous-batching decode passes ``paged`` block-table state shared by
    every group).  The paged mode is fully ragged per row — each row's
    ``positions``/``new_counts`` place anywhere from 0 to S new tokens at
    its own offset, which is what lets the serving engine fuse chunked
    prefill and decode into one fixed-shape step (and what a speculative
    verify step will reuse).  NOTE: only attention layers are inert on
    padded row positions (their writes land in the trash page and the
    causal mask hides them); mamba/rwkv scans carry state across every
    position, so ragged multi-token rows are pure-attention-only — hybrids
    keep exact-length monolithic prefill.
    """
    period = pattern_period(cfg)

    def apply_group(x, gp, gc, gkv):
        x = constrain_stream(x, ctx)
        new_gc = {}
        aux_g = dict(AUX_ZERO)
        for i in range(period):
            x, nc, aux = block_apply(
                gp[f"p{i}"], x, ctx, cfg, i, positions=positions, causal=causal,
                cache=None if gc is None else gc[f"p{i}"], cache_pos=cache_pos,
                enc_out=enc_out,
                cross_kv=None if gkv is None else gkv[f"p{i}"],
                paged=paged)
            if gc is not None:
                new_gc[f"p{i}"] = nc
            aux_g = {k: aux_g[k] + aux[k] for k in aux_g}
        return x, (new_gc if gc is not None else None), aux_g

    if caches is None:
        def body(carry, gp):
            x, aux_acc = carry
            x, _, aux_g = apply_group(x, gp, None, None)
            return (x, {k: aux_acc[k] + aux_g[k] for k in aux_acc}), None
        if run.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, dict(AUX_ZERO)), params_groups)
        return x, None, aux

    xs = ((params_groups, caches) if cross_kv is None
          else (params_groups, caches, cross_kv))

    def body(x, xs_):
        gp, gc = xs_[0], xs_[1]
        gkv = xs_[2] if len(xs_) == 3 else None
        xo, ngc, _ = apply_group(x, gp, gc, gkv)
        return xo, ngc

    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches, dict(AUX_ZERO)


# ---------------------------------------------------------------------------
# full decoder LM
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    dtype = jnp.dtype(run.param_dtype)
    ks = jax.random.split(key, 4)
    p = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
         "groups": layers_init(ks[1], cfg, dtype),
         "ln_f": norm_init(cfg.norm, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab, dtype=dtype,
                                   scale=cfg.d_model ** -0.5)
    if cfg.family == "vlm":
        p["vision_proj"] = linear_init(ks[3], cfg.d_model, cfg.d_model, dtype=dtype)
    return p


def logits_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig) -> Array:
    # vocab-parallel head: logits sharded over the model axis; the fp32
    # softmax/CE over the sharded vocab dim lowers to a distributed
    # reduction under GSPMD.
    if cfg.tie_embeddings:
        w = params["embed"]["e"].T
        return maybe_unpack(linear_apply({"w": w}, x, ctx, tp="col"))
    return maybe_unpack(linear_apply(params["lm_head"], x, ctx, tp="col"))


def lm_apply(params: dict, embeds: Array, ctx: MatmulContext, cfg: ModelConfig,
             run: RunConfig, *, positions: Array, caches=None, cache_pos=None,
             last_only: bool = False, paged=None,
             logits_at: Optional[Array] = None):
    """embeds: [B, S, D] input embeddings (token and/or stub-modality).

    Returns (logits [B,S,V] (or [B,1,V] when ``last_only`` — the serving
    prefill path, which skips the full-sequence vocab projection), caches,
    aux).  ``logits_at``: [B] per-row position — emit logits for that
    position only (ragged prefill: each row's last *valid* token differs)
    — or [B, K] per-row positions, emitting [B, K, V] (the speculative
    verify step reads logits at each of a row's k draft positions from one
    fused call while the head still projects K << S positions).
    """
    x: Stream = maybe_pack(embeds, ctx)
    x, new_caches, aux = layers_apply(params["groups"], x, ctx, cfg, run,
                                      positions=positions, caches=caches,
                                      cache_pos=cache_pos, paged=paged)
    x = norm_apply(params["ln_f"], x, cfg.norm)
    if logits_at is not None:
        idx = logits_at if logits_at.ndim == 2 else logits_at[:, None]
        x = jnp.take_along_axis(maybe_unpack(x),
                                idx[:, :, None].astype(jnp.int32),
                                axis=1)
        x = maybe_pack(x, ctx)
    elif last_only:
        x = maybe_unpack(x)[:, -1:, :]
        x = maybe_pack(x, ctx)
    logits = logits_apply(params, x, ctx, cfg)
    return logits, new_caches, aux
