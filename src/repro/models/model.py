"""Model facade: init / forward / loss / decode_step / input_specs per family.

This is the single interface consumed by the trainer, the serving engine,
the dry-run launcher and the benchmarks.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeSpec
from repro.core.hardware import HardwareSpec, query
from repro.core.linear import MatmulContext, linear_apply
from repro.core.layout import LayoutPolicy
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.common import embed_apply

Array = jnp.ndarray

__all__ = ["ReproModel", "build_model"]


_TRACE_LOG_TREE_CAP = 8   # args with more leaves are summarized as one entry


def _describe_trace_args(names, args, kwargs) -> dict:
    """Per-argument (shape, dtype, weak_type) signatures of one trace,
    keyed by ``argname`` + pytree path.  Large pytrees (params) collapse
    to one summary entry — retrace attribution needs "which argument
    changed", not five hundred weight leaves."""
    desc = {}
    items = list(zip(names, args)) + sorted(kwargs.items())
    for name, val in items:
        leaves, _ = jax.tree_util.tree_flatten_with_path(val)
        sigs = []
        for path, leaf in leaves:
            aval = getattr(leaf, "aval", None)
            if aval is not None:
                sigs.append((jax.tree_util.keystr(path), tuple(aval.shape),
                             str(aval.dtype),
                             bool(getattr(aval, "weak_type", False))))
            else:
                sigs.append((jax.tree_util.keystr(path), "static",
                             repr(type(leaf).__name__), False))
        if len(sigs) > _TRACE_LOG_TREE_CAP:
            desc[name] = (f"<pytree:{len(sigs)} leaves>",
                          f"sig_hash={hash(tuple(sigs)) & 0xffffffff:#x}",
                          False)
        else:
            for p, shp, dt, weak in sigs:
                desc[name + p] = (shp, dt, weak)
    return desc


def _xent(logits: Array, labels: Array, z_loss: float) -> Tuple[Array, dict]:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    zl = jnp.mean(lse ** 2)
    return nll + z_loss * zl, {"nll": nll, "z_loss": zl}


class ReproModel:
    """Family-dispatched model with a uniform train/serve interface."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, shape: ShapeSpec,
                 hw: Optional[HardwareSpec] = None, mesh=None):
        self.cfg = cfg
        self.run = run
        self.shape = shape
        mesh_axes = None
        dp_size = tp_size = 1
        if mesh is not None:
            mesh_axes = tuple(mesh.axis_names)
            tp_size = mesh.shape.get("model", 1)
            dp_size = 1
            for a in ("pod", "data"):
                dp_size *= mesh.shape.get(a, 1)
        self.ctx = MatmulContext(policy=LayoutPolicy(run.layout_policy),
                                 hw=hw or query(), propagate=run.propagate,
                                 mesh_axes=mesh_axes, dp_size=dp_size,
                                 tp_size=tp_size,
                                 moe_local=run.moe_local_dispatch)
        self.compute_dtype = jnp.dtype(run.compute_dtype)

    # ------------------------------------------------------------------
    # shapes
    # ------------------------------------------------------------------
    @property
    def text_len(self) -> int:
        s = self.shape.seq_len
        if self.cfg.family == "vlm":
            return s - self.cfg.vision_tokens
        return s

    @property
    def enc_len(self) -> int:
        return self.shape.seq_len // self.cfg.audio_downsample

    def input_specs(self, kind: Optional[str] = None) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        kind = kind or self.shape.kind
        b, s = self.shape.global_batch, self.shape.seq_len
        i32 = jnp.int32
        f = self.compute_dtype
        d = self.cfg.d_model
        sds = jax.ShapeDtypeStruct
        if kind in ("train", "prefill"):
            specs = {"tokens": sds((b, self.text_len), i32)}
            if kind == "train":
                specs["labels"] = sds((b, self.text_len), i32)
            if self.cfg.family == "encdec":
                specs["frames"] = sds((b, self.enc_len, d), f)
            if self.cfg.family == "vlm":
                specs["patches"] = sds((b, self.cfg.vision_tokens, d), f)
            return specs
        # decode: one new token against a seq_len cache
        caches = jax.eval_shape(lambda: self.init_cache(b, s))
        return {"caches": caches,
                "token": sds((b, 1), i32),
                "pos": sds((), i32)}

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_init(key, self.cfg, self.run,
                                          max_src=max(self.enc_len, 8),
                                          max_tgt=max(self.shape.seq_len, 8))
        return tfm.lm_init(key, self.cfg, self.run)

    # ------------------------------------------------------------------
    # forward / loss
    # ------------------------------------------------------------------
    def _embeds(self, params: dict, batch: dict) -> Array:
        from repro.models.common import constrain_stream
        x = embed_apply(params["embed"], batch["tokens"]).astype(self.compute_dtype)
        # anchor the gather output (batch over DP, features replicated):
        # without this GSPMD can demand a model-sharded feature dim from the
        # token gather and trip its own partitioner (verifier failure)
        x = constrain_stream(x, self.ctx)
        if self.cfg.family == "vlm":
            vis = linear_apply(params["vision_proj"],
                               batch["patches"].astype(self.compute_dtype), self.ctx)
            x = jnp.concatenate([vis, x], axis=1)
        return x

    def forward(self, params: dict, batch: dict,
                last_only: bool = False) -> Tuple[Array, dict]:
        """Full-sequence forward.  Returns (logits, aux).

        ``last_only``: serving prefill — emit logits for the final position
        only (skips the [B,S,vocab] projection; §Perf iteration 3).
        """
        if self.cfg.family == "encdec":
            logits = encdec_mod.encdec_forward(params, batch, self.ctx, self.cfg,
                                               self.run)
            if last_only:
                logits = logits[:, -1:]
            return logits, dict(tfm.AUX_ZERO)
        x = self._embeds(params, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        logits, _, aux = tfm.lm_apply(params, x, self.ctx, self.cfg, self.run,
                                      positions=positions, last_only=last_only)
        return logits, aux

    def loss(self, params: dict, batch: dict) -> Tuple[Array, dict]:
        logits, aux = self.forward(params, batch)
        if self.cfg.family == "vlm":
            logits = logits[:, self.cfg.vision_tokens:]
        loss, metrics = _xent(logits, batch["labels"], self.run.z_loss)
        if self.cfg.moe:
            loss = (loss
                    + self.cfg.router_aux_weight * aux["load_balance"]
                    + self.cfg.router_z_weight * aux["router_z"])
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        dt = self.compute_dtype
        if self.cfg.family == "encdec":
            layers = tfm.init_layer_caches(self.cfg, batch, max_len, dt)
            hkv, dh = self.cfg.n_kv_heads, self.cfg.d_head
            period = tfm.pattern_period(self.cfg)
            groups = self.cfg.n_layers // period
            enc_l = max_len // self.cfg.audio_downsample
            cross = {f"p{i}": {"k": jnp.zeros((groups, batch, enc_l, hkv, dh), dt),
                               "v": jnp.zeros((groups, batch, enc_l, hkv, dh), dt)}
                     for i in range(period)}
            return {"layers": layers, "cross": cross}
        return tfm.init_layer_caches(self.cfg, batch, max_len, dt)

    def init_paged_cache(self, num_pages: int, page_tokens: int,
                         slots: int) -> dict:
        """Continuous-batching caches: shared attention page pool + per-slot
        recurrent state (see :func:`transformer.init_paged_caches`)."""
        assert self.cfg.family != "encdec", "paged serving: decoder-only LMs"
        return tfm.init_paged_caches(self.cfg, num_pages, page_tokens, slots,
                                     self.compute_dtype)

    def paged_decode_step(self, params: dict, caches: dict, token: Array,
                          block_tables: Array, lens: Array,
                          new_counts: Array,
                          logits_idx: Optional[Array] = None) -> Tuple[Array, dict]:
        """Continuous-batching token step: every row advances from its own
        position.  ``token``: [B, s] (s=1 decode; s>1 the fused ragged step
        — rows mix decoding (1 new token) and chunked prefill (up to s
        prompt tokens at positions ``lens[b]..``) freely; rows padded past
        ``new_counts`` are inert).  Causality *within* a row's chunk against
        its paged past falls out of the per-row 2-D positions; the same
        ragged multi-position row doubles as the speculative-decode verify
        step (score k draft tokens in one call).  ``block_tables``:
        [B, MP] page ids; ``lens``: [B] tokens already in cache; ``new_counts``:
        [B] valid new tokens this step (0 = inactive slot).
        ``logits_idx``: optional [B, K] within-chunk positions to read
        logits at (the verify step needs every draft position, not just the
        last — K bounds the head projection at k+1 however wide the fused
        chunk is); ``None`` reads each row's last valid token.  Returns
        (logits [B, K, V] (K=1 when ``logits_idx`` is None), caches')."""
        x = embed_apply(params["embed"], token).astype(self.compute_dtype)
        positions = lens[:, None] + jnp.arange(token.shape[1], dtype=jnp.int32)
        paged = {"block_tables": block_tables, "lens": lens,
                 "new_counts": new_counts}
        logits_at = (jnp.maximum(new_counts - 1, 0) if logits_idx is None
                     else logits_idx)
        logits, new_caches, _ = tfm.lm_apply(
            params, x, self.ctx, self.cfg, self.run, positions=positions,
            caches=caches, paged=paged, logits_at=logits_at)
        return logits, new_caches

    def flat_decode_step(self, params: dict, caches: dict, token: Array,
                         block_tables: Array, row_ids: Array, q_pos: Array,
                         logits_idx: Array) -> Tuple[Array, dict]:
        """Flat token-level continuous-batching step (the paper's
        fixed-shape-grid argument at token granularity): one ``[1, W]``
        stream where position ``i`` is token ``q_pos[i]`` of engine row
        ``row_ids[i]`` (``-1`` = padding).  Rows become variable-length
        *segments* of the stream — a decode row costs exactly its 1+k real
        positions instead of a padded chunk-width row, so the token budget
        is token-exact.  Attention is the segment-masked ragged op over the
        page pool (kernels/ragged_attn).

        ``block_tables``: [B, MP] per-row page ids; ``logits_idx``: [K]
        flat positions to read logits at (each row's last position per
        draft slot — fixed K keeps the head projection and the step shape
        static).  Returns (logits [1, K, V], caches')."""
        x = embed_apply(params["embed"], token).astype(self.compute_dtype)
        positions = q_pos[None, :]
        paged = {"block_tables": block_tables, "row_ids": row_ids,
                 "q_pos": q_pos}
        logits, new_caches, _ = tfm.lm_apply(
            params, x, self.ctx, self.cfg, self.run, positions=positions,
            caches=caches, paged=paged, logits_at=logits_idx[None, :])
        return logits, new_caches

    def prefill_cache(self, params: dict, batch: dict) -> dict:
        """Serving-side: build a cache for decode (whisper: run the encoder
        and materialize cross K/V)."""
        b = batch["tokens"].shape[0]
        max_len = self.shape.seq_len
        caches = self.init_cache(b, max_len)
        if self.cfg.family == "encdec":
            enc_out = encdec_mod.encode(params, batch["frames"], self.ctx,
                                        self.cfg, self.run)
            caches["cross"] = encdec_mod.compute_cross_kv(params, enc_out,
                                                          self.ctx, self.cfg)
        return caches

    @property
    def trace_counts(self) -> dict:
        """Per-kind count of XLA traces (= compilations) of the jitted
        steps.  The wrapped step function body runs exactly once per
        (shape, dtype) cache miss, so a Python-side increment there is a
        compile counter — the hook Engine.warmup's no-recompile-after-warmup
        contract is regression-tested against."""
        if not hasattr(self, "_trace_counts"):
            self._trace_counts = {"decode": 0, "paged": 0, "flat": 0}
        return self._trace_counts

    @property
    def trace_log(self) -> list:
        """One entry per XLA trace of a jitted step: ``{"kind", "args"}``
        where ``args`` maps argument (pytree-path) names to (shape, dtype,
        weak_type).  ``trace_counts`` answers *whether* a retrace happened;
        this log answers *which argument caused it* — the recompile-hazard
        analyzer (:mod:`repro.analysis.retrace`) diffs post-warmup entries
        against earlier same-kind signatures and names the leaf that
        differs (e.g. a python scalar leaking in as a weak-typed 0 where
        warmup traced a strong ``int32``)."""
        if not hasattr(self, "_trace_log"):
            self._trace_log = []
        return self._trace_log

    def jit_step(self, kind: str = "decode"):
        """Cached jitted step (donating the cache): shared by every Engine
        built over this model, so serving sessions amortize compilations the
        way prepacking amortizes packing — re-jitting per engine would
        recompile identical programs."""
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        if kind not in self._jit_cache:
            fn = {"decode": self.decode_step,
                  "paged": self.paged_decode_step,
                  "flat": self.flat_decode_step}[kind]
            counts = self.trace_counts
            log = self.trace_log
            names = [p.name for p in
                     inspect.signature(fn).parameters.values()]

            def counted(*args, _fn=fn, _kind=kind, **kwargs):
                counts[_kind] += 1       # runs at trace time only
                try:
                    log.append({"kind": _kind,
                                "args": _describe_trace_args(names, args,
                                                             kwargs)})
                except Exception:        # the recorder must never be the
                    pass                 # reason a trace fails
                return _fn(*args, **kwargs)

            self._jit_cache[kind] = jax.jit(counted, donate_argnums=(1,))
        return self._jit_cache[kind]

    def decode_step(self, params: dict, caches: dict, token: Array, pos: Array,
                    embeds: Optional[Array] = None) -> Tuple[Array, dict]:
        """Token step(s) against the cache.  ``token``: [B, s] (s=1 decode;
        s>1 = chunked prefill into the cache).  ``embeds`` overrides token
        embeddings (vlm prefill with patch embeddings).  Returns
        (logits [B,s,V], caches')."""
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_decode_step(params, caches, token, pos,
                                                 self.ctx, self.cfg, self.run)
        if embeds is None:
            x = embed_apply(params["embed"], token).astype(self.compute_dtype)
        else:
            x = embeds.astype(self.compute_dtype)
        b, s = x.shape[0], x.shape[1]
        positions = pos + jnp.arange(s, dtype=jnp.int32)  # 1-D: shared batch
        logits, new_caches, _ = tfm.lm_apply(params, x, self.ctx, self.cfg,
                                             self.run, positions=positions,
                                             caches=caches, cache_pos=pos)
        return logits, new_caches


def build_model(cfg: ModelConfig, run: RunConfig, shape: ShapeSpec,
                hw: Optional[HardwareSpec] = None, mesh=None) -> ReproModel:
    return ReproModel(cfg, run, shape, hw, mesh=mesh)
