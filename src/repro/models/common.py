"""Shared model components: norms, rotary embeddings, embeddings, activations.

All components speak both representations: plain arrays and
:class:`~repro.core.propagation.PackedArray` (packed-layout propagation).
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.propagation import PackedArray, pack_activation
from repro.core.linear import MatmulContext

Array = jnp.ndarray
Stream = Union[jnp.ndarray, PackedArray]

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
        "tanh": jnp.tanh}

__all__ = ["ACTS", "Stream", "norm_init", "norm_apply", "apply_rope",
           "embed_init", "embed_apply", "maybe_pack", "maybe_unpack",
           "stream_add"]


# ---------------------------------------------------------------------------
# packed/unpacked stream helpers
# ---------------------------------------------------------------------------

def maybe_pack(x: Array, ctx: MatmulContext) -> Stream:
    if ctx.packed and ctx.propagate:
        return pack_activation(x, ctx.layout(x.dtype))
    return x


def maybe_unpack(x: Stream) -> Array:
    return x.unpack() if isinstance(x, PackedArray) else x


def stream_add(a: Stream, b: Stream) -> Stream:
    if isinstance(a, PackedArray) and isinstance(b, PackedArray):
        return a + b
    return maybe_unpack(a) + maybe_unpack(b)


def constrain_stream(x: Stream, ctx: MatmulContext) -> Stream:
    """Anchor the residual stream's leading batch dim to the DP axes inside
    scanned layer bodies (GSPMD loses it through scan params otherwise)."""
    if not ctx.dp_axes:
        return x
    import jax.lax
    from jax.sharding import PartitionSpec as P
    data = x.data if isinstance(x, PackedArray) else x
    if data.shape[0] % max(1, ctx.dp_size) != 0:
        return x  # e.g. batch-1 long-context: leave to seq sharding
    spec = P(ctx.dp_axes, *(None,) * (data.ndim - 1))
    out = jax.lax.with_sharding_constraint(data, spec)
    if isinstance(x, PackedArray):
        return PackedArray(data=out, m=x.m, k=x.k, layout=x.layout)
    return out


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return {"g": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    if kind == "layernorm_np":  # olmo: non-parametric LN
        return {}
    raise ValueError(kind)


def norm_apply(params: dict, x: Stream, kind: str, eps: float = 1e-6) -> Stream:
    if isinstance(x, PackedArray):
        if kind == "rmsnorm":
            return x.rms_norm(params["g"], eps)
        if kind == "layernorm":
            return x.layer_norm(params["g"], params["b"], eps)
        if kind == "layernorm_np":
            return x.layer_norm(None, None, eps)
        raise ValueError(kind)
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        return (y * params["g"].astype(jnp.float32)).astype(x.dtype)
    if kind in ("layernorm", "layernorm_np"):
        mean = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["g"].astype(jnp.float32) + params["b"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary position embeddings (neox-style halves; partial rotation for 2d RoPE)
# ---------------------------------------------------------------------------

def apply_rope(q: Array, k: Array, positions: Array, *, theta: float = 1e4,
               pct: float = 1.0) -> tuple[Array, Array]:
    """q: [B,S,Hq,dh], k: [B,S,Hkv,dh], positions: [B,S] or [S] int32.

    ``pct < 1`` rotates only the first ``pct * dh`` dims (chatglm 2d-RoPE
    convention: half the head dim carries rotary phase, the rest is passthrough).
    """
    dh = q.shape[-1]
    rot = int(dh * pct)
    rot -= rot % 2
    if positions.ndim == 1:
        positions = positions[None, :]

    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]

    def rotate(x):
        xr, xp = x[..., :rot], x[..., rot:]
        x1, x2 = xr[..., :half], xr[..., half:]
        y1 = x1 * cos - x2 * sin
        y2 = x2 * cos + x1 * sin
        return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], -1)

    return rotate(q), rotate(k)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    e = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"e": e.astype(dtype)}


def embed_apply(params: dict, tokens: Array) -> Array:
    return jnp.take(params["e"], tokens, axis=0)
