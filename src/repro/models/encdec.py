"""Encoder-decoder LM (whisper-small family).

The conv/audio frontend is a stub per the assignment: ``input_specs`` hands
the model precomputed frame embeddings [B, S_enc, d]; a linear projector +
learned positions stand in for the conv stem.  Encoder layers are
bidirectional; decoder layers are causal with cross-attention.  Decode
precomputes the cross K/V once per request (standard whisper serving).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.models import attention
from repro.models.common import (constrain_stream, embed_apply, embed_init,
                                 maybe_pack, maybe_unpack, norm_apply,
                                 norm_init)
from repro.models import transformer as tfm

Array = jnp.ndarray

__all__ = ["encdec_init", "encode", "decode_train", "compute_cross_kv",
           "encdec_forward", "encdec_decode_step", "enc_config"]


def enc_config(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, name=cfg.name + "-enc",
                               n_layers=cfg.encoder_layers, moe=False,
                               encoder_layers=0)


def encdec_init(key, cfg: ModelConfig, run: RunConfig, *, max_src: int,
                max_tgt: int) -> dict:
    dtype = jnp.dtype(run.param_dtype)
    ks = jax.random.split(key, 6)
    ecfg = enc_config(cfg)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "pe_enc": (jax.random.normal(ks[1], (max_src, cfg.d_model), jnp.float32)
                   * 0.01).astype(dtype),
        "pe_dec": (jax.random.normal(ks[2], (max_tgt, cfg.d_model), jnp.float32)
                   * 0.01).astype(dtype),
        "frontend_proj": linear_init(ks[3], cfg.d_model, cfg.d_model, bias=True,
                                     dtype=dtype),
        "enc_groups": tfm.layers_init(ks[4], ecfg, dtype),
        "enc_ln_f": norm_init(cfg.norm, cfg.d_model, dtype),
        "dec_groups": tfm.layers_init(ks[5], cfg, dtype, cross=True),
        "ln_f": norm_init(cfg.norm, cfg.d_model, dtype),
    }


def encode(params: dict, frames: Array, ctx: MatmulContext, cfg: ModelConfig,
           run: RunConfig) -> Array:
    s = frames.shape[1]
    x = linear_apply(params["frontend_proj"], frames, ctx)
    x = x + params["pe_enc"][:s].astype(x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    x = maybe_pack(x, ctx)
    x, _, _ = tfm.layers_apply(params["enc_groups"], x, ctx, enc_config(cfg), run,
                               positions=positions, causal=False)
    x = norm_apply(params["enc_ln_f"], x, cfg.norm)
    return maybe_unpack(x)


def decode_train(params: dict, tokens: Array, enc_out: Array, ctx: MatmulContext,
                 cfg: ModelConfig, run: RunConfig) -> Array:
    s = tokens.shape[1]
    x = embed_apply(params["embed"], tokens)
    x = constrain_stream(x, ctx)  # anchor the token gather (see model._embeds)
    x = x + params["pe_dec"][:s].astype(x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    x = maybe_pack(x, ctx)
    x, _, _ = tfm.layers_apply(params["dec_groups"], x, ctx, cfg, run,
                               positions=positions, causal=True, enc_out=enc_out)
    x = norm_apply(params["ln_f"], x, cfg.norm)
    return tfm.logits_apply(params, x, ctx, cfg)


def encdec_forward(params: dict, batch: dict, ctx: MatmulContext,
                   cfg: ModelConfig, run: RunConfig) -> Array:
    enc_out = encode(params, batch["frames"], ctx, cfg, run)
    return decode_train(params, batch["tokens"], enc_out, ctx, cfg, run)


def compute_cross_kv(params: dict, enc_out: Array, ctx: MatmulContext,
                     cfg: ModelConfig) -> dict:
    """Precompute decoder cross-attention K/V from the encoder output.

    Returns a [G, ...]-stacked pytree matching ``dec_groups`` structure.
    """
    b, s = enc_out.shape[0], enc_out.shape[1]
    hkv, dh = cfg.n_kv_heads, cfg.d_head

    def per_group(gp):
        out = {}
        for name, bp in gp.items():
            cp = bp["cross"]
            k = maybe_unpack(linear_apply(cp["wk"], enc_out, ctx)).reshape(b, s, hkv, dh)
            v = maybe_unpack(linear_apply(cp["wv"], enc_out, ctx)).reshape(b, s, hkv, dh)
            if cfg.qk_norm:
                k = norm_apply(cp["k_norm"], k, "rmsnorm")
            out[name] = {"k": k, "v": v}
        return out

    def body(_, gp):
        return 0, per_group(gp)

    _, stacked = jax.lax.scan(body, 0, params["dec_groups"])
    return stacked


def encdec_decode_step(params: dict, caches: dict, token: Array, pos: Array,
                       ctx: MatmulContext, cfg: ModelConfig, run: RunConfig
                       ) -> Tuple[Array, dict]:
    """One decoder token step; caches = {"layers": [G,...], "cross": [G,...]}."""
    b, s = token.shape
    x = embed_apply(params["embed"], token)
    x = constrain_stream(x, ctx)
    x = x + jax.lax.dynamic_slice_in_dim(params["pe_dec"], pos, s, 0).astype(x.dtype)
    positions = pos + jnp.arange(s, dtype=jnp.int32)  # 1-D: shared batch
    x = maybe_pack(x, ctx)
    x, new_layers, _ = tfm.layers_apply(
        params["dec_groups"], x, ctx, cfg, run, positions=positions, causal=True,
        caches=caches["layers"], cache_pos=pos, cross_kv=caches["cross"])
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = tfm.logits_apply(params, x, ctx, cfg)
    return logits, {"layers": new_layers, "cross": caches["cross"]}
