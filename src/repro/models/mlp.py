"""Gated / plain MLP blocks in the packed domain.

The MLP is the paper's sweet spot: two (or three) chained weight matmuls
with a pointwise activation between them.  Under the scalable layout the
entire block runs packed — pack once at entry, unpack once at exit (and even
those cancel against neighbouring packed ops under propagation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.core.propagation import PackedArray
from repro.models.common import ACTS, Stream

__all__ = ["mlp_init", "mlp_apply"]


def mlp_init(key, d: int, d_ff: int, cfg: ModelConfig, dtype=jnp.float32,
             *, bias: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wu": linear_init(ks[0], d, d_ff, bias=bias, dtype=dtype),
         "wd": linear_init(ks[1], d_ff, d, bias=bias, dtype=dtype,
                           scale=d_ff ** -0.5 / max(1, cfg.n_layers) ** 0.5)}
    if cfg.glu:
        p["wg"] = linear_init(ks[2], d, d_ff, bias=bias, dtype=dtype)
    return p


def mlp_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig,
              *, keep_packed: bool = False) -> Stream:
    act = ACTS[cfg.act]
    inner_packed = ctx.packed and ctx.propagate
    if cfg.glu:
        g = linear_apply(params["wg"], x, ctx, activation=act,
                         keep_packed=inner_packed, tp="col")
        u = linear_apply(params["wu"], x, ctx, keep_packed=inner_packed,
                         tp="col")
        h = g * u
    else:
        h = linear_apply(params["wu"], x, ctx, activation=act,
                         keep_packed=inner_packed, tp="col")
    return linear_apply(params["wd"], h, ctx, keep_packed=keep_packed,
                        tp="row")
