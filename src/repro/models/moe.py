"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design (scales to 128-expert configs; EP-shardable):
  1. router (fp32) -> top-k -> normalized combine weights;
  2. flat (token, choice) assignments sorted by expert (stable argsort),
     position-in-expert via counts/offsets, capacity drop;
  3. scatter into [E, C, D] (the EP all-to-all boundary: token dims shard
     over data, the expert dim shards over model);
  4. expert FFNs as *batched packed matmuls* — the paper's layouts mapped
     over the leading expert dim;
  5. weighted scatter-add combine back to tokens.

Aux losses: Switch-style load-balance + router z-loss.

Supports the assigned MoE variants: qwen3-moe (128e top-8), arctic (128e
top-2 + parallel dense residual branch), jamba (16e top-2, every 2nd layer).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.linear import MatmulContext, linear_init, batched_linear_apply
from repro.models.common import ACTS, Stream, maybe_unpack
from repro.models import mlp as mlp_mod

Array = jnp.ndarray

__all__ = ["moe_init", "moe_apply", "capacity"]


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    c = -(-c // 8) * 8  # round up to sublane multiple (packing-friendly)
    return max(8, min(c, n_tokens))


def _expert_linear_init(key, e: int, d_in: int, d_out: int, dtype, scale=None):
    scale = (d_in ** -0.5) if scale is None else scale
    w = jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale
    return {"w": w.astype(dtype)}


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": linear_init(ks[0], d, e, dtype=jnp.float32, scale=d ** -0.5),
        "wu": _expert_linear_init(ks[1], e, d, f, dtype),
        "wd": _expert_linear_init(ks[2], e, f, d, dtype,
                                  scale=f ** -0.5 / max(1, cfg.n_layers) ** 0.5),
    }
    if cfg.glu:
        p["wg"] = _expert_linear_init(ks[3], e, d, f, dtype)
    if cfg.dense_residual:
        p["dense"] = mlp_mod.mlp_init(ks[4], d, cfg.d_ff, cfg, dtype)
    return p


def constrain_blocks(xb: Array, ctx: MatmulContext) -> Array:
    """Anchor the dispatch block dim to the DP axes (token-local sorting)."""
    if not ctx.dp_axes:
        return xb
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        xb, P(ctx.dp_axes, *(None,) * (xb.ndim - 1)))


def moe_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig,
              *, local_dispatch: Optional[bool] = None) -> Tuple[Array, dict]:
    """Returns (output [B,S,D] unpacked, aux-loss dict).

    Routing is token-level top-k — not padding-neutral — so the stream is
    unpacked at entry; the expert compute itself runs packed (step 4).
    ``local_dispatch``: per-DP-shard sort/capacity (§Perf iteration 6).
    """
    if local_dispatch is None:
        local_dispatch = ctx.moe_local
    xu = maybe_unpack(x)
    b, s, d = xu.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = xu.reshape(t, d)

    # 1. routing (fp32)
    logits = xf.astype(jnp.float32) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # [T, k]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)          # renormalize

    # 2. flat assignment, sort by expert, capacity.
    # Local dispatch (§Perf iteration 6): sorting the GLOBAL [T*k]
    # assignment under GSPMD forces an all-gather of every key/payload —
    # the dominant collective in the MoE train cells.  With
    # ``local_dispatch`` the sort runs per DP shard (blocks = dp_size,
    # capacity per block), which is token-local; only the [E,C,D] expert
    # buffers cross the mesh (the unavoidable EP all-to-all).
    blocks = ctx.dp_size if (local_dispatch and ctx.dp_size > 1
                             and t % ctx.dp_size == 0) else 1
    tb = t // blocks
    c = capacity(tb, cfg)

    def dispatch(xf_b, top_e_b, top_p_b):
        e_flat = top_e_b.reshape(tb * k)
        w_flat = top_p_b.reshape(tb * k)
        perm = jnp.argsort(e_flat, stable=True)                # token-priority
        e_sorted = e_flat[perm]
        w_sorted = w_flat[perm]
        counts = jnp.bincount(e_flat, length=e)
        offsets = jnp.cumsum(counts) - counts                  # exclusive
        pos = jnp.arange(tb * k) - offsets[e_sorted]
        keep = pos < c
        src_tok = perm // k
        dst_c = jnp.where(keep, pos, c - 1)
        vals = xf_b[src_tok] * keep[:, None].astype(xf_b.dtype)
        x_e = jnp.zeros((e, c, d), xf_b.dtype).at[e_sorted, dst_c].add(vals)
        return x_e, (e_sorted, dst_c, w_sorted, keep, src_tok, counts)

    if blocks == 1:
        x_e, meta = dispatch(xf, top_e, top_p)
    else:
        xb = constrain_blocks(xf.reshape(blocks, tb, d), ctx)
        x_eb, meta = jax.vmap(dispatch)(
            xb, top_e.reshape(blocks, tb, k), top_p.reshape(blocks, tb, k))
        # [blocks, E, C, D] -> [E, blocks*C, D]: the EP all-to-all boundary
        x_e = x_eb.transpose(1, 0, 2, 3).reshape(e, blocks * c, d)

    # 4. expert FFN (batched packed matmuls over the expert dim)
    act = ACTS[cfg.act]
    if cfg.glu:
        g = batched_linear_apply(params["wg"], x_e, ctx, activation=act)
        u = batched_linear_apply(params["wu"], x_e, ctx)
        h = g * u
    else:
        h = batched_linear_apply(params["wu"], x_e, ctx, activation=act)
    y_e = batched_linear_apply(params["wd"], h, ctx)           # [E, C(*blk), D]

    # 5. combine
    if blocks == 1:
        e_sorted, dst_c, w_sorted, keep, src_tok, counts = meta
        contrib = y_e[e_sorted, dst_c] * (w_sorted * keep).astype(y_e.dtype)[:, None]
        y = jnp.zeros((t, d), xu.dtype).at[src_tok].add(contrib)
    else:
        y_eb = y_e.reshape(e, blocks, c, d).transpose(1, 0, 2, 3)

        def combine(y_b, meta_b):
            e_s, d_c, w_s, kp, s_t, _ = meta_b
            contrib = y_b[e_s, d_c] * (w_s * kp).astype(y_b.dtype)[:, None]
            return jnp.zeros((tb, d), xu.dtype).at[s_t].add(contrib)

        y = jax.vmap(combine)(y_eb, meta).reshape(t, d)
        counts = jnp.sum(meta[5], axis=0)
        keep = meta[3].reshape(-1)
    y = y.reshape(b, s, d)

    if cfg.dense_residual:  # arctic: parallel dense branch
        y = y + maybe_unpack(mlp_mod.mlp_apply(params["dense"], x, ctx, cfg))

    # aux losses (fp32 scalars)
    me = jnp.mean(probs, axis=0)                               # mean router prob
    ce = counts.astype(jnp.float32) / (t * k)                  # dispatch fraction
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2),
        "dropped_frac": 1.0 - jnp.sum(keep.astype(jnp.float32)) / (t * k),
    }
    return y, aux
