"""Model substrate: layers, mixers, families."""
