"""Mamba (selective SSM) mixer — jamba's recurrent block.

The in/out/x/dt projections run through the packed-layout pipeline; the
selective-scan recurrence itself is not a matmul and stays a native
associative scan (noted as layout-inapplicable in DESIGN.md
§Arch-applicability).

Train path: parallel associative scan over the sequence.
Decode path: O(1) recurrent state update (conv window + SSM state).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.linear import MatmulContext, linear_init, linear_apply
from repro.models.common import Stream, maybe_unpack

Array = jnp.ndarray

__all__ = ["mamba_init", "mamba_apply", "init_mamba_cache"]


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    dt_rank = -(-d // 16)
    return d, di, dt_rank, cfg.mamba_d_state


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, di, dt_rank, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": linear_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, 1, di), jnp.float32)
                   * (cfg.mamba_d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": linear_init(ks[2], di, dt_rank + 2 * n, dtype=dtype),
        "dt_proj": {"w": (jax.random.normal(ks[3], (dt_rank, di), jnp.float32)
                          * dt_rank ** -0.5).astype(dtype),
                    "b": dt_bias.astype(jnp.float32)},
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (di, n))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(ks[5], di, d, dtype=dtype,
                                scale=di ** -0.5 / max(1, cfg.n_layers) ** 0.5),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    _, di, _, n = _dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, n), jnp.float32)}


def _causal_conv(x: Array, w: Array, b: Array, prepend: Optional[Array] = None) -> Array:
    """Depthwise causal conv1d.  x: [B,S,di]; w: [W,1,di]."""
    wdt = x.dtype
    pad = w.shape[0] - 1
    if prepend is None:
        x_in = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    else:
        x_in = jnp.concatenate([prepend.astype(wdt), x], axis=1)
    out = jax.lax.conv_general_dilated(
        x_in, w.astype(wdt), window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(wdt)


def _ssm_scan(da: Array, dbx: Array) -> Array:
    """h_t = da_t * h_{t-1} + dbx_t via associative scan over axis 1."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    return h


def mamba_apply(params: dict, x: Stream, ctx: MatmulContext, cfg: ModelConfig, *,
                cache: Optional[dict] = None) -> Tuple[Array, Optional[dict]]:
    """x: stream [B,S,D].  Returns ([B,S,D], new_cache)."""
    d, di, dt_rank, n = _dims(cfg)
    xz = maybe_unpack(linear_apply(params["in_proj"], x, ctx, tp="col"))
    x_in, z = jnp.split(xz, 2, axis=-1)
    b, s = x_in.shape[0], x_in.shape[1]

    new_cache = None
    if cache is None:
        x_c = _causal_conv(x_in, params["conv_w"], params["conv_b"])
    else:
        window = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in], axis=1)
        x_c = _causal_conv(x_in, params["conv_w"], params["conv_b"],
                           prepend=cache["conv"])
        new_conv = window[:, -(cfg.mamba_d_conv - 1):, :]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype)}
    x_c = jax.nn.silu(x_c)

    proj = linear_apply(params["x_proj"], x_c, ctx)
    dt, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        (dt.astype(jnp.float32) @ params["dt_proj"]["w"].astype(jnp.float32))
        + params["dt_proj"]["b"])                                  # [B,S,di]
    a = -jnp.exp(params["a_log"])                                  # [di,N]

    da = jnp.exp(delta[..., None] * a)                             # [B,S,di,N]
    dbx = (delta[..., None] * b_ssm[:, :, None, :].astype(jnp.float32)
           * x_c[..., None].astype(jnp.float32))

    if cache is None:
        h = _ssm_scan(da, dbx)                                     # [B,S,di,N]
    else:
        h0 = cache["ssm"]                                          # [B,di,N]
        if s == 1:
            h = (da[:, 0] * h0 + dbx[:, 0])[:, None]
        else:  # prefill with state: inject h0 into the first step
            dbx = dbx.at[:, 0].add(da[:, 0] * h0)
            h = _ssm_scan(da, dbx)
        new_cache = {**(new_cache or {}), "ssm": h[:, -1]}

    y = jnp.einsum("bsdn,bsn->bsd", h, c_ssm.astype(jnp.float32))
    y = y + params["d_skip"] * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xz.dtype)
    out = linear_apply(params["out_proj"], y, ctx, tp="row")
    return out, new_cache
