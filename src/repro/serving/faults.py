"""Deterministic fault injection for the serving engine.

The resilience contract is **identity under chaos**: for any seeded
:class:`FaultPlan`, every request that survives the plan produces tokens
bit-identical to the fault-free run, and the allocator is balanced once
the drain ends.  The engine can promise this because its failure
handling only ever *removes* work — shed at admission, quarantine a
poisoned row, preempt-and-recompute a displaced one — and rows are
mathematically independent with every pick keyed by
``(seed, rid, position)``, so a survivor cannot observe a casualty.

A :class:`FaultPlan` is pure host-side instrumentation.  ``install``
wraps the engine's step entry point to track the step number and arms
one-shot faults at the planned steps:

  - ``"oom"``     — the next ``pool.alloc`` raises ``OutOfPages``
    (exercises the admission-rollback and growth-preemption paths);
  - ``"drafter"`` — the next ``Drafter.propose_all`` raises (exercises
    the speculative degradation ladder up to auto-disable);
  - ``"nan"``     — one live row of the step's logits is overwritten
    with NaN on the host *after* the device call (exercises the
    quarantine path; device state is untouched, so the zero-recompile
    contract is preserved under injection);
  - ``"copier"``  — the next ``page_copier`` call raises (exercises the
    CoW failure paths: prefix-hit fallback and rollback quarantine).

Event schedules derive from the plan's seed via ``np.random.Philox`` —
the same plan replays the same faults at the same steps, which is what
lets the chaos smoke diff a faulted drain against a clean one.

:class:`StallError` lives here too: it is the watchdog's terminal
diagnosis when a drain stops advancing, and fault plans are the main way
to provoke one on purpose.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.telemetry import NULL as _NULL_OBS
from repro.serving.kv_cache import OutOfPages

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultPlan", "InjectedFault",
           "StallError"]

FAULT_KINDS = ("oom", "drafter", "nan", "copier")


class StallError(RuntimeError):
    """A drain stopped advancing: the fused step scheduled zero tokens
    while slots were live, or admissible work sat unadmitted for
    ``watchdog_steps`` consecutive idle ticks.  The message names the
    non-advancing rids and their lifecycle states so a stuck server is
    diagnosable instead of silently spinning."""


class InjectedFault(RuntimeError):
    """Raised only by injected drafter/copier faults, never by real
    code — test assertions can tell an injection apart from an organic
    failure."""


@dataclass(frozen=True)
class FaultEvent:
    """One planned fault: at engine step ``step`` (0-based, counted over
    ``Engine.step`` calls), arm a one-shot fault of ``kind``."""
    step: int
    kind: str


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    ``install(engine)`` monkey-patches the engine instance (never the
    classes); ``uninstall()`` restores every patched attribute, so a
    plan can be applied to one drain of a long-lived engine.  The
    ``on(engine)`` context manager pairs the two.
    """

    def __init__(self, events: Sequence[FaultEvent], *, seed: int = 0):
        for e in events:
            if e.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {e.kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
            if e.step < 0:
                raise ValueError(f"fault step must be >= 0, got {e.step}")
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.step, e.kind))
        self.seed = seed
        self.fired = {k: 0 for k in FAULT_KINDS}
        self._armed: List[str] = []
        self._step_no = 0
        self._installed = None
        self._undo: List[Tuple[object, str, object, bool]] = []
        self._obs = _NULL_OBS          # the engine's recorder, on install

    @classmethod
    def random(cls, seed: int, *, steps: int = 32, num_events: int = 4,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """A seeded random plan: ``num_events`` faults over engine steps
        ``[1, steps)``, kinds drawn uniformly.  Same seed, same plan."""
        rng = np.random.Generator(np.random.Philox(seed))
        events = [FaultEvent(int(rng.integers(1, max(2, steps))),
                             kinds[int(rng.integers(len(kinds)))])
                  for _ in range(num_events)]
        return cls(events, seed=seed)

    # ------------------------------------------------------------------
    # arming
    # ------------------------------------------------------------------
    def _take(self, kind: str) -> bool:
        """Consume one armed fault of ``kind`` (one-shot per event)."""
        if kind in self._armed:
            self._armed.remove(kind)
            self.fired[kind] += 1
            self._obs.fault(kind, self._step_no - 1)
            return True
        return False

    @staticmethod
    def _victim_slot(engine) -> Optional[int]:
        """Deterministic NaN victim: the smallest decoding slot, else the
        smallest live slot (a prefilling row), else None (fault wasted —
        an idle step has no logits row to poison)."""
        running = engine.scheduler.running
        decoding = [s for s, r in running.items() if r.status == "running"]
        if decoding:
            return min(decoding)
        return min(running) if running else None

    # ------------------------------------------------------------------
    # install / uninstall
    # ------------------------------------------------------------------
    def _wrap(self, obj, name: str, wrapper) -> None:
        had = name in obj.__dict__
        self._undo.append((obj, name, getattr(obj, name) if had else None,
                           had))
        setattr(obj, name, wrapper)

    def install(self, engine) -> "FaultPlan":
        if self._installed is not None:
            raise RuntimeError("FaultPlan is already installed")
        self._installed = engine
        self._step_no = 0
        self._obs = getattr(engine, "obs", _NULL_OBS)
        plan = self

        orig_step = engine.step

        def step(*, now=None, greedy=True, seed=0):
            plan._armed = [e.kind for e in plan.events
                           if e.step == plan._step_no]
            plan._step_no += 1
            try:
                return orig_step(now=now, greedy=greedy, seed=seed)
            finally:
                plan._armed = []
        self._wrap(engine, "step", step)

        pool = engine.pool
        orig_alloc = pool.alloc

        def alloc(*a, **k):
            if plan._take("oom"):
                raise OutOfPages("injected OutOfPages spike (FaultPlan "
                                 f"seed={plan.seed}, step {plan._step_no - 1})")
            return orig_alloc(*a, **k)
        self._wrap(pool, "alloc", alloc)

        if pool.page_copier is not None:
            orig_copier = pool.page_copier

            def copier(src, dst):
                if plan._take("copier"):
                    raise InjectedFault(
                        f"injected page_copier failure ({src} -> {dst}, "
                        f"FaultPlan seed={plan.seed})")
                return orig_copier(src, dst)
            self._wrap(pool, "page_copier", copier)

        if getattr(engine, "drafter", None) is not None:
            orig_propose = engine.drafter.propose_all

            def propose_all(jobs):
                if plan._take("drafter"):
                    raise InjectedFault(
                        f"injected drafter failure (FaultPlan "
                        f"seed={plan.seed}, step {plan._step_no - 1})")
                return orig_propose(jobs)
            self._wrap(engine.drafter, "propose_all", propose_all)

        orig_paged = engine._run_paged

        def run_paged(token, bt, lens, counts, idx):
            rows = orig_paged(token, bt, lens, counts, idx)
            if "nan" in plan._armed:
                slot = plan._victim_slot(engine)
                if slot is not None and plan._take("nan"):
                    rows = np.array(rows)
                    rows[slot] = np.nan
            return rows
        self._wrap(engine, "_run_paged", run_paged)

        if getattr(engine, "_flat_step", None) is not None:
            orig_flat = engine._run_flat

            def run_flat(token, bt, row_ids, q_pos, idx):
                out = orig_flat(token, bt, row_ids, q_pos, idx)
                if "nan" in plan._armed:
                    slot = plan._victim_slot(engine)
                    if slot is not None and plan._take("nan"):
                        out = np.array(out)
                        k1 = out.shape[0] // engine.slots
                        out[slot * k1:(slot + 1) * k1] = np.nan
                return out
            self._wrap(engine, "_run_flat", run_flat)
        return self

    def uninstall(self) -> None:
        for obj, name, orig, had in reversed(self._undo):
            if had:
                setattr(obj, name, orig)
            else:
                delattr(obj, name)
        self._undo = []
        self._armed = []
        self._installed = None

    @contextlib.contextmanager
    def on(self, engine):
        """``with plan.on(engine): engine.drain()`` — install for the
        block, restore afterwards even if the drain raises."""
        self.install(engine)
        try:
            yield self
        finally:
            self.uninstall()

    def stats(self) -> dict:
        return {
            "seed": self.seed,
            "events": [(e.step, e.kind) for e in self.events],
            "fired": dict(self.fired),
        }
