"""Speculative decoding over the fused ragged step: drafters + acceptance.

The fused ``[slots, s]`` ragged step (PR 3) lets one row carry several new
positions per engine tick — which is exactly the **verify** primitive
speculative decoding needs.  A *drafter* proposes up to ``k`` cheap guesses
for a decoding request's next tokens; the engine feeds the row
``[fed-back token, d_1 .. d_k]`` (``new_counts = k+1``) through the one
pre-compiled paged step, reads the target model's logits at every draft
position in that single call (``logits_idx``), and accepts the longest
draft prefix the target itself would have produced.  Per accepted draft
the request advances one extra token for the same number of step launches
— the paper's fixed-shape-grid argument (fix the compiled shape once, let
per-row occupancy vary) extended from chunked prefill to speculation: one
step shape serves *any* per-row draft length, zero new traces after
warmup.

**The acceptance rule is token-identical to the baseline by construction.**
This engine's sampling is deterministic given the request: greedy picks
``argmax``, and sampled picks draw from a (seed, rid, position)-derived
key (see ``Engine._pick``), so the baseline's next token is a pure
function of (target logits at that position, request, position).  The
lossless rule is therefore *exact match against the baseline's own pick*:
at each position, compute the pick the non-speculative engine would have
made from the verify step's target logits, accept the draft token iff it
equals that pick, and stop at the first mismatch — the computed pick IS
the correction token (speculation never costs a step: the mismatching
position still yields the token the baseline would have produced, and a
fully-accepted draft yields a bonus pick from the logits after the last
draft).  This is standard rejection sampling conditioned on the engine's
pre-committed randomness stream: with the per-position key fixed, the
target's categorical draw is a point mass, ``min(1, p/q)`` acceptance
degenerates to equality with that draw, and any other rule would break
token identity.  Greedy is the ``argmax`` special case.  Outputs are
asserted bit-identical to the non-speculative engine in
``tests/test_speculative.py`` and ``benchmarks/bench_serving.py`` — the
drafter only ever changes *throughput*, never tokens, so drafters are free
to be wrong, stale, or heuristic.

Two drafters ship:

- :class:`NgramDrafter` — prompt-lookup / self-ngram speculation: match
  the request's trailing n-gram against earlier positions of its own
  prompt + generated text and propose the historical continuation.  No
  extra model, no state, no device work; strong on repetitive or
  copy-heavy continuations (summarization, code, the loops greedy toy
  models settle into), silent otherwise (an empty proposal degenerates the
  row to plain decode).
- :class:`DraftModelDrafter` — a smaller :class:`~repro.models.model.
  ReproModel` sharing the target's tokenizer (vocab) drafts greedily from
  its own dense per-request KV cache.  Catch-up tokens (prompt at first
  sight, then each step's correction/bonus) are fed in power-of-two binary
  decomposition chunks so the compile count stays ``log2(max_len)`` with
  no padded garbage writes; rejected speculative positions in the draft
  cache are reconciled by token comparison on the next propose, and a
  target-side preemption is invisible here (:func:`request_context` is
  fold-invariant, so the stream's content only ever grows).

Rollback of rejected KV lives with the engine: the verify step wrote K/V
for every fed position, so after acceptance the engine truncates the row's
block table back to the accepted length
(:meth:`~repro.serving.kv_cache.SequencePages.truncate`) — whole trailing
pages return to the pool through the double-free-checked allocator, stale
positions inside the kept last page are masked by ``lens + new_counts``
until the next write overwrites them.  Under the prefix cache the same
call upholds the sharing invariants: a shared trailing page merely loses
this request's reference, and a shared *kept* tail page is CoW-split
before the next verify step writes into it — rollback can never mutate a
page another request (or the cache) still reads.  Preemption composes for
free: ``out_tokens`` only ever holds accepted tokens, so a fold after a
verify step can never leak a rejected draft into the recompute prompt —
nor, for the same reason, can a rejected draft ever be inserted into the
prefix cache (preemption inserts only committed-KV pages).
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.layout import round_up
from repro.core.linear import prepack_params
from repro.obs.telemetry import NULL as _NULL_OBS
from repro.serving.scheduler import Request

__all__ = ["Drafter", "NgramDrafter", "DraftModelDrafter", "accept_tokens",
           "request_context"]


def request_context(req: Request) -> np.ndarray:
    """The request's true token stream: the (possibly fold-extended)
    prompt plus the generated tokens **not yet folded into it**.  A
    preemption *copies* ``out_tokens[:folded]`` into the prompt
    (``Scheduler._preempt``) and leaves ``out_tokens`` whole, so naively
    concatenating prompt + out_tokens would duplicate the folded prefix —
    corrupting n-gram lookups and a draft model's cache context.  With the
    ``folded`` watermark respected, the stream's content is invariant
    under preemption and only ever grows."""
    return np.concatenate([req.prompt,
                           np.asarray(req.out_tokens[req.folded:],
                                      np.int32)])


def accept_tokens(req: Request, drafts: List[int], logits_rows: np.ndarray,
                  n_eff: int, pick) -> Tuple[int, int]:
    """The acceptance rule (correctness-critical — see the module
    docstring for why exact-match against the engine's own deterministic
    pick is the lossless rule here).

    ``logits_rows``: [K, V] target logits from the verify step; row ``j``
    is the distribution after the row's j-th fed token (j=0: the fed-back
    token, j>=1: draft ``drafts[j-1]``).  ``n_eff`` fed tokens means rows
    ``0 .. n_eff-1`` are meaningful and ``drafts[:n_eff-1]`` were fed.
    ``pick(logits_row, req)`` must be the engine's baseline pick — it reads
    ``len(req.out_tokens)`` for the position key, so appends must happen
    here, between picks, exactly as the baseline interleaves them.

    Appends the accepted prefix plus the correction/bonus pick to
    ``req.out_tokens`` (stopping early at eos/max_new exactly where the
    baseline would) and returns ``(appended, accepted)``:
    ``appended - accepted`` is always 1 except on an early stop, and
    ``req.len`` is NOT advanced — the engine owns cache-length accounting.
    """
    # typed, -O-proof: a wrong verify width here would silently corrupt
    # the identity contract, not just crash — never let it be stripped
    if not 1 <= n_eff <= logits_rows.shape[0]:
        raise ValueError(
            f"accept_tokens: n_eff={n_eff} outside the verify rows "
            f"[1, {logits_rows.shape[0]}] for rid {req.rid}")
    if len(drafts) < n_eff - 1:
        raise ValueError(
            f"accept_tokens: {len(drafts)} drafts cannot cover "
            f"n_eff={n_eff} fed tokens for rid {req.rid}")
    appended = accepted = 0
    for j in range(n_eff):
        tok = pick(logits_rows[j], req)
        req.out_tokens.append(tok)
        appended += 1
        matched = j < n_eff - 1 and tok == drafts[j]
        if matched:
            accepted += 1
        if req.done() or not matched:
            break
    return appended, accepted


class Drafter:
    """Pluggable draft-token source for speculative decoding.

    Contract: :meth:`propose` returns up to ``k`` int token guesses for the
    continuation of ``req`` after ``req.out_tokens[-1]`` — fewer (or none)
    whenever it has nothing confident to say; a wrong guess costs only the
    padded verify compute, never a token (the acceptance rule is lossless).
    Drafters may keep per-request state keyed by ``req.rid``; the engine
    calls :meth:`forget` when a request finishes and :meth:`warmup` from
    ``Engine.warmup()`` so a stateful drafter can pre-compile its own step
    shapes (the zero-recompile-after-warmup contract covers the drafter
    too).
    """

    # telemetry (repro.obs): the engine swaps in its live recorder after
    # attach(); the class default keeps standalone drafters silent
    obs = _NULL_OBS

    def attach(self, engine) -> None:
        """Bind engine-derived sizing/validation (called from Engine)."""

    def warmup(self) -> None:
        """Pre-compile any drafter-side step shapes."""

    def propose(self, req: Request, k: int) -> List[int]:
        raise NotImplementedError

    def propose_all(self, jobs: List[Tuple[Request, int]]) -> dict:
        """``{rid: drafts}`` for one engine step's decoding rows at once.
        The base implementation loops :meth:`propose`; a model-backed
        drafter overrides it to batch rows through its own step (one
        ``[slots, 1]`` call per draft position instead of ``k`` sequential
        ``[1, 1]`` calls per row)."""
        out = {req.rid: self.propose(req, k) for req, k in jobs}
        self.obs.draft_batch(len(jobs), sum(len(d) for d in out.values()))
        return out

    def forget(self, rid: int) -> None:
        """Drop per-request state (the request finished)."""

    def stats(self) -> dict:
        return {}


class NgramDrafter(Drafter):
    """Prompt-lookup / self-ngram speculation (no draft model).

    Matches the trailing ``n``-gram of the request's own context (prompt +
    generated tokens) against every earlier position, longest ``n`` first,
    most recent match wins, and proposes the tokens that followed the
    match.  This is the assisted-generation "prompt lookup" trick: on
    copy-heavy continuations the context is its own excellent draft model,
    and it costs a numpy sliding-window compare per step — no weights, no
    device work, no per-request state.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert 1 <= min_ngram <= max_ngram
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.proposals = 0           # propose() calls that returned tokens
        self.misses = 0              # propose() calls with no match

    def propose(self, req: Request, k: int) -> List[int]:
        ctx = request_context(req)
        size = int(ctx.shape[0])
        for n in range(min(self.max_ngram, size - 1), self.min_ngram - 1, -1):
            tail = ctx[size - n:]
            win = np.lib.stride_tricks.sliding_window_view(ctx, n)
            # candidate starts end strictly before the tail's own window
            hits = np.flatnonzero((win[:size - n] == tail).all(axis=1))
            if hits.size:
                start = int(hits[-1]) + n       # most recent occurrence
                self.proposals += 1
                return [int(t) for t in ctx[start:start + k]]
        self.misses += 1
        return []

    def stats(self) -> dict:
        return {"drafter": "ngram", "max_ngram": self.max_ngram,
                "proposals": self.proposals, "misses": self.misses}


class DraftModelDrafter(Drafter):
    """Greedy draft proposals from a smaller model sharing the tokenizer.

    Keeps one dense ``[1, max_len]`` KV cache per live request (the draft
    model is small — that is the point — so dense per-request state is
    cheap where the target's must be paged).  Each :meth:`propose`:

      1. reconciles: positions the previous propose wrote speculatively are
         kept only while their tokens match what the target actually
         accepted (rejected positions are simply re-fed — the dense cache's
         next write at a position overwrites the stale K/V and the
         ``cache_pos``-derived mask hides anything beyond);
      2. catches up: feeds context tokens the draft cache hasn't seen
         (the whole prompt on first sight; afterwards the correction/bonus
         token(s) of the last verify) in **binary-decomposition chunks** —
         widths are the powers of two in the remainder, so every width is
         one of ``log2(max_len)`` pre-compiled shapes and nothing is ever
         padded;
      3. drafts: ``k`` greedy single-token steps (``[1, 1]``), returning
         the argmax chain.

    The draft model must be pure-attention (a recurrent scan could not
    reconcile rejected speculative state by overwrite) and share the
    target's vocab.  Wall-clock spent here is the "draft overhead" the
    engine reports; acceptance quality is whatever the small model earns —
    the rule in :func:`accept_tokens` keeps tokens identical regardless.
    """

    def __init__(self, model, params, *, prepack: bool = True):
        assert all(t == "attn" for t in model.cfg.layer_types), \
            f"draft model {model.cfg.name}: recurrent mixers cannot " \
            f"reconcile rejected speculative state by overwrite — " \
            f"speculative drafting needs a pure-attention draft model"
        self.model = model
        self.params = (prepack_params(params, model.ctx) if prepack
                       else params)
        self._step = model.jit_step("decode")
        self.max_len = model.shape.seq_len
        self._state: dict = {}       # rid -> {caches, ctx_len, spec}
        self.draft_steps = 0         # draft-model step launches
        # batched (attached) mode: one paged draft cache shared by every
        # live request — one page per draft row, rid -> row map below
        self._paged = None
        self._caches = None
        self._rows: dict = {}        # rid -> draft row
        self._lru: dict = {}         # rid -> last propose tick
        self._tick = 0

    def attach(self, engine) -> None:
        assert self.model.cfg.vocab == engine.model.cfg.vocab, \
            f"draft model vocab {self.model.cfg.vocab} != target vocab " \
            f"{engine.model.cfg.vocab} — drafter and target must share " \
            f"the tokenizer"
        # widest context the draft cache must hold: the target's context
        # limit plus the final pick plus k-1 speculative writes
        self.max_len = engine.scheduler.max_len + engine.spec_tokens + 1
        # batched drafting state: the draft model's own *paged* step (its
        # per-row lens are what let rows at different positions share one
        # call), one page per draft row sized to hold a whole stream, and
        # a [rows, 1] static block table (row r -> page 1 + r; page 0
        # stays the trash page for inert rows)
        self._slots = engine.slots
        layout = self.model.ctx.layout(self.model.compute_dtype)
        self._page_tokens = round_up(self.max_len, layout.m_r)
        self._paged = self.model.jit_step("paged")
        self._caches = None          # device alloc deferred to first use

    def _widths(self) -> List[int]:
        w, out = 1, []
        while w <= self.max_len:
            out.append(w)
            w *= 2
        return out

    def warmup(self) -> None:
        """Compile every catch-up width — batched (attached): the
        ``[rows, w]`` ragged paged shapes, ``[rows, 1]`` included (w=1);
        standalone: the dense ``[1, w]`` shapes against a scratch cache."""
        if self._paged is not None:
            self._ensure_caches()
            zb = jnp.zeros((self._slots,), jnp.int32)
            btz = jnp.zeros((self._slots, 1), jnp.int32)
            for w in self._batch_widths():
                _, self._caches = self._paged(
                    self.params, self._caches,
                    jnp.zeros((self._slots, w), jnp.int32), btz, zb, zb,
                    None)
            return
        for w in self._widths():
            caches = self.model.init_cache(1, self.max_len)
            self._step(self.params, caches,
                       jnp.zeros((1, w), jnp.int32), jnp.int32(0))

    def _batch_widths(self) -> List[int]:
        """Batched catch-up widths: powers of two up to the pow2 *ceiling*
        of ``max_len`` — the batched path feeds the whole widest catch-up
        in one ragged call (per-row padding goes to the trash page), so
        the top width can exceed ``max_len``, unlike the per-row binary
        decomposition whose widths never do."""
        w, out = 1, []
        while True:
            out.append(w)
            if w >= self.max_len:
                return out
            w *= 2

    def _ensure_caches(self) -> None:
        if self._caches is None:
            self._caches = self.model.init_paged_cache(
                1 + self._slots, self._page_tokens, self._slots)

    def _row_for(self, rid: int, job_rids: set) -> int:
        """The draft row (page) backing ``rid``, allocating on first sight.
        When every row is taken, evict the least-recently-proposing state
        that is *not* in this step's jobs (it re-feeds its context on next
        sight — stale page KV is invisible behind its fresh lens).  A
        victim always exists: live jobs never exceed the engine's slots."""
        if rid in self._rows:
            return self._rows[rid]
        taken = set(self._rows.values())
        free = [r for r in range(self._slots) if r not in taken]
        if free:
            row = free[0]
        else:
            victim = min((r for r in self._rows if r not in job_rids),
                         key=lambda r: self._lru.get(r, -1))
            row = self._rows.pop(victim)
            self._state.pop(victim, None)
            self._lru.pop(victim, None)
        self._rows[rid] = row
        return row

    def propose_all(self, jobs: List[Tuple[Request, int]]) -> dict:
        """Batched drafting (attached engines): every decoding row's
        catch-up rides ONE ragged ``[rows, w]`` paged call (per-row lens;
        padding routed to the trash page), then each draft position is ONE
        batched ``[rows, 1]`` greedy step — ``1 + (k-1)`` device launches
        per engine step instead of the per-row loop's
        ``rows * (catchup + k - 1)``.  Tokens are identical to the per-row
        path: same reconcile, same greedy argmax chain, row-independent
        attention."""
        if self._paged is None or not jobs:
            return super().propose_all(jobs)
        self._ensure_caches()
        self._tick += 1
        job_rids = {req.rid for req, _ in jobs}
        plans = []
        for req, k in jobs:
            row = self._row_for(req.rid, job_rids)
            self._lru[req.rid] = self._tick
            st = self._state.get(req.rid)
            if st is None:
                st = {"ctx_len": 0, "spec": np.zeros((0,), np.int32)}
                self._state[req.rid] = st
            ctx = request_context(req)
            size = int(ctx.shape[0])
            # reconcile + the start-one-token-early trick, exactly as in
            # the per-row path (see propose)
            base, spec = st["ctx_len"], st["spec"]
            m = 0
            while (m < spec.shape[0] and base + m < size
                   and spec[m] == ctx[base + m]):
                m += 1
            start = min(base + m, size - 1)
            plans.append({"row": row, "req": req, "k": k, "ctx": ctx,
                          "size": size, "start": start, "st": st})
        # one ragged catch-up call at the pow2 width of the widest row
        maxn = max(p["size"] - p["start"] for p in plans)
        w = 1
        while w < maxn:
            w *= 2
        rows_n = self._slots
        token = np.zeros((rows_n, w), np.int32)
        lens = np.zeros((rows_n,), np.int32)
        counts = np.zeros((rows_n,), np.int32)
        bt = np.zeros((rows_n, 1), np.int32)
        for p in plans:
            r, n = p["row"], p["size"] - p["start"]
            token[r, :n] = p["ctx"][p["start"]:p["size"]]
            lens[r] = p["start"]
            counts[r] = n
            bt[r, 0] = 1 + r
        logits = self._run_batch(token, bt, lens, counts)
        drafted = {p["row"]: [] for p in plans}
        kmax = max(p["k"] for p in plans)
        for j in range(kmax):
            for p in plans:
                if j < p["k"]:
                    drafted[p["row"]].append(
                        int(np.argmax(logits[p["row"], 0])))
            if j == kmax - 1:
                break                # the last draft's KV is never needed
            token = np.zeros((rows_n, 1), np.int32)
            lens = np.zeros((rows_n,), np.int32)
            counts = np.zeros((rows_n,), np.int32)
            for p in plans:
                r = p["row"]
                if j + 1 >= p["k"]:
                    continue         # this row is done: inert this call
                token[r, 0] = drafted[r][-1]
                lens[r] = p["size"] + j
                counts[r] = 1
            logits = self._run_batch(token, bt, lens, counts)
        out = {}
        for p in plans:
            d = drafted[p["row"]]
            st = p["st"]
            st["ctx_len"] = p["size"]
            # positions written beyond the committed context: all but the
            # last proposed token
            st["spec"] = np.asarray(d[:-1], np.int32)
            out[p["req"].rid] = d
        self.obs.draft_batch(len(jobs), sum(len(d) for d in out.values()))
        return out

    def _run_batch(self, token, bt, lens, counts) -> np.ndarray:
        logits, self._caches = self._paged(
            self.params, self._caches, jnp.asarray(token), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(counts), None)
        self.draft_steps += 1
        return np.asarray(logits)

    def propose(self, req: Request, k: int) -> List[int]:
        ctx = request_context(req)
        size = int(ctx.shape[0])
        st = self._state.get(req.rid)
        if st is None:
            st = {"caches": self.model.init_cache(1, self.max_len),
                  "ctx_len": 0, "spec": np.zeros((0,), np.int32)}
            self._state[req.rid] = st
        # reconcile: speculative positions survive while their tokens match
        # the context the target actually committed
        base, spec = st["ctx_len"], st["spec"]
        m = 0
        while (m < spec.shape[0] and base + m < size
               and spec[m] == ctx[base + m]):
            m += 1
        valid = base + m
        # start one token early when the speculative cache already covers
        # the whole context (the engine shed/trimmed a draft whose tokens
        # it then committed anyway): logits from the previous propose were
        # discarded, so re-feed the final context token — an identical
        # overwrite of its KV — to recover the distribution to draft from
        start = min(valid, size - 1)
        caches, pos = st["caches"], start
        logits = None
        i = start
        while i < size:                      # catch-up, binary decomposition
            w = 1
            while w * 2 <= size - i:
                w *= 2
            tok = jnp.asarray(ctx[None, i:i + w])
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(pos))
            self.draft_steps += 1
            pos += w
            i += w
        drafted: List[int] = []
        for j in range(k):
            t = int(np.argmax(np.asarray(logits[0, -1])))
            drafted.append(t)
            if j == k - 1:
                break                # the last draft's KV is never needed
            logits, caches = self._step(self.params, caches,
                                        jnp.asarray([[t]]), jnp.int32(pos))
            self.draft_steps += 1
            pos += 1
        st["caches"] = caches
        st["ctx_len"] = size
        # positions written beyond the committed context: all but the last
        st["spec"] = np.asarray(drafted[:-1], np.int32)
        return drafted

    def forget(self, rid: int) -> None:
        self._state.pop(rid, None)
        self._rows.pop(rid, None)
        self._lru.pop(rid, None)

    def stats(self) -> dict:
        return {"drafter": "draft-model", "model": self.model.cfg.name,
                "draft_steps": self.draft_steps,
                "live_states": len(self._state)}
