"""Paged, layout-aware KV cache for continuous-batching serving.

The decode KV cache is a pool of fixed-size **pages** shared by all live
requests; each request owns an ordered list of page ids (its *block table*)
covering logical positions ``0 .. len-1``.  Admitting a request allocates
pages for its *prompt only*; each decode step grows the block table
incrementally (:meth:`SequencePages.ensure`), and finishing a request
returns its pages — sequences of different lengths coexist without padding
the cache to a common length, and pool capacity is consumed by tokens that
actually exist rather than by reserved lifetimes (the scheduler handles
exhaustion by preempting, see :mod:`repro.serving.scheduler`).

The allocator tracks the set of live page ids, so a double-free or a free
of a never-allocated page — either of which would eventually hand one page
to two requests and silently cross their KV streams — fails loudly at the
``free`` call instead.

The page size is derived from the active :class:`~repro.core.layout.
PackedLayout`: ``page_tokens = round_up(requested, m_r)``, so a page always
holds a whole number of microkernel M-tiles and decode attention reads
tiles the mmt4d kernels can consume directly — the paper's amortized
prepacking argument (§4.1) extended from weights to KV pages.  Chunked
prefill (``Engine(chunk_tokens=...)``) keeps the same alignment on the
write side: chunk sizes are rounded up to ``m_r`` too, so every chunk
lands as whole tiles and a paused prefill's held pages stay valid KV
(positions ``0..cursor-1``) across a displacement — only ``release()``
invalidates them.

Device-side pool arrays live inside the engine's cache pytree
(``{"k_pages","v_pages"}: [G, P, T, Hkv, dh]``, built by
``transformer.init_paged_caches``); this module owns the host-side
bookkeeping (allocator, per-request block tables) plus the pytree helpers
that separate shared page pools from per-slot recurrent state.

Page 0 is reserved as the **trash page**: padded prefill positions and
inactive decode slots scatter their (masked-out) K/V there, so a fixed-shape
step can never corrupt a live request.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import PackedLayout, ceil_div, round_up

__all__ = ["OutOfPages", "PagedKVPool", "SequencePages",
           "fresh_slot_states", "prefill_view", "merge_slot",
           "map_slot_states"]


class OutOfPages(RuntimeError):
    """The pool cannot satisfy an allocation (admission must wait)."""


class PagedKVPool:
    """Host-side page allocator for the device page pool.

    ``page_tokens`` is rounded up to a multiple of the layout's ``m_r`` so
    page boundaries coincide with packed-tile boundaries.  Page 0 is the
    trash page and is never handed out.
    """

    def __init__(self, num_pages: int, page_tokens: int,
                 layout: Optional[PackedLayout] = None):
        if layout is not None:
            page_tokens = round_up(page_tokens, layout.m_r)
        assert num_pages >= 2, "need at least the trash page + one real page"
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        # LIFO free list → recently-freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._allocated: set = set()
        # allocator stats (cumulative; peak_used drives pool-sizing decisions)
        self.total_allocs = 0
        self.total_frees = 0
        self.peak_used = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def pages_for(self, tokens: int) -> int:
        return ceil_div(max(0, tokens), self.page_tokens)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.num_free

    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages("KV pool exhausted")
        p = self._free.pop()
        self._allocated.add(p)
        self.total_allocs += 1
        self.peak_used = max(self.peak_used, self.num_used)
        return p

    def free(self, pages: Iterable[int]) -> None:
        for p in pages:
            assert 0 < p < self.num_pages, p
            assert p in self._allocated, \
                f"page {p} freed twice (or never allocated) — a double-free " \
                f"hands one page to two requests and crosses their KV"
            self._allocated.remove(p)
            self._free.append(p)
            self.total_frees += 1

    def stats(self) -> dict:
        return {"num_pages": self.num_pages, "page_tokens": self.page_tokens,
                "num_used": self.num_used, "num_free": self.num_free,
                "peak_used": self.peak_used, "total_allocs": self.total_allocs,
                "total_frees": self.total_frees}


@dataclasses.dataclass
class SequencePages:
    """One request's block table: ordered page ids covering 0..len-1."""

    pool: PagedKVPool
    pages: List[int] = dataclasses.field(default_factory=list)

    @property
    def capacity(self) -> int:
        return len(self.pages) * self.pool.page_tokens

    def ensure(self, tokens: int) -> None:
        """Grow the block table to cover ``tokens`` logical positions.
        All-or-nothing: a partial allocation is rolled back on failure."""
        start = len(self.pages)
        try:
            while self.capacity < tokens:
                self.pages.append(self.pool.alloc())
        except OutOfPages:
            self.pool.free(self.pages[start:])
            del self.pages[start:]
            raise

    def release(self) -> None:
        self.pool.free(self.pages)
        self.pages = []

    def truncate(self, tokens: int) -> int:
        """Shrink the block table to cover ``tokens`` logical positions,
        freeing whole trailing pages — the speculative-decode rollback:
        rejected draft positions past ``tokens`` either share the last kept
        page (their stale K/V is masked by ``lens + new_counts`` and
        overwritten by the next write at that position) or sit in trailing
        pages this returns to the pool.  Pages stay ``m_r``-aligned whole
        tiles — truncation only ever drops whole pages, never splits one —
        and the frees go through the pool's double-free accounting like any
        release.  Returns the number of pages freed."""
        keep = self.pool.pages_for(tokens)
        dropped = self.pages[keep:]
        self.pool.free(dropped)
        del self.pages[keep:]
        return len(dropped)

    def block_row(self, max_pages: int) -> np.ndarray:
        assert len(self.pages) <= max_pages, (len(self.pages), max_pages)
        row = np.zeros((max_pages,), np.int32)
        row[:len(self.pages)] = self.pages
        return row


# ---------------------------------------------------------------------------
# cache-pytree helpers: page pools are shared, recurrent state is per-slot
# ---------------------------------------------------------------------------

def map_slot_states(caches, fn):
    """Apply ``fn`` to per-slot recurrent leaves ([G, slots, ...]); pass the
    shared ``*_pages`` pool leaves through unchanged."""
    if isinstance(caches, dict):
        return {k: (v if k.endswith("_pages") else map_slot_states(v, fn))
                for k, v in caches.items()}
    return fn(caches)


def fresh_slot_states(caches):
    """A zeroed single-slot ([G, 1, ...]) recurrent-state tree matching
    ``caches`` — the state a request starts prefill from."""
    return map_slot_states(
        caches, lambda x: jnp.zeros(x.shape[:1] + (1,) + x.shape[2:], x.dtype))


def prefill_view(caches, fresh):
    """Single-slot cache view for prefill: shared pools from ``caches``,
    recurrent state from the zeroed single-slot tree ``fresh``."""
    if isinstance(caches, dict):
        return {k: (v if k.endswith("_pages") else prefill_view(v, fresh[k]))
                for k, v in caches.items()}
    return fresh


def merge_slot(caches, updated, slot: int):
    """Merge a prefill result back: pools are taken from ``updated`` (pages
    were written there), the [G, 1, ...] recurrent state is written into row
    ``slot`` of the full tree."""
    if isinstance(caches, dict):
        return {k: (updated[k] if k.endswith("_pages")
                    else merge_slot(v, updated[k], slot))
                for k, v in caches.items()}
    return jax.lax.dynamic_update_slice_in_dim(
        caches, updated.astype(caches.dtype), slot, axis=1)
