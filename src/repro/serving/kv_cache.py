"""Paged, layout-aware KV cache for continuous-batching serving.

The decode KV cache is a pool of fixed-size **pages** shared by all live
requests; each request owns an ordered list of page ids (its *block table*)
covering logical positions ``0 .. len-1``.  Admitting a request allocates
pages for its *prompt only*; each decode step grows the block table
incrementally (:meth:`SequencePages.ensure`), and finishing a request
returns its pages — sequences of different lengths coexist without padding
the cache to a common length, and pool capacity is consumed by tokens that
actually exist rather than by reserved lifetimes (the scheduler handles
exhaustion by preempting, see :mod:`repro.serving.scheduler`).

The allocator tracks a **refcount** per live page id (PR 5): a page may be
shared byte-for-byte by several requests and by the prefix cache
(:mod:`repro.serving.prefix_cache`), ``free`` drops one reference, and the
page returns to the free list only at refcount zero.  A free of a page with
no outstanding references — which would eventually hand one page to two
requests and silently cross their KV streams — still fails loudly at the
``free`` call, shared pages included.

Sharing rests on three invariants, spelled out here because every layer of
the serving stack leans on them:

  - **pages are immutable once full** — the paged step only ever writes
    positions ``lens .. lens + new_counts - 1``, so a page whose every
    token is committed is never touched again (truncation is the one
    exception, handled next); only such full pages enter the prefix cache;
  - **copy-on-write before any in-place write** — a partially-filled page
    about to be written (the admission cursor landing mid-page on a
    fully-cached prompt, or a speculative rollback truncating into a kept
    tail page) must be private first: :meth:`PagedKVPool.cow` allocates a
    fresh page, device-copies the contents, and swaps it into the block
    table, so no shared page is ever written in place;
  - **cache keys include the layout** — pages are whole ``m_r``-aligned
    microkernel tiles, so the prefix-cache hash chain is rooted in
    ``(m_r, page_tokens)`` and a layout change can never alias stale KV.

The page size is derived from the active :class:`~repro.core.layout.
PackedLayout`: ``page_tokens = round_up(requested, m_r)``, so a page always
holds a whole number of microkernel M-tiles and decode attention reads
tiles the mmt4d kernels can consume directly — the paper's amortized
prepacking argument (§4.1) extended from weights to KV pages.  Chunked
prefill (``Engine(chunk_tokens=...)``) keeps the same alignment on the
write side: chunk sizes are rounded up to ``m_r`` too, so every chunk
lands as whole tiles and a paused prefill's held pages stay valid KV
(positions ``0..cursor-1``) across a displacement — only ``release()``
invalidates them.

Device-side pool arrays live inside the engine's cache pytree
(``{"k_pages","v_pages"}: [G, P, T, Hkv, dh]``, built by
``transformer.init_paged_caches``); this module owns the host-side
bookkeeping (allocator, per-request block tables) plus the pytree helpers
that separate shared page pools from per-slot recurrent state.

Page 0 is reserved as the **trash page**: padded prefill positions and
inactive decode slots scatter their (masked-out) K/V there, so a fixed-shape
step can never corrupt a live request.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import PackedLayout, ceil_div, round_up
from repro.obs.telemetry import NULL as _NULL_OBS

__all__ = ["PoolError", "OutOfPages", "PagedKVPool", "SequencePages",
           "copy_pages", "fresh_slot_states", "prefill_view", "merge_slot",
           "map_slot_states"]


class PoolError(RuntimeError):
    """An allocator contract violation (double-free, foreign free, sharing
    a dead page) or allocation failure.  Raised explicitly — unlike the
    ``assert`` statements it replaced, the check survives ``python -O``,
    because a refcount bug in a production drain silently crossing two
    requests' KV streams is exactly the failure mode optimized runs must
    still catch.  The message carries the diagnostic payload (page id,
    refcount, owner rids via :meth:`PagedKVPool.holders`)."""


class OutOfPages(PoolError):
    """The pool cannot satisfy an allocation (admission must wait)."""


class PagedKVPool:
    """Host-side refcounting page allocator for the device page pool.

    ``page_tokens`` is rounded up to a multiple of the layout's ``m_r`` so
    page boundaries coincide with packed-tile boundaries.  Page 0 is the
    trash page (``reserved_pages``) and is never handed out — every
    capacity question should use :attr:`usable_pages`, not ``num_pages``.

    Sharing (PR 5): :meth:`alloc` hands out a page at refcount 1,
    :meth:`share` adds a reference (a prefix-cache hit handing the page to
    a second request, or the cache registering its own claim), and
    :meth:`free` drops one — the page returns to the free list only at
    refcount zero.  A page with refcount > 1 is **read-only** (see the
    module docstring); :meth:`cow` is the copy-on-write split that makes a
    shared page writable again.  Two optional hooks integrate the prefix
    cache without the allocator knowing its structure:

      - ``reclaimer``: an object with ``evictable() -> int`` and
        ``evict(n) -> int``; :meth:`alloc` calls ``evict(1)`` on an empty
        free list before raising, so cache-held pages are always
        reclaimable under pool pressure — the scheduler's "a solo request
        fits" termination invariant survives the cache holding pages;
      - ``page_copier``: ``fn(src, dst)`` performing the device-side page
        copy :meth:`cow` needs (the engine owns the cache pytree).
    """

    def __init__(self, num_pages: int, page_tokens: int,
                 layout: Optional[PackedLayout] = None):
        if layout is not None:
            page_tokens = round_up(page_tokens, layout.m_r)
        assert num_pages >= 2, "need at least the trash page + one real page"
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.reserved_pages = 1          # page 0: the trash page
        # LIFO free list → recently-freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}   # page id -> outstanding references
        self._seqs: "weakref.WeakSet[SequencePages]" = weakref.WeakSet()
        # allocator stats (cumulative; peak_used drives pool-sizing decisions)
        self.total_allocs = 0
        self.total_shares = 0
        self.total_frees = 0
        self.peak_used = 0
        self.cow_copies = 0
        self.reclaimer = None            # prefix cache, when enabled
        self.page_copier = None          # engine-installed device page copy
        self.obs = _NULL_OBS             # telemetry; engine swaps in a live one

    @property
    def usable_pages(self) -> int:
        """Pages that can ever hold live KV (reserved pages excluded)."""
        return self.num_pages - self.reserved_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.usable_pages - len(self._free)

    @property
    def num_available(self) -> int:
        """Free pages plus cache-held pages reclaimable on demand — the
        number an admission/growth decision may count on, since
        :meth:`alloc` evicts from the cache before giving up."""
        extra = self.reclaimer.evictable() if self.reclaimer is not None else 0
        return len(self._free) + extra

    def pages_for(self, tokens: int) -> int:
        return ceil_div(max(0, tokens), self.page_tokens)

    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.num_available

    def ref(self, page: int) -> int:
        """Outstanding references to ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def is_shared(self, page: int) -> bool:
        return self._ref.get(page, 0) > 1

    def sequences(self) -> List["SequencePages"]:
        """Live block tables registered with this pool (weakly held)."""
        return [s for s in self._seqs]

    def holders(self, page: int) -> List:
        """Owner ids (request ids, where known) of the live sequences whose
        block table holds ``page`` — the context a double-free / sanitizer
        diagnostic needs in the middle of a long drain."""
        return sorted({s.owner for s in self._seqs
                       if s.owner is not None and page in s.pages})

    def ledger(self) -> dict:
        """Read-only snapshot of the allocator state for external audits
        (:func:`repro.analysis.aliasing.check_pool_consistency`): the
        refcount map and the free list.  Copies — mutating the allocator
        stays the privilege of this module (enforced by the AST lint's
        allocator-privacy rule)."""
        return {"refs": dict(self._ref), "free": list(self._free)}

    def alloc(self) -> int:
        if not self._free and self.reclaimer is not None:
            # LRU eviction under pool pressure: cached-but-unreferenced
            # pages are reclaimable, so a cache can never deadlock a drain
            self.reclaimer.evict(1)
        if not self._free:
            raise OutOfPages("KV pool exhausted")
        p = self._free.pop()
        self._ref[p] = 1
        self.total_allocs += 1
        self.peak_used = max(self.peak_used, self.num_used)
        return p

    def share(self, pages: Iterable[int]) -> None:
        """Add one reference to each page (it must be live).  The new
        holder sees the page read-only: shared pages are never written in
        place (:meth:`cow` first)."""
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise PoolError(
                    f"page {p} shared while not allocated (ref=0, holders: "
                    f"{self.holders(p) or 'none'}) — sharing a dead page "
                    f"would resurrect freed KV")
            self._ref[p] += 1
            self.total_shares += 1

    def free(self, pages: Iterable[int]) -> None:
        for p in pages:
            if not 0 < p < self.num_pages:
                raise PoolError(
                    f"page {p} freed outside the pool's usable range "
                    f"1..{self.num_pages - 1} (page 0 is the trash page)")
            if p not in self._ref:
                raise PoolError(
                    f"page {p} freed twice (or never allocated): ref="
                    f"{self._ref.get(p, 0)}, still held by requests "
                    f"{self.holders(p) or 'none'} — a double-free hands one "
                    f"page to two requests and crosses their KV")
            self._ref[p] -= 1
            self.total_frees += 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)

    def cow(self, seq: "SequencePages", idx: int) -> int:
        """Copy-on-write split of ``seq.pages[idx]``: if the page is
        shared, allocate a private copy (device contents copied via
        ``page_copier``), swap it into the block table, and drop the
        sequence's reference on the original — the other holders keep the
        immutable original, the sequence gets a writable twin.  No-op on an
        unshared page.  Returns the (possibly new) page id; may raise
        :class:`OutOfPages` like any allocation."""
        old = seq.pages[idx]
        if self._ref.get(old, 0) <= 1:
            return old
        new = self.alloc()
        if self.page_copier is not None:
            try:
                self.page_copier(old, new)
            except Exception as e:
                # a failed device copy must not leak the fresh page or
                # leave a half-copied page in the block table; surface a
                # typed error the caller can degrade on (prefix-cache
                # fallback re-prefills, the engine quarantines)
                self.free([new])
                raise PoolError(
                    f"page_copier failed copying page {old} -> {new} "
                    f"(holders of {old}: {self.holders(old) or 'none'}): "
                    f"{e}") from e
        seq.pages[idx] = new
        self.free([old])
        self.cow_copies += 1
        self.obs.cow()
        return new

    def stats(self) -> dict:
        """Allocator counters.  ``free_pages``/``usable_pages`` exclude the
        reserved trash page consistently (``num_pages`` does not), so cache
        occupancy ratios have a correct denominator; ``pages_per_request``
        is the mean block-table length over live sequences — the
        per-request share of the pool the aggregate counters hide."""
        live = [len(s.pages) for s in self._seqs if s.pages]
        return {"num_pages": self.num_pages, "page_tokens": self.page_tokens,
                "reserved_pages": self.reserved_pages,
                "usable_pages": self.usable_pages,
                "num_used": self.num_used, "num_free": self.num_free,
                "free_pages": self.num_free,
                "live_requests": len(live),
                "pages_per_request": (sum(live) / len(live)) if live else 0.0,
                "shared_pages": sum(1 for r in self._ref.values() if r > 1),
                "peak_used": self.peak_used, "total_allocs": self.total_allocs,
                "total_shares": self.total_shares,
                "total_frees": self.total_frees,
                "cow_copies": self.cow_copies}


@dataclasses.dataclass(eq=False)
class SequencePages:
    """One request's block table: ordered page ids covering 0..len-1.

    Entries may be *shared* (prefix-cache hits: refcount > 1, read-only —
    always a prefix of the table, since writes only ever append past the
    cached cursor); :meth:`release`/:meth:`truncate` drop references, not
    necessarily pages.  ``eq=False`` keeps identity hashing so the pool's
    weak registry (``stats()["pages_per_request"]``) can track live
    tables.  ``owner`` (the scheduler sets it to the request id) exists
    purely for diagnostics: allocator asserts and the runtime sanitizer
    name the requests holding a page via :meth:`PagedKVPool.holders`."""

    pool: PagedKVPool
    pages: List[int] = dataclasses.field(default_factory=list)
    owner: Optional[int] = None

    def __post_init__(self):
        self.pool._seqs.add(self)

    @property
    def capacity(self) -> int:
        return len(self.pages) * self.pool.page_tokens

    def ensure(self, tokens: int) -> None:
        """Grow the block table to cover ``tokens`` logical positions.
        All-or-nothing: a partial allocation is rolled back on failure."""
        start = len(self.pages)
        try:
            while self.capacity < tokens:
                self.pages.append(self.pool.alloc())
        except OutOfPages:
            self.pool.free(self.pages[start:])
            del self.pages[start:]
            raise

    def release(self) -> None:
        self.pool.free(self.pages)
        self.pages = []

    def truncate(self, tokens: int) -> int:
        """Shrink the block table to cover ``tokens`` logical positions,
        freeing whole trailing pages — the speculative-decode rollback:
        rejected draft positions past ``tokens`` either share the last kept
        page (their stale K/V is masked by ``lens + new_counts`` and
        overwritten by the next write at that position) or sit in trailing
        pages this returns to the pool.  Pages stay ``m_r``-aligned whole
        tiles — truncation only ever drops whole pages, never splits one —
        and the frees go through the pool's double-free accounting like any
        release (a shared trailing page just loses this table's reference).

        A **shared** page is never truncated into: when ``tokens`` lands
        mid-page and the kept tail page is shared, the next write at
        position ``tokens`` would mutate it in place under the other
        holders — so it is CoW-split first (the engine's normal flows keep
        shared pages behind the cursor and this never fires, but the
        rollback path must be safe against any caller).  Returns the number
        of page references dropped."""
        keep = self.pool.pages_for(tokens)
        dropped = self.pages[keep:]
        self.pool.free(dropped)
        del self.pages[keep:]
        if keep and tokens % self.pool.page_tokens:
            self.pool.cow(self, keep - 1)
        return len(dropped)

    def block_row(self, max_pages: int) -> np.ndarray:
        assert len(self.pages) <= max_pages, (len(self.pages), max_pages)
        row = np.zeros((max_pages,), np.int32)
        row[:len(self.pages)] = self.pages
        return row


# ---------------------------------------------------------------------------
# cache-pytree helpers: page pools are shared, recurrent state is per-slot
# ---------------------------------------------------------------------------

def map_slot_states(caches, fn):
    """Apply ``fn`` to per-slot recurrent leaves ([G, slots, ...]); pass the
    shared ``*_pages`` pool leaves through unchanged."""
    if isinstance(caches, dict):
        return {k: (v if k.endswith("_pages") else map_slot_states(v, fn))
                for k, v in caches.items()}
    return fn(caches)


def fresh_slot_states(caches):
    """A zeroed single-slot ([G, 1, ...]) recurrent-state tree matching
    ``caches`` — the state a request starts prefill from."""
    return map_slot_states(
        caches, lambda x: jnp.zeros(x.shape[:1] + (1,) + x.shape[2:], x.dtype))


def prefill_view(caches, fresh):
    """Single-slot cache view for prefill: shared pools from ``caches``,
    recurrent state from the zeroed single-slot tree ``fresh``."""
    if isinstance(caches, dict):
        return {k: (v if k.endswith("_pages") else prefill_view(v, fresh[k]))
                for k, v in caches.items()}
    return fresh


def _copy_pages(caches, src, dst):
    def rec(node):
        if isinstance(node, dict):
            return {k: (v.at[:, dst].set(v[:, src]) if k.endswith("_pages")
                        else rec(v))
                    for k, v in node.items()}
        return node
    return rec(caches)


copy_pages = jax.jit(_copy_pages, donate_argnums=(0,))
copy_pages.__doc__ = """Device-side page copy for copy-on-write splits:
duplicate page ``src``'s contents into ``dst`` in every ``*_pages`` pool
leaf ([G, P, T, Hkv, dh]; page dim = axis 1) of the cache pytree, leaving
per-slot recurrent state untouched.  One jitted program per cache
structure (the engine primes it at warmup), with the input donated so the
pool is updated in place rather than doubled."""


def merge_slot(caches, updated, slot: int):
    """Merge a prefill result back: pools are taken from ``updated`` (pages
    were written there), the [G, 1, ...] recurrent state is written into row
    ``slot`` of the full tree."""
    if isinstance(caches, dict):
        return {k: (updated[k] if k.endswith("_pages")
                    else merge_slot(v, updated[k], slot))
                for k, v in caches.items()}
    return jax.lax.dynamic_update_slice_in_dim(
        caches, updated.astype(caches.dtype), slot, axis=1)
