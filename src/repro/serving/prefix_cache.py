"""Layout-aware, page-granular prefix cache: refcounted page sharing,
copy-on-write, and cache-backed preemption.

KV pages are whole ``m_r``-aligned microkernel tiles (the paper's
amortized-prepacking argument, §4.1, extended from weights to KV), which
makes a full page a *self-contained, layout-keyed unit*: its bytes depend
only on the model weights, the layout, and the exact token block it holds
— never on which request computed it, what shared its batch, or when.
That is exactly the property a vLLM-style prefix cache exploits: two
requests whose prompts share a page-aligned prefix can share the pages
byte-for-byte instead of prefilling twice.

**Keying.**  Each cached full page is a node in a hash chain: its key is
``H(parent_key || token_block)``, with the chain rooted in
``H(layout m_r, page_tokens)``.  A lookup walks the prompt's full
page-blocks from the root and stops at the first miss — the walk *is* the
longest-cached-prefix query, radix-style (vLLM/aphrodite's block manager
keyed by content instead of an explicit trie; branching falls out of the
hashing, since two prompts diverging inside block ``i`` produce different
child keys under the same parent).  Rooting the chain in the layout means
a layout change (different ``m_r``, hence different page geometry and
packed-tile contents) can never alias stale KV — the sharing invariants
the whole stack leans on are spelled out in :mod:`repro.serving.kv_cache`.

**Refcounts.**  The pool refcounts pages (``alloc`` = 1 ref); the cache
holds one reference per cached page and a hit :meth:`lookup` adds one for
the requester — so a page serving k requests while cached carries
``k + 1`` refs, and ``free`` only returns it to the free list at zero.
Pages whose *sole* reference is the cache's are **evictable**: eviction is
LRU over those (childless nodes first, so chains shrink from the leaves),
and the pool calls :meth:`evict` itself when its free list runs dry
(``pool.reclaimer``).  Cached pages are therefore always reclaimable under
pressure, which preserves the scheduler's termination proof — the "a solo
request fits the pool" invariant counts ``pool.num_available``, free
pages plus evictable ones.

**Hit cursor.**  A hit is capped at ``prompt_len - 1`` tokens: the last
prompt position's *logits* feed the first pick, so at least one position
must be recomputed even when every page is cached (the standard vLLM
cap).  For a fully-cached, page-aligned prompt the cursor therefore lands
*inside* the last shared page — the one place a requester must write into
a shared page — and the scheduler CoW-splits that page before prefill
touches it (partially-filled last pages copy-on-write on divergence).

**Insertion.**  Prefill writes newly-completed full pages into the cache
as the cursor advances (chunked) or at prefill completion (monolithic);
preemption *releases pages into the cache instead of freeing them* —
generated tokens fold into the prompt first, so the fold-extended prompt
keys the written full pages and re-admission recomputes only the uncached
suffix: at most the partial last page plus the one never-written pick.
The PR-2 recompute-everything fold path becomes a cache hit.  The same
release-into-cache path serves **cancellation**: a request cancelled or
deadline-expired from any lifecycle state donates its full written pages
(`Scheduler.cancel(..., cache_pages=True)`), so the work it did complete
survives for later arrivals.  The one exception is **quarantine**: a row
whose logits went NaN/Inf is retired with ``cache_pages=False`` — its KV
is suspect by construction and must never enter the cache (the
``REPRO_SANITIZE=1`` sanitizer's ``cancel_checked`` audit enforces
exactly this: every sole-ref page of a quarantined request is freed, not
cached).

Host-side only: this module never touches device arrays (the engine owns
the cache pytree and installs ``pool.page_copier`` for CoW).  Lookups and
inserts re-hash the chain from the root — O(pages) blake2 per call, noise
next to a forward pass at serving page counts.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Tuple

import numpy as np

from repro.obs.telemetry import NULL as _NULL_OBS
from repro.serving.kv_cache import PagedKVPool

__all__ = ["PrefixCache"]


class _Node:
    """One cached full page: the chain key, its parent's key (for child
    accounting on eviction), the page id, and an LRU tick."""

    __slots__ = ("key", "parent", "page", "nchildren", "tick")

    def __init__(self, key: bytes, parent: bytes, page: int, tick: int):
        self.key = key
        self.parent = parent
        self.page = page
        self.nchildren = 0
        self.tick = tick


class PrefixCache:
    """Page-granular prefix cache over a :class:`PagedKVPool`.

    Registers itself as the pool's ``reclaimer`` so allocation pressure
    evicts LRU cache-only pages automatically.  All methods are host-side
    bookkeeping; the caller owns device KV (which is why sharing is sound:
    cached page *contents* are immutable once full).
    """

    def __init__(self, pool: PagedKVPool, *, layout_key=()):
        self.pool = pool
        self.page_tokens = pool.page_tokens
        # the chain root folds the layout into every key: a page cached
        # under one (m_r, page_tokens) geometry can never be returned for
        # another — a layout change invalidates the whole cache by design
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(("repro-prefix-cache", tuple(layout_key),
                       pool.page_tokens)).encode())
        self._root = h.digest()
        self._nodes: Dict[bytes, _Node] = {}
        self._tick = 0
        # counters (cumulative; surfaced via Engine.stats()["prefix_cache"])
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.hit_pages = 0
        self.inserted_pages = 0
        self.insert_dups = 0
        self.evictions = 0
        self.obs = _NULL_OBS    # telemetry; engine swaps in a live one
        pool.reclaimer = self

    # ------------------------------------------------------------------
    def _child_key(self, parent: bytes, block: np.ndarray) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(parent)
        h.update(np.ascontiguousarray(block, np.int32).tobytes())
        return h.digest()

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    # ------------------------------------------------------------------
    def lookup(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached page-chain prefix of ``prompt``.

        Returns ``(pages, hit_tokens)``: the matched page ids (one pool
        reference each transferred to the caller — read-only until CoW)
        and the hit cursor, capped at ``prompt_len - 1`` (the final
        position's logits must be recomputed).  ``([], 0)`` on a miss.
        The caller keeps *all* matched pages even under the cap: position
        ``prompt_len - 1`` then lands inside the last one, which it must
        CoW-split before writing."""
        self.lookups += 1
        prompt = np.asarray(prompt, np.int32)
        size = int(prompt.shape[0])
        t = self.page_tokens
        pages: List[int] = []
        h = self._root
        for i in range(size // t):
            key = self._child_key(h, prompt[i * t:(i + 1) * t])
            node = self._nodes.get(key)
            if node is None:
                break
            self._touch(node)
            pages.append(node.page)
            h = key
        if not pages:
            return [], 0
        hit = min(len(pages) * t, size - 1)
        self.pool.share(pages)
        self.hits += 1
        self.hit_tokens += hit
        self.hit_pages += len(pages)
        self.obs.prefix_hit(hit, len(pages))
        return pages, hit

    def insert(self, prompt: np.ndarray, pages: List[int], upto: int) -> int:
        """Register the full pages covering ``prompt[:upto]`` (``pages`` is
        the owning request's block table — page ``i`` must hold the KV of
        token block ``i``).  Only whole pages are cached: a partial tail
        stays private to its writer.  Existing nodes are refreshed (LRU)
        and never replaced — if another request prefilled the same content
        into a different page first, the cache keeps the incumbent and the
        duplicate stays private (``insert_dups``).  New nodes take their
        own pool reference, so cached pages survive the inserter's release.
        Returns the number of pages newly cached."""
        prompt = np.asarray(prompt, np.int32)
        t = self.page_tokens
        n = min(min(upto, int(prompt.shape[0])) // t, len(pages))
        h = self._root
        new = 0
        for i in range(n):
            key = self._child_key(h, prompt[i * t:(i + 1) * t])
            node = self._nodes.get(key)
            if node is None:
                self._tick += 1
                node = _Node(key, h, pages[i], self._tick)
                self._nodes[key] = node
                parent = self._nodes.get(h)
                if parent is not None:
                    parent.nchildren += 1
                self.pool.share([pages[i]])
                self.inserted_pages += 1
                new += 1
            else:
                if node.page != pages[i]:
                    self.insert_dups += 1
                self._touch(node)
            h = key
        return new

    def pages(self) -> List[int]:
        """Page ids the cache currently holds a reference to — one per
        node, by construction.  Read-only, for external audits
        (:func:`repro.analysis.aliasing.check_pool_consistency` balances
        the pool's refcounts against sequence holders + this list)."""
        return [n.page for n in self._nodes.values()]

    # ------------------------------------------------------------------
    # eviction (also the pool's reclaimer interface)
    # ------------------------------------------------------------------
    def evictable(self) -> int:
        """Cached pages whose only reference is the cache's — the pages
        :meth:`evict` may free right now (a page serving a live request
        carries that request's reference too and is pinned)."""
        return sum(1 for n in self._nodes.values()
                   if self.pool.ref(n.page) == 1)

    def evict(self, want: int) -> int:
        """Free up to ``want`` cache-only pages, least-recently-used first
        with childless nodes preferred (chains shrink from the leaves; a
        mid-chain eviction merely strands its stale descendants, which age
        out by the same LRU).  Candidates are scanned once per call, not
        once per page — refcounts cannot change mid-evict (only cache refs
        are dropped here), and the child-count ordering going slightly
        stale within a batch only shifts preference, never correctness.
        Returns the number actually freed."""
        cands = sorted((n for n in self._nodes.values()
                        if self.pool.ref(n.page) == 1),
                       key=lambda n: (n.nchildren > 0, n.tick))
        freed = 0
        for node in cands:
            if freed >= want:
                break
            del self._nodes[node.key]
            parent = self._nodes.get(node.parent)
            if parent is not None:
                parent.nchildren -= 1
            self.pool.free([node.page])
            self.evictions += 1
            freed += 1
        if freed:
            self.obs.prefix_evict(freed)
        return freed

    def clear(self) -> int:
        """Evict everything evictable (e.g. after a drain, to return the
        pool to a balanced state for accounting)."""
        return self.evict(len(self._nodes))

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {"entries": len(self._nodes),
                "evictable": self.evictable(),
                "lookups": self.lookups, "hits": self.hits,
                "hit_rate": self.hits / max(1, self.lookups),
                "hit_tokens": self.hit_tokens, "hit_pages": self.hit_pages,
                "inserted_pages": self.inserted_pages,
                "insert_dups": self.insert_dups,
                "evictions": self.evictions,
                "shared_pages": sum(
                    1 for n in self._nodes.values()
                    if self.pool.is_shared(n.page)),
                "cow_copies": self.pool.cow_copies}
