"""Continuous-batching scheduler: FCFS admission into fixed decode slots,
lazy page allocation, preemption-by-recomputation.

The engine owns a fixed number of decode *slots* (rows of the batched decode
step — the compiled step shape never changes).  The scheduler:

  - queues incoming requests in **arrival order** (``add`` inserts by the
    request's ``arrival`` stamp, so benchmarks may enqueue a trace out of
    order without stalling replay behind a not-yet-arrived head; preempted
    requests always sit at the *front* of the queue, ahead of any arrival),
  - admits a waiting request when a slot is free AND the pool has pages for
    its **prompt** plus a small **watermark** of free pages (the watermark is
    headroom so running requests can grow a few tokens before the next
    preemption; it is waived when nothing else is running, since then there
    is nobody left to grow),
  - interleaves prefill and decode.  In the **monolithic** policy (the
    PR-1/2 baseline) newly-admitted requests are prefilled one at a time
    (each at its own length — no cross-request prompt padding), then every
    running slot advances one token per engine step.  In the **chunked**
    policy (``chunk_tokens`` set) admission books pages for the *first
    chunk* only and the request enters a ``prefilling`` state: each engine
    step feeds it the next ``chunk_tokens``-sized slice of its prompt
    (:meth:`Scheduler.plan_chunks`) inside the same fused batch that
    advances every decoding slot one token — a long admission is spread
    across steps and never stalls running decodes (Sarathi-style
    token-budget scheduling).  The per-request ``prefill_cursor`` tracks
    how many prompt tokens have KV in the cache; when it reaches the
    prompt length the request samples its first token and starts decoding,
  - **grows** every running request by one KV position per decode step
    (:meth:`Scheduler.grow`) — or, under speculative decoding, by ``1 + k``
    positions for the fed-back token plus the row's draft tokens, where
    only the first position is mandatory and the speculative remainder is
    shed on pressure instead of preempting for it —
    allocating pages only as sequences actually
    lengthen instead of reserving ``prompt + max_new - 1`` up front — a pool
    sized for average-length outputs serves long-tail traffic instead of
    idling behind reservations (the paper's amortized-packing economics,
    §4.1, applied to KV capacity; same philosophy as SVE's one-binary-many-
    vector-lengths: one pool size, many output-length distributions),
  - on :class:`~repro.serving.kv_cache.OutOfPages` during growth,
    **preempts** the youngest-admitted running request: its pages are
    released, and it re-enters the waiting queue at the front with its
    already-generated tokens folded into the prompt, so re-admission
    *recomputes* the interrupted sequence.  Because rows are mathematically
    independent and prefill logits at the last prompt token equal the decode
    logits that produced the next token (the batch-independence property
    proven in tests/test_scheduler.py), recomputation reproduces exactly the
    same greedy continuation — and the same sampled one, since sampling keys
    are derived from (seed, rid, position), not from batch composition,
  - evicts finished requests, returning their slot and pages to the free
    lists immediately.

A mid-prefill victim is **paused**, not preempted: it keeps its pages (the
KV for prompt tokens ``0 .. prefill_cursor-1`` stays valid) and its cursor,
gives up only its slot, and resumes from the cursor on re-admission —
already-written chunks are never recomputed.  Pausing frees no pages, but
it stops the victim's chunk-per-step page demand and shrinks the victim
set, so the preemption loop moves on to decoding victims.  Only as a last
resort — the sole running request still cannot grow and the remaining
pages are held by paused waiters — are a paused request's pages
**reclaimed** (released in full, cursor reset to 0, a true preemption that
recomputes the partial prefill); this is what keeps drains terminating at
any pool size.

Termination: the victim is always the *youngest* admitted request, so the
oldest running request is only ever preempted when it runs alone — and a
solo request can always finish, because ``add`` asserts every request's
whole KV lifetime fits the pool by itself and the reclaim fallback can
always hand a solo request the entire pool.  The oldest request therefore
always makes progress, and drains terminate even when the pool is far
smaller than the sum of reservations (see the OutOfPages-under-load test).

With a **prefix cache** attached (PR 5, :mod:`repro.serving.prefix_cache`),
admission starts prefill at the longest cached prefix of the prompt:
matched pages are *shared* into the block table (refcounted, read-only)
and ``prefill_cursor``/``len`` begin at the hit cursor — a fully-cached
prompt recomputes only its final position (whose logits the first pick
needs), CoW-splitting the shared page that position writes into.
Preemption then **releases pages into the cache instead of freeing them**:
generated tokens fold into the prompt first, the written full pages are
inserted under the fold-extended prompt's keys, and re-admission finds
them — recompute covers only the uncached suffix (at most the partial
last page plus the never-written final pick) instead of the whole
sequence, turning the PR-2 fold path into a cache hit.  Cached pages are
always reclaimable (the pool evicts LRU cache-only pages when its free
list runs dry, and availability checks count ``pool.num_available``), so
every preemption/termination argument above survives the cache holding
pages.

A note on the token budget: under the dense chunked policy the engine's
step *shape* is fixed at ``(slots, chunk_tokens)`` whenever any slot
prefills (the paper's fixed-shape-grid philosophy: one compiled shape,
occupancy varies via ``new_counts``), so per-step device compute is
bounded by the shape, not the budget.  ``chunk_tokens`` is therefore the
latency knob; the ``token_budget`` cap on total assigned new tokens
additionally bounds how many slots prefill concurrently (page-allocation
raggedness), and decoding slots are never budget-stalled — decode
progress is unconditional.

**Flat-segment layout contract** (the default engine step since the flat
refactor; :meth:`Scheduler.plan_segments`): the step is one ``[1, W]``
token stream, ``W`` the token budget rounded up to the layout's ``m_r``
(tile writes stay whole).  Each scheduled row occupies a contiguous
*segment* of the stream: position ``i`` carries ``row_ids[i]`` (the
slot; ``-1`` = padding) and ``q_pos[i]`` (the token's absolute position
in that row — its segment offset plus the row's cursor/len), and the
attention mask is segment-aware causal (``kv_pos <= q_pos[i]`` within
the row's own page stream, see kernels/ragged_attn).  A decode row costs
exactly its ``1 + granted_drafts`` real positions — not a padded
chunk-width row — so the budget is token-exact: ``sum(segment lengths)
<= token_budget`` counts only real tokens, the per-token padding tax of
the dense ``[slots, chunk]`` grid is gone, and decode segments are still
never budget-stalled (they are planned before prefill chunks).

``eager=True`` restores the PR-1 policy (reserve the full lifetime at
admission; growth never fails) — kept as the benchmark baseline.

Request lifecycle state machine
-------------------------------

Every request moves through ``Request.status`` states along exactly these
edges (terminal ``finish_reason`` in parentheses):

  - ``(new) → waiting`` — ``add()`` passed the admission checks and
    inserted the request into the bounded wait queue in arrival order.
  - ``(new) → finished (rejected)`` — ``add()`` shed the request instead:
    the queue is at ``queue_limit`` depth, or the queue's predicted page
    demand (prompt pages of every queued-but-pageless request plus this
    one) exceeds ``queue_pages``.  The shed is a typed
    :class:`AdmissionError` raised *before* any state is taken — fast
    rejection under overload instead of unbounded queueing; the engine
    converts it into a ``finish_reason="rejected"`` row.  (A request whose
    KV budget can never fit ``max_len`` or the pool even alone raises the
    same typed error with ``kind="impossible"`` — a caller bug, not an
    overload signal, so the engine re-raises it.)
  - ``waiting → prefilling`` (chunked) or ``waiting → running``
    (monolithic) — ``admit()``: a slot was free, pages were available, and
    the arrival time has passed.
  - ``prefilling → running`` — the prefill cursor reached the prompt
    length and the first token was picked.
  - ``prefilling → waiting`` (*paused*) — displaced mid-prefill: keeps
    pages + cursor, surrenders only the slot.
  - ``waiting (paused) → waiting`` (*reclaimed*) — last-resort page
    recovery released the paused pages and reset the cursor.
  - ``running → waiting`` (*preempted*) — youngest victim of pool
    exhaustion: generated tokens folded into the prompt, pages released
    (into the prefix cache when attached), recompute on re-admission.
  - ``running → finished (eos | length)`` — ``done()``; the one
    happy-path exit.
  - ``waiting | prefilling | running → finished (timeout)`` —
    ``expire(now)``: the request's ``deadline_s`` elapsed (any state), or
    ``max_queue_s`` elapsed before it was ever admitted.
  - ``waiting | prefilling | running → finished (cancelled | timeout |
    error)`` — ``cancel(rid, reason)``: works from *any* live state,
    including between a speculative rollback and the next step (out_tokens
    only ever holds accepted tokens, so there is no mid-rollback state to
    corrupt).  The slot (if any) is returned, pages are released — into
    the prefix cache when the KV is valid (``cache_pages=True``), straight
    to the free list when it is quarantined (``reason="error"``: a
    NaN-logit row's pages must never be shared) — and the request never
    re-enters any queue.

Cancellation and the termination proof: cancel/expire only ever *remove*
work (a cancelled request frees its slot and pages and never returns), so
every quantity the termination argument counts — waiting requests, pages
the oldest request still needs — is monotonically helped by a
cancellation, and the proof above survives unchanged.  Admission
rejections shrink the queue before it holds state, so they cannot strand
pages either.  Zero-leak-on-cancel (a cancelled request leaves no live
pages, a quarantined request's private pages never reach the cache) is
checked dynamically by the ``REPRO_SANITIZE=1`` sanitizer and audited by
``analysis.aliasing.check_pool_consistency``.

Invariants & how they're checked
--------------------------------

The standing contracts above are machine-enforced, each by a named
analysis pass (:mod:`repro.analysis`; run all of them via
``scripts/tier1.sh --analyze``) or test:

  - **m_r alignment** — pages, chunk widths, flat widths, and prefill
    buckets are whole microkernel tiles from a finite geometric ladder:
    the shape-ladder linter (``analysis.shapes.lint_engine_shapes``)
    re-derives each ladder from this contract, diffs it against the
    engine, and walks every compiled step family's jaxpr asserting all
    dims static; plus tests/test_flat_step.py's ladder tests.
  - **zero post-warmup traces** — ``Engine.warmup`` compiles every
    reachable shape: the recompile-hazard detector
    (``analysis.retrace.RetraceDetector``) diffs the model's per-trace
    argument signatures after ``mark()`` and names the leaf (shape/
    dtype/weak_type) that forced any new trace; plus the zero-trace
    regression tests in tests/test_chunked_prefill.py etc.
  - **CoW before write / guarded pool writes** — every jaxpr-level KV
    write is addressed through the block-table gather with the
    trash-page route (``analysis.aliasing.lint_engine_aliasing``), the
    refcount ledger always matches holders + cache
    (``analysis.aliasing.check_pool_consistency``), and under
    ``REPRO_SANITIZE=1`` every in-place page write asserts ``ref == 1``
    at runtime (``analysis.sanitize``).
  - **termination** — youngest-victim preemption, the solo-fit admission
    assert (on ``usable_pages``/``num_available``, enforced by the AST
    lint's capacity-asserts rule), and the reclaim fallback:
    tests/test_scheduler.py's OutOfPages-under-load drains.
  - **token identity** — flat/chunked/monolithic/spec/prefix-cache
    outputs are bitwise the baseline's: the A/B drains in
    tests/test_flat_step.py, tests/test_speculative.py,
    tests/test_prefix_cache.py and the bench smoke.
  - **allocator hygiene** — ``._free``/``._ref`` are mutated only in
    kv_cache.py and no unseeded randomness enters serving code: the AST
    lint (``analysis.ast_lint``, ``scripts/lint_invariants.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.obs.telemetry import NULL as _NULL_OBS
from repro.serving.kv_cache import (OutOfPages, PagedKVPool, PoolError,
                                    SequencePages)

__all__ = ["AdmissionError", "Request", "Scheduler", "finish_reason_for"]


class AdmissionError(RuntimeError):
    """``Scheduler.add`` refused a request.  ``kind`` says why:

    - ``"queue-depth"`` / ``"page-demand"`` — overload shed: the bounded
      wait queue is full, or its predicted page demand already exceeds the
      configured cap.  Transient; the engine reports the request with
      ``finish_reason="rejected"`` instead of queueing it unboundedly.
    - ``"impossible"`` — the request's KV budget can never fit ``max_len``
      or the pool even running alone: a caller bug, never admissible.

    Raised explicitly (not an ``assert``) so the admission contract
    survives ``python -O`` — an impossible request slipping into the queue
    would deadlock the preemption loop's termination argument."""

    def __init__(self, rid: int, kind: str, message: str):
        super().__init__(message)
        self.rid = rid
        self.kind = kind


def finish_reason_for(tokens, max_new: int, eos_id: Optional[int]):
    """The single finish-reason rule, shared by the continuous path
    (:meth:`Request.done`) and ``Engine.generate``'s static post-hoc
    classification so the two can never drift: the first eos strictly
    before the final permitted position finishes the stream as ``"eos"``
    (keeping ``i + 1`` tokens, eos included); otherwise the stream runs to
    ``max_new`` and finishes as ``"length"`` — an eos that lands *on* the
    final token is a length finish, since the budget, not the eos, is what
    stopped generation.  Returns ``(n_kept, reason)``."""
    if eos_id is not None:
        for i, t in enumerate(tokens[:max_new]):
            if t == eos_id and i < max_new - 1:
                return i + 1, "eos"
    return min(len(tokens), max_new), "length"


@dataclasses.dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: np.ndarray            # [L] int32 prompt tokens
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # per-request sampling params (multi-tenant serving: one batch mixes
    # temperatures and seeds; the speculative acceptance rule needs the
    # request's own key stream, not a global one).  ``temperature == 0``
    # forces greedy for this request even in a sampled drain; ``seed=None``
    # falls back to the engine step's seed.
    temperature: float = 1.0
    seed: Optional[int] = None
    # per-request SLO bounds, both measured from ``arrival`` against the
    # clock the engine is stepped with (``step(now=...)``; wall-clock when
    # the engine drives its own drain): ``deadline_s`` bounds the whole
    # lifetime in any state, ``max_queue_s`` bounds only the time spent
    # waiting before the *first* admission.  ``None`` = unbounded.
    # Expiry finishes the request as ``finish_reason="timeout"`` with
    # whatever tokens it has (padded like an eos row in ``generate``).
    deadline_s: Optional[float] = None
    max_queue_s: Optional[float] = None

    # runtime state (owned by the scheduler/engine)
    status: str = "waiting"       # waiting | prefilling | running | finished
    slot: int = -1
    pages: Optional[SequencePages] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    len: int = 0                  # tokens whose KV is in the cache
    finish_reason: Optional[str] = None
    admit_seq: int = -1           # admission order; preemption evicts max
    preempted: bool = False       # waiting at the front for re-admission
    num_preemptions: int = 0
    folded: int = 0               # leading out_tokens already in the prompt
    # chunked prefill (chunk_tokens set): prompt tokens whose KV is written.
    # Survives a pause (pages kept) so the prefill resumes, not restarts;
    # reset to 0 only when pages are actually released (preempt/reclaim).
    prefill_cursor: int = 0
    num_pauses: int = 0
    chunk_steps: int = 0          # prefill steps run (monolithic: per call)
    # prefix-cache accounting: out_tokens watermark at the last admission
    # (a resume's "generated since" denominator) and whether a reclaim
    # reset the cursor (its resume legitimately recomputes never-cached
    # prefill work, so the resume-recompute bound does not apply)
    out_at_admit: int = 0
    reclaimed: bool = False
    cached_upto: int = 0          # tokens whose pages entered the cache at
                                  # the last preempt (resume-eviction probe)
    # telemetry: (label, t) lifecycle marks appended by repro.obs when the
    # engine runs with telemetry on; stays empty under the NULL recorder
    obs_events: List = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def kv_budget(self) -> int:
        """KV slots this request can ever occupy from here: the (possibly
        recompute-extended) prompt plus every remaining generated token that
        is fed back (the final token never is).  Invariant under preemption
        — folding k generated tokens into the prompt grows ``prompt_len`` by
        k and shrinks the remaining budget by k.  Valid while waiting."""
        return self.prompt_len + (self.max_new - len(self.out_tokens)) - 1

    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new or (
                self.eos_id is not None and self.out_tokens
                and self.out_tokens[-1] == self.eos_id):
            self.finish_reason = self.finish_reason or finish_reason_for(
                self.out_tokens, self.max_new, self.eos_id)[1]
            return True
        return False


class Scheduler:
    def __init__(self, max_slots: int, pool: PagedKVPool, max_len: int, *,
                 eager: bool = False, watermark_pages: int = 1,
                 chunk_tokens: Optional[int] = None, chunk_align: int = 1,
                 prefix_cache=None, queue_limit: Optional[int] = None,
                 queue_pages: Optional[int] = None, telemetry=None):
        self.max_slots = max_slots
        self.pool = pool
        self.max_len = max_len
        self.eager = eager
        self.watermark_pages = watermark_pages
        self.chunk_tokens = chunk_tokens       # None = monolithic prefill
        self.chunk_align = max(1, chunk_align)  # layout m_r: chunks stay tiles
        self.prefix_cache = prefix_cache       # None = no sharing (PR-2/3/4)
        # admission control: bound on queued requests / on the queue's
        # predicted page demand; None = unbounded (the pre-PR-8 behavior)
        self.queue_limit = queue_limit
        self.queue_pages = queue_pages
        self.obs = telemetry if telemetry is not None else _NULL_OBS
        assert prefix_cache is None or not eager, \
            "prefix cache needs lazy allocation: eager reservation books " \
            "full lifetimes, which shared (refcounted) pages would double-count"
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}          # slot -> request
        self._free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._admit_counter = 0
        self.num_preemptions = 0
        self.num_pauses = 0
        self.prefill_stall_steps = 0           # steps where a chunk got < ask
        self.spec_grow_fallbacks = 0           # speculative page asks shed
        self.peak_running = 0
        self.peak_waiting = 0          # high-water queue depth (the
                                       # queue-growth monitor's context:
                                       # was a growth excursion also a
                                       # lifetime high?)
        # preempt-resume accounting under the prefix cache: scalar totals
        # for stats() plus a bounded window of per-event records (the
        # cache contract asserted by tests/bench: recompute <=
        # generated_since + one partial page, unless a reclaim dropped the
        # pages or pool-pressure eviction beat the resume to them)
        self.resumes = 0
        self.resume_recompute_tokens = 0
        self.resume_events: Deque[dict] = deque(maxlen=256)
        # resilience counters (PR 8): requests shed at add(), expired past
        # their deadline, cancelled by the caller, or quarantined (a
        # NaN-logit row retired with its pages kept out of the cache)
        self.num_rejected = 0
        self.num_timeouts = 0
        self.num_cancels = 0
        self.num_quarantines = 0

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def num_free_slots(self) -> int:
        return len(self._free_slots)

    def add(self, req: Request) -> None:
        """Queue one request, or refuse it with a typed
        :class:`AdmissionError` — ``kind="impossible"`` for a request that
        could never run (caller bug), ``kind="queue-depth"`` /
        ``"page-demand"`` for an overload shed when the bounded queue is
        configured.  Sheds are decided *before* any state is taken, so a
        rejection can never strand a slot or a page."""
        if req.kv_budget > self.max_len:
            raise AdmissionError(
                req.rid, "impossible",
                f"request {req.rid}: KV budget {req.kv_budget} (prompt "
                f"{req.prompt_len} + max_new {req.max_new} - 1) exceeds "
                f"engine max_len {self.max_len}")
        if self.pool.pages_for(req.kv_budget) > self.pool.usable_pages:
            raise AdmissionError(
                req.rid, "impossible",
                f"request {req.rid}: KV budget {req.kv_budget} can never "
                f"fit the pool ({self.pool.usable_pages} usable pages of "
                f"{self.pool.page_tokens} tokens) — it could neither run "
                f"eagerly nor survive preemption (cached pages don't help: "
                f"they are reclaimable, not extra capacity)")
        # overload shed signals (bounded wait queue).  Preempted/paused
        # requests re-enter via appendleft, never through add(), so already
        # -admitted work is never shed here.
        if self.queue_limit is not None \
                and len(self.waiting) >= self.queue_limit:
            self.num_rejected += 1
            raise AdmissionError(
                req.rid, "queue-depth",
                f"request {req.rid} shed: wait queue at its bound "
                f"({len(self.waiting)}/{self.queue_limit}) — admitting "
                f"would queue unboundedly under overload")
        if self.queue_pages is not None:
            # predicted demand: prompt pages of every queued request that
            # holds no pages yet, plus the incoming one (paused waiters'
            # held pages are already booked, not future demand)
            demand = self.pool.pages_for(req.prompt_len) + sum(
                self.pool.pages_for(r.prompt_len) for r in self.waiting
                if r.pages is None)
            if demand > self.queue_pages:
                self.num_rejected += 1
                raise AdmissionError(
                    req.rid, "page-demand",
                    f"request {req.rid} shed: queued prompt-page demand "
                    f"{demand} exceeds queue_pages={self.queue_pages} — "
                    f"the backlog already outsizes what the pool can "
                    f"drain promptly")
        req.status = "waiting"
        # insert in arrival order (stable: FCFS among equal arrivals), but
        # never ahead of preempted requests — they resume first regardless
        i, n = 0, len(self.waiting)
        while i < n and self.waiting[i].preempted:
            i += 1
        while i < n and self.waiting[i].arrival <= req.arrival:
            i += 1
        self.waiting.insert(i, req)
        self.peak_waiting = max(self.peak_waiting, len(self.waiting))
        self.obs.request_queued(req)

    def admit(self, now: Optional[float] = None,
              limit: Optional[int] = None) -> List[Request]:
        """Admit waiting requests (FCFS) while a slot is free and the pool
        has pages for the head's prompt plus the watermark (``eager=True``:
        for its full KV budget; chunked: for its *next chunk* only — the
        rest of the prompt is paged in as the cursor advances).  Returns the
        newly-admitted requests; the engine prefills them (monolithic) or
        streams them chunk by chunk (``status == "prefilling"``).  ``now``
        gates admission by arrival time (benchmark trace replay); ``limit``
        caps this call's admissions — the monolithic engine admits one at a
        time so each admission's prefill lands in the prefix cache before
        the next admission's lookup (same-step arrivals then share)."""
        admitted = []
        while (self.waiting and self._free_slots
               and (limit is None or len(admitted) < limit)
               and (now is None or self.waiting[0].arrival <= now)):
            if not self._pages_available(self.waiting[0]):
                # with nothing running, nobody will ever free pages on its
                # own — reclaim paused waiters (never the head itself, whose
                # held pages reduce its need) so the head always progresses
                # and drains terminate at any pool size
                if not self.running and \
                        self._reclaim_one_paused(exclude=self.waiting[0]):
                    continue
                break
            req = self.waiting.popleft()
            req.slot = self._free_slots.pop()
            was_preempted, was_reclaimed = req.preempted, req.reclaimed
            fresh_pages = req.pages is None
            req.preempted = False
            req.reclaimed = False
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            try:
                self._take_pages(req, was_preempted, was_reclaimed)
            except OutOfPages:
                # _pages_available said yes but the allocation still
                # failed (a cache eviction raced it, or a fault-injection
                # harness spiked the allocator): undo the half-admission
                # completely — nothing leaks, the head retries next step
                if fresh_pages and req.pages is not None:
                    req.pages.release()
                    req.pages = None
                    req.prefill_cursor = 0
                    req.len = 0
                self._free_slots.append(req.slot)
                req.slot = -1
                req.status = "waiting"
                req.preempted = was_preempted
                req.reclaimed = was_reclaimed
                self.waiting.appendleft(req)
                break
            self.running[req.slot] = req
            self.obs.request_admitted(req)
            admitted.append(req)
        self.peak_running = max(self.peak_running, len(self.running))
        return admitted

    def _take_pages(self, req: Request, was_preempted: bool,
                    was_reclaimed: bool) -> None:
        """The page-acquiring half of one admission (everything that can
        raise :class:`OutOfPages`), split out so ``admit`` can roll the
        whole thing back atomically when an allocation fails *after* the
        availability check said yes."""
        if req.pages is None:            # a paused request keeps its pages
            req.pages = SequencePages(self.pool, owner=req.rid)
            if self.prefix_cache is not None:
                self._acquire_prefix(req)
                if was_preempted:
                    recompute = req.prompt_len - req.prefill_cursor
                    self.resumes += 1
                    self.resume_recompute_tokens += recompute
                    self.resume_events.append({
                        "rid": req.rid,
                        "recompute": recompute,
                        "generated_since": (len(req.out_tokens)
                                            - req.out_at_admit),
                        "reclaimed": was_reclaimed,
                        # pool pressure may LRU-evict a victim's cached
                        # pages before it resumes — the bound then
                        # legitimately does not apply (output identity
                        # always does)
                        "evicted": req.prefill_cursor < min(
                            req.cached_upto, req.prompt_len - 1)})
        req.out_at_admit = len(req.out_tokens)
        if self.chunk_tokens is not None:
            # chunked: pages arrive with each chunk (plan_chunks); a
            # resumed pause continues from its cursor, never from 0
            assert req.prefill_cursor < req.prompt_len
            req.status = "prefilling"
            req.len = req.prefill_cursor
            if self.eager:               # eager A/B: lifetime up front
                req.pages.ensure(req.kv_budget)
        else:
            req.status = "running"
            # eager: reserve the whole lifetime; lazy: the prompt only —
            # decode steps grow the block table via grow()
            req.pages.ensure(req.kv_budget if self.eager
                             else req.prompt_len)

    def _acquire_prefix(self, req: Request) -> None:
        """Start ``req`` at its longest cached prefix: matched pages are
        shared into the (empty) block table and the prefill cursor jumps to
        the hit — a fully-cached prompt recomputes only its final position.
        When the capped cursor lands *inside* the last shared page (only
        the fully-cached case; full-page hits leave the cursor on a page
        boundary), that page is CoW-split now, before prefill writes the
        final position into it — no shared page is ever written in place.
        If even the CoW copy cannot be allocated, the tail page is handed
        back instead and its block re-prefills from the aligned boundary —
        a pure fallback, never a correctness difference."""
        assert not req.pages.pages and req.prefill_cursor == 0
        pages, hit = self.prefix_cache.lookup(req.prompt)
        if not pages:
            return
        req.pages.pages = pages
        if hit % self.pool.page_tokens:
            try:
                self.pool.cow(req.pages, len(pages) - 1)
            except PoolError:
                # no page for the copy, or the device copy itself failed
                # (PoolError wraps page_copier errors): hand the tail page
                # back and re-prefill its block — degraded, never wrong
                self.pool.free([req.pages.pages.pop()])
                hit = len(req.pages.pages) * self.pool.page_tokens
        req.prefill_cursor = hit
        req.len = hit

    def _pages_available(self, req: Request) -> bool:
        # num_available counts free pages plus cache-evictable ones (alloc
        # reclaims the latter on demand); the need is computed as if the
        # lookup misses.  Monolithic: a hit shrinks the need by exactly the
        # pages sharing pins (plus at most one CoW page, covered because
        # CoW only fires when >= 1 page was pinned), so the check stays
        # sufficient.  Chunked: the need covers the *next chunk* only and
        # does not shrink with the hit, while the hit may pin
        # previously-evictable pages — the watermark headroom can erode by
        # the hit size in the worst case.  That costs at most an avoidable
        # displacement on a later grow() (plan_chunks stalls, grow pauses/
        # preempts — all handled paths); admission itself stays safe
        # because the chunk's own pages were counted before any pinning.
        if self.eager:
            return self.pool.can_fit(req.kv_budget)
        # the watermark keeps headroom for already-running requests to grow;
        # with nothing running there is nobody to protect, so a solo request
        # may take the whole pool (this is what guarantees drain progress)
        reserve = self.watermark_pages if self.running else 0
        if self.chunk_tokens is not None:
            held = 0 if req.pages is None else len(req.pages.pages)
            first = min(req.prefill_cursor + self.chunk_tokens,
                        req.prompt_len)
            need = max(0, self.pool.pages_for(first) - held)
            return need + reserve <= self.pool.num_available
        return self.pool.pages_for(req.prompt_len) + reserve \
            <= self.pool.num_available

    def plan_chunks(self, budget: int) -> Dict[int, int]:
        """Assign this step's prompt chunk to every PREFILLING slot, oldest
        admission first: each gets ``min(chunk_tokens, remaining prompt,
        remaining budget)`` tokens and the pages to hold them.  On
        ``OutOfPages`` the slot **stalls** (it keeps its slot, cursor and
        pages, and simply contributes ``new_counts == 0`` this step) rather
        than stealing pages from decodes — except for the oldest prefill
        when nothing is decoding, which reclaims paused waiters' pages (and,
        failing that, pauses younger prefills so the *next* reclaim can take
        theirs) so the head of the line always makes progress.  Returns
        ``{slot: n}``."""
        plan: Dict[int, int] = {}
        if self.chunk_tokens is None:
            return plan
        prefilling = sorted(
            (r for r in self.running.values() if r.status == "prefilling"),
            key=lambda r: r.admit_seq)
        decoding = any(r.status == "running" for r in self.running.values())
        stalled = False
        for idx, req in enumerate(prefilling):
            if req.slot < 0 or req.status != "prefilling":
                continue                 # paused by an earlier reclaim pass
            want = min(self.chunk_tokens,
                       req.prompt_len - req.prefill_cursor)
            n = min(want, max(0, budget))
            if n < want:
                # budget-clamped: keep the cursor on a microkernel-tile
                # boundary so every later chunk still writes whole tiles
                # (only the final prompt-remainder chunk may be inexact)
                n -= n % self.chunk_align
            if n > 0:
                try:
                    req.pages.ensure(req.prefill_cursor + n)
                except OutOfPages:
                    if idx == 0 and not decoding:
                        self._reclaim_for(req, n)
                    n = min(n, req.pages.capacity - req.prefill_cursor)
            if n < want:
                stalled = True
            plan[req.slot] = n
            budget -= n
        if stalled:
            self.prefill_stall_steps += 1
        return plan

    def plan_segments(self, decode_counts: Dict[int, int],
                      budget: int) -> List[tuple]:
        """Flat-segment plan for one ``[1, W]`` step: decode rows first
        (each costs exactly its ``1 + granted_drafts`` real positions —
        token-exact, never budget-stalled), then prefill chunks from
        :meth:`plan_chunks` under the remaining budget (same page
        bookkeeping, stalls, and reclaim fallbacks as the dense path — the
        flat layout changes how tokens are *shaped*, not how they are
        scheduled).  ``decode_counts``: ``{slot: 1 + k}`` for every
        decoding row.  Returns an ordered ``[(slot, kind, n)]`` list,
        ``kind in {"decode", "prefill"}``; the engine lays the segments
        out back-to-back in the flat stream."""
        ndecode = sum(decode_counts.values())
        plan = self.plan_chunks(budget - ndecode)
        segs: List[tuple] = []
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.status == "running" and slot in decode_counts:
                segs.append((slot, "decode", decode_counts[slot]))
            elif req.status == "prefilling" and plan.get(slot, 0) > 0:
                segs.append((slot, "prefill", plan[slot]))
        return segs

    def _reclaim_for(self, req: Request, n: int) -> None:
        """Last-resort page recovery for the oldest prefill when nothing
        else is running: release paused waiters' pages (youngest admission
        first), pausing still-running younger prefills so the next reclaim
        can take theirs.  ``add``'s solo-fit assert guarantees this loop
        hands ``req`` enough pages eventually."""
        while True:
            try:
                req.pages.ensure(req.prefill_cursor + n)
                return
            except OutOfPages:
                if self._reclaim_one_paused():
                    continue
                younger = [r for r in self.running.values()
                           if r.status == "prefilling" and r is not req]
                if not younger:
                    return               # caller falls back to capacity
                self._pause(max(younger, key=lambda r: r.admit_seq))

    def grow(self, want: Optional[Dict[int, int]] = None) -> List[Request]:
        """Give every decoding request a KV slot for the position its next
        token writes (``len``), oldest admission first (PREFILLING slots get
        their pages chunk-wise in :meth:`plan_chunks` instead).  On pool
        exhaustion, displace the youngest-admitted running request and
        retry: a mid-prefill victim is *paused* (keeps pages + cursor, frees
        only its slot and its future chunk demand), a decoding victim is
        *preempted* (pages released, tokens folded, recompute).  When the
        growing request is its own youngest victim, paused waiters' pages
        are reclaimed first — self-preemption is the true last resort.

        ``want``: optional ``{slot: n}`` asking n >= 1 KV positions for a
        row this step — the speculative verify step writes 1 fed-back token
        plus up to k draft tokens.  Only the first position is mandatory:
        a speculative ask is shed (all-or-nothing, counted in
        ``spec_grow_fallbacks``) not just when it outsizes the free list
        but whenever granting it would eat into the pages the *other*
        running rows' mandatory one-token growth needs this step — a
        speculative grant must never be what forces a preemption (tokens
        it books may be rejected anyway), so the preemption loop only ever
        runs for the same one-token demand as plain decode and the
        termination proof is untouched.

        Returns the requests displaced this step (the engine masks their
        slots into the trash page for the in-flight decode).  No-op when
        admission was eager — capacity was reserved up front."""
        displaced: List[Request] = []
        for req in sorted(self.running.values(), key=lambda r: r.admit_seq):
            if req.status != "running":
                continue
            n = 1 if want is None else max(1, want.get(req.slot, 1))
            if n > 1:
                need = max(0, self.pool.pages_for(req.len + n)
                           - len(req.pages.pages))
                if need == 0:
                    continue     # slack in the held pages covers the ask
                if need <= self.pool.num_available \
                        - self._mandatory_growth_pages(req):
                    try:
                        req.pages.ensure(req.len + n)
                        continue
                    except OutOfPages:
                        pass
                self.spec_grow_fallbacks += 1
            while req.status == "running":
                try:
                    req.pages.ensure(req.len + 1)
                    break
                except OutOfPages:
                    victim = max(self.running.values(),
                                 key=lambda r: r.admit_seq)
                    if victim.status == "prefilling":
                        # frees no pages, but shrinks the victim set; the
                        # retry walks on to the next-youngest victim
                        self._pause(victim)
                    elif victim is req and self._reclaim_one_paused():
                        continue
                    else:
                        self._preempt(victim)
                    displaced.append(victim)
        return displaced

    def _mandatory_growth_pages(self, exclude: Request) -> int:
        """Pages the other decoding rows' mandatory one-token growth will
        demand this step (0 or 1 each — one token crosses at most one page
        boundary).  Rows grown earlier this pass already hold their page
        and contribute 0, so this is exactly the not-yet-served demand a
        speculative grant must leave room for."""
        return sum(1 for r in self.running.values()
                   if r is not exclude and r.status == "running"
                   and self.pool.pages_for(r.len + 1) > len(r.pages.pages))

    def _pause(self, req: Request) -> None:
        """Displace a mid-prefill request *without* losing its work: it
        keeps its pages (KV for prompt[0:prefill_cursor] stays valid — those
        pages cannot be handed to anyone else) and its cursor, returns only
        its slot, and waits at the queue front; re-admission resumes the
        prefill from the cursor instead of recomputing written chunks."""
        assert req.status == "prefilling"
        assert self.running.get(req.slot) is req
        self.obs.request_paused(req)       # before the slot clears: the
        del self.running[req.slot]         # instant lands on its track
        self._free_slots.append(req.slot)
        req.slot = -1
        req.status = "waiting"
        req.preempted = True
        req.num_pauses += 1
        self.num_pauses += 1
        self.waiting.appendleft(req)

    def _reclaim_one_paused(self, exclude: Optional[Request] = None) -> bool:
        """Release the pages of one paused waiting request (youngest
        admission first), resetting its cursor — a true preemption of a
        partial prefill, used only when running victims are exhausted.
        ``exclude`` protects the request the reclaim is *for* (releasing
        its own pages would grow, not shrink, its need).  Returns False
        when no other waiter holds pages."""
        holders = [r for r in self.waiting
                   if r is not exclude and r.pages is not None
                   and r.pages.pages]
        if not holders:
            return False
        victim = max(holders, key=lambda r: r.admit_seq)
        if self.prefix_cache is not None:
            # a reclaim is still a release-into-the-cache: the victim's
            # completed chunks stay findable (and instantly evictable if
            # the pressure that forced this reclaim needs them)
            self.prefix_cache.insert(victim.prompt, victim.pages.pages,
                                     min(victim.prefill_cursor,
                                         victim.prompt_len))
            victim.pages.release()
            victim.pages = None      # re-admission re-looks-up the prefix
        else:
            victim.pages.release()
        victim.prefill_cursor = 0
        victim.len = 0
        victim.reclaimed = True
        victim.num_preemptions += 1
        self.num_preemptions += 1
        self.obs.request_reclaimed(victim)
        return True

    def _preempt(self, req: Request) -> None:
        """Release everything and requeue at the front for recomputation:
        the generated-so-far tokens are folded into the prompt, so the
        re-admission prefill recomputes the KV the release threw away and
        the next pick continues the sequence exactly where it stopped.

        With a prefix cache, "release" means **release into the cache**:
        the fold runs first so the extended prompt keys the written full
        pages, those are inserted (the cache takes its own references), and
        only then are the request's references dropped — full pages survive
        for the re-admission lookup, the partial tail page returns to the
        free list, and the resume recomputes just the uncached suffix."""
        assert self.running.get(req.slot) is req
        self.obs.request_preempted(req)    # before the slot clears: the
        del self.running[req.slot]         # instant lands on its track
        self._free_slots.append(req.slot)
        req.slot = -1
        # fold only the tokens generated since the last admission — earlier
        # preemptions already folded their prefix (re-folding would duplicate
        # it and silently corrupt the recompute context)
        fresh = req.out_tokens[req.folded:]
        if fresh:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fresh, np.int32)])
            req.folded = len(req.out_tokens)
        if self.prefix_cache is not None:
            # req.len positions hold committed KV (speculative rollbacks
            # already truncated rejected drafts, so nothing stale can leak)
            upto = min(req.len, req.prompt_len)
            self.prefix_cache.insert(req.prompt, req.pages.pages, upto)
            req.cached_upto = (upto // self.pool.page_tokens
                               * self.pool.page_tokens)
        req.pages.release()
        req.pages = None
        req.len = 0
        req.prefill_cursor = 0       # pages gone: re-prefill from the start
        req.status = "waiting"
        req.preempted = True
        req.num_preemptions += 1
        self.num_preemptions += 1
        # victims are preempted youngest-first, so successive appendlefts
        # leave the *oldest* victim at the head for re-admission
        self.waiting.appendleft(req)

    def finish(self, req: Request) -> None:
        """Evict: return the slot and the pages to the free lists."""
        assert self.running.get(req.slot) is req
        self.obs.request_finished(req)     # slot still valid: the decode
        del self.running[req.slot]         # span closes on its track
        req.pages.release()
        self._free_slots.append(req.slot)
        req.slot = -1
        req.status = "finished"

    def cancel(self, rid: int, reason: str = "cancelled", *,
               cache_pages: bool = True) -> Optional[Request]:
        """Retire request ``rid`` from *any* live state — queued (fresh,
        paused or preempted), prefilling, or decoding (including right
        after a speculative rollback: ``out_tokens``/``len`` only ever
        cover accepted tokens, so there is no partial state to corrupt).
        The slot (if held) is returned, pages are released — into the
        prefix cache when attached and ``cache_pages=True`` (the committed
        KV is valid; a later identical prompt may reuse it), straight to
        the free list when the KV is suspect (``cache_pages=False``: the
        engine's NaN-logit quarantine) — and the request finishes with
        ``finish_reason=reason`` (``"cancelled"`` | ``"timeout"`` |
        ``"error"``).  Returns the request, or ``None`` when ``rid`` is
        not live (already finished, never added, or shed at add)."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                del self.waiting[i]
                self.obs.request_cancelled(r, reason)
                return self._retire_cancelled(r, reason, cache_pages)
        for slot, r in list(self.running.items()):
            if r.rid == rid:
                del self.running[slot]
                self._free_slots.append(slot)
                self.obs.request_cancelled(r, reason)
                r.slot = -1
                return self._retire_cancelled(r, reason, cache_pages)
        return None

    def _retire_cancelled(self, req: Request, reason: str,
                          cache_pages: bool) -> Request:
        if req.pages is not None:
            if cache_pages and self.prefix_cache is not None \
                    and req.pages.pages:
                # same contract as preemption: positions 0..len-1 hold
                # committed KV (cursor for a mid-prefill victim), capped at
                # the prompt — the cache takes its references before ours
                # drop, so full pages survive for a future identical prompt
                upto = min(max(req.len, req.prefill_cursor), req.prompt_len)
                self.prefix_cache.insert(req.prompt, req.pages.pages, upto)
            req.pages.release()
            req.pages = None
        req.prefill_cursor = 0
        req.len = 0
        req.status = "finished"
        req.finish_reason = reason
        if reason == "timeout":
            self.num_timeouts += 1
        elif reason == "error":
            self.num_quarantines += 1
        else:
            self.num_cancels += 1
        return req

    def expire(self, now: Optional[float]) -> List[Request]:
        """Cancel-as-timeout every live request past its deadline at time
        ``now``: ``deadline_s`` bounds the whole lifetime in any state,
        ``max_queue_s`` only the wait before the first admission.  Run by
        the engine at the top of each step (before admission, so a doomed
        head never takes a slot).  ``now=None`` (an untimed drain) checks
        nothing — deadlines need the caller's clock."""
        if now is None:
            return []
        stale = [r for r in list(self.waiting) + list(self.running.values())
                 if (r.deadline_s is not None
                     and now - r.arrival >= r.deadline_s)
                 or (r.max_queue_s is not None and r.admit_seq < 0
                     and r.status == "waiting"
                     and now - r.arrival >= r.max_queue_s)]
        return [self.cancel(r.rid, "timeout") for r in stale]

    def stats(self) -> dict:
        """Scheduler-side counters (cumulative; pool stats live on the
        pool).  ``prefilling``/``decoding`` split the running set by state;
        ``prefill_stall_steps`` counts steps where some prefilling slot was
        assigned fewer chunk tokens than it asked for (pages or budget)."""
        running = list(self.running.values())
        return {
            "waiting": len(self.waiting),
            "running": len(running),
            "prefilling": sum(r.status == "prefilling" for r in running),
            "decoding": sum(r.status == "running" for r in running),
            "free_slots": len(self._free_slots),
            "peak_running": self.peak_running,
            "peak_waiting": self.peak_waiting,
            "num_preemptions": self.num_preemptions,
            "num_pauses": self.num_pauses,
            "prefill_stall_steps": self.prefill_stall_steps,
            "spec_grow_fallbacks": self.spec_grow_fallbacks,
            "chunk_tokens": self.chunk_tokens,
            "resumes": self.resumes,
            "resume_recompute_tokens": self.resume_recompute_tokens,
            "queue_limit": self.queue_limit,
            "queue_pages": self.queue_pages,
            "num_rejected": self.num_rejected,
            "num_timeouts": self.num_timeouts,
            "num_cancels": self.num_cancels,
            "num_quarantines": self.num_quarantines,
        }
