"""Continuous-batching scheduler: FCFS admission into fixed decode slots.

The engine owns a fixed number of decode *slots* (rows of the batched decode
step — the compiled step shape never changes).  The scheduler:

  - queues incoming requests (FCFS; ``arrival`` lets benchmarks replay a
    trace),
  - admits a waiting request when a slot is free AND the KV pool can hold
    its whole lifetime (prompt + max_new tokens — reservation up front means
    a running request can never die of pool exhaustion mid-flight;
    preemption/recompute is future work, see ROADMAP),
  - interleaves prefill and decode: newly-admitted requests are prefilled
    one at a time (each at its own length — no cross-request prompt
    padding), then every running slot advances one token per engine step,
  - evicts finished requests, returning their slot and pages to the free
    lists immediately; the next waiting request takes the slot at the next
    step's admission phase.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.kv_cache import PagedKVPool, SequencePages

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: np.ndarray            # [L] int32 prompt tokens
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0

    # runtime state (owned by the scheduler/engine)
    status: str = "waiting"       # waiting | running | finished
    slot: int = -1
    pages: Optional[SequencePages] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    len: int = 0                  # tokens whose KV is in the cache
    finish_reason: Optional[str] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def kv_budget(self) -> int:
        """KV slots this request can ever occupy: the prompt plus every
        generated token that is fed back (the final token never is)."""
        return self.prompt_len + self.max_new - 1

    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new:
            self.finish_reason = self.finish_reason or "length"
            return True
        if self.eos_id is not None and self.out_tokens \
                and self.out_tokens[-1] == self.eos_id:
            self.finish_reason = "eos"
            return True
        return False


class Scheduler:
    def __init__(self, max_slots: int, pool: PagedKVPool, max_len: int):
        self.max_slots = max_slots
        self.pool = pool
        self.max_len = max_len
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}          # slot -> request
        self._free_slots: List[int] = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def num_free_slots(self) -> int:
        return len(self._free_slots)

    def add(self, req: Request) -> None:
        assert req.kv_budget <= self.max_len, \
            f"request {req.rid}: KV budget {req.kv_budget} (prompt " \
            f"{req.prompt_len} + max_new {req.max_new} - 1) exceeds " \
            f"engine max_len {self.max_len}"
        req.status = "waiting"
        self.waiting.append(req)

    def admit(self, now: Optional[float] = None) -> List[Request]:
        """Admit waiting requests (FCFS) while a slot is free and the pool
        can hold their full KV budget.  Returns the newly-admitted requests;
        the engine prefills them.  ``now`` gates admission by arrival time
        (benchmark trace replay)."""
        admitted = []
        while (self.waiting and self._free_slots
               and (now is None or self.waiting[0].arrival <= now)
               and self.pool.can_fit(self.waiting[0].kv_budget)):
            req = self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.status = "running"
            req.pages = SequencePages(self.pool)
            req.pages.ensure(req.kv_budget)   # reserve the whole lifetime
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def finish(self, req: Request) -> None:
        """Evict: return the slot and the pages to the free lists."""
        assert self.running.get(req.slot) is req
        del self.running[req.slot]
        req.pages.release()
        self._free_slots.append(req.slot)
        req.slot = -1
        req.status = "finished"
