"""Continuous-batching scheduler: FCFS admission into fixed decode slots,
lazy page allocation, preemption-by-recomputation.

The engine owns a fixed number of decode *slots* (rows of the batched decode
step — the compiled step shape never changes).  The scheduler:

  - queues incoming requests in **arrival order** (``add`` inserts by the
    request's ``arrival`` stamp, so benchmarks may enqueue a trace out of
    order without stalling replay behind a not-yet-arrived head; preempted
    requests always sit at the *front* of the queue, ahead of any arrival),
  - admits a waiting request when a slot is free AND the pool has pages for
    its **prompt** plus a small **watermark** of free pages (the watermark is
    headroom so running requests can grow a few tokens before the next
    preemption; it is waived when nothing else is running, since then there
    is nobody left to grow),
  - interleaves prefill and decode: newly-admitted requests are prefilled
    one at a time (each at its own length — no cross-request prompt
    padding), then every running slot advances one token per engine step,
  - **grows** every running request by one KV position per decode step
    (:meth:`Scheduler.grow`), allocating pages only as sequences actually
    lengthen instead of reserving ``prompt + max_new - 1`` up front — a pool
    sized for average-length outputs serves long-tail traffic instead of
    idling behind reservations (the paper's amortized-packing economics,
    §4.1, applied to KV capacity; same philosophy as SVE's one-binary-many-
    vector-lengths: one pool size, many output-length distributions),
  - on :class:`~repro.serving.kv_cache.OutOfPages` during growth,
    **preempts** the youngest-admitted running request: its pages are
    released, and it re-enters the waiting queue at the front with its
    already-generated tokens folded into the prompt, so re-admission
    *recomputes* the interrupted sequence.  Because rows are mathematically
    independent and prefill logits at the last prompt token equal the decode
    logits that produced the next token (the batch-independence property
    proven in tests/test_scheduler.py), recomputation reproduces exactly the
    same greedy continuation — and the same sampled one, since sampling keys
    are derived from (seed, rid, position), not from batch composition,
  - evicts finished requests, returning their slot and pages to the free
    lists immediately.

Termination: the victim is always the *youngest* admitted request, so the
oldest running request is only ever preempted when it runs alone — and a
solo request can always finish, because ``add`` asserts every request's
whole KV lifetime fits the pool by itself.  The oldest request therefore
always makes progress, and drains terminate even when the pool is far
smaller than the sum of reservations (see the OutOfPages-under-load test).

``eager=True`` restores the PR-1 policy (reserve the full lifetime at
admission; growth never fails) — kept as the benchmark baseline.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serving.kv_cache import OutOfPages, PagedKVPool, SequencePages

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: np.ndarray            # [L] int32 prompt tokens
    max_new: int
    eos_id: Optional[int] = None
    arrival: float = 0.0

    # runtime state (owned by the scheduler/engine)
    status: str = "waiting"       # waiting | running | finished
    slot: int = -1
    pages: Optional[SequencePages] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    len: int = 0                  # tokens whose KV is in the cache
    finish_reason: Optional[str] = None
    admit_seq: int = -1           # admission order; preemption evicts max
    preempted: bool = False       # waiting at the front for re-admission
    num_preemptions: int = 0
    folded: int = 0               # leading out_tokens already in the prompt

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def kv_budget(self) -> int:
        """KV slots this request can ever occupy from here: the (possibly
        recompute-extended) prompt plus every remaining generated token that
        is fed back (the final token never is).  Invariant under preemption
        — folding k generated tokens into the prompt grows ``prompt_len`` by
        k and shrinks the remaining budget by k.  Valid while waiting."""
        return self.prompt_len + (self.max_new - len(self.out_tokens)) - 1

    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new:
            self.finish_reason = self.finish_reason or "length"
            return True
        if self.eos_id is not None and self.out_tokens \
                and self.out_tokens[-1] == self.eos_id:
            self.finish_reason = "eos"
            return True
        return False


class Scheduler:
    def __init__(self, max_slots: int, pool: PagedKVPool, max_len: int, *,
                 eager: bool = False, watermark_pages: int = 1):
        self.max_slots = max_slots
        self.pool = pool
        self.max_len = max_len
        self.eager = eager
        self.watermark_pages = watermark_pages
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}          # slot -> request
        self._free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._admit_counter = 0
        self.num_preemptions = 0
        self.peak_running = 0

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def num_free_slots(self) -> int:
        return len(self._free_slots)

    def add(self, req: Request) -> None:
        assert req.kv_budget <= self.max_len, \
            f"request {req.rid}: KV budget {req.kv_budget} (prompt " \
            f"{req.prompt_len} + max_new {req.max_new} - 1) exceeds " \
            f"engine max_len {self.max_len}"
        assert self.pool.pages_for(req.kv_budget) <= self.pool.num_pages - 1, \
            f"request {req.rid}: KV budget {req.kv_budget} can never fit " \
            f"the pool ({self.pool.num_pages - 1} usable pages of " \
            f"{self.pool.page_tokens} tokens) — it could neither run eagerly " \
            f"nor survive preemption"
        req.status = "waiting"
        # insert in arrival order (stable: FCFS among equal arrivals), but
        # never ahead of preempted requests — they resume first regardless
        i, n = 0, len(self.waiting)
        while i < n and self.waiting[i].preempted:
            i += 1
        while i < n and self.waiting[i].arrival <= req.arrival:
            i += 1
        self.waiting.insert(i, req)

    def admit(self, now: Optional[float] = None) -> List[Request]:
        """Admit waiting requests (FCFS) while a slot is free and the pool
        has pages for the head's prompt plus the watermark (``eager=True``:
        for its full KV budget).  Returns the newly-admitted requests; the
        engine prefills them.  ``now`` gates admission by arrival time
        (benchmark trace replay)."""
        admitted = []
        while (self.waiting and self._free_slots
               and (now is None or self.waiting[0].arrival <= now)
               and self._pages_available(self.waiting[0])):
            req = self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.status = "running"
            req.preempted = False
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            req.pages = SequencePages(self.pool)
            # eager: reserve the whole lifetime; lazy: the prompt only —
            # decode steps grow the block table via grow()
            req.pages.ensure(req.kv_budget if self.eager else req.prompt_len)
            self.running[req.slot] = req
            admitted.append(req)
        self.peak_running = max(self.peak_running, len(self.running))
        return admitted

    def _pages_available(self, req: Request) -> bool:
        if self.eager:
            return self.pool.can_fit(req.kv_budget)
        # the watermark keeps headroom for already-running requests to grow;
        # with nothing running there is nobody to protect, so a solo request
        # may take the whole pool (this is what guarantees drain progress)
        reserve = self.watermark_pages if self.running else 0
        return self.pool.pages_for(req.prompt_len) + reserve \
            <= self.pool.num_free

    def grow(self) -> List[Request]:
        """Give every running request a KV slot for the position its next
        decode token writes (``len``), oldest admission first.  On pool
        exhaustion, preempt the youngest-admitted running request and retry;
        returns the requests preempted this step (the engine masks their
        slots into the trash page for the in-flight decode).  No-op when
        admission was eager — capacity was reserved up front."""
        preempted: List[Request] = []
        for req in sorted(self.running.values(), key=lambda r: r.admit_seq):
            while req.status == "running":
                try:
                    req.pages.ensure(req.len + 1)
                    break
                except OutOfPages:
                    victim = max(self.running.values(),
                                 key=lambda r: r.admit_seq)
                    self._preempt(victim)
                    preempted.append(victim)
        return preempted

    def _preempt(self, req: Request) -> None:
        """Release everything and requeue at the front for recomputation:
        the generated-so-far tokens are folded into the prompt, so the
        re-admission prefill recomputes the KV the release threw away and
        the next pick continues the sequence exactly where it stopped."""
        assert self.running.get(req.slot) is req
        del self.running[req.slot]
        req.pages.release()
        req.pages = None
        self._free_slots.append(req.slot)
        req.slot = -1
        req.len = 0
        # fold only the tokens generated since the last admission — earlier
        # preemptions already folded their prefix (re-folding would duplicate
        # it and silently corrupt the recompute context)
        fresh = req.out_tokens[req.folded:]
        if fresh:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fresh, np.int32)])
            req.folded = len(req.out_tokens)
        req.status = "waiting"
        req.preempted = True
        req.num_preemptions += 1
        self.num_preemptions += 1
        # victims are preempted youngest-first, so successive appendlefts
        # leave the *oldest* victim at the head for re-admission
        self.waiting.appendleft(req)

    def finish(self, req: Request) -> None:
        """Evict: return the slot and the pages to the free lists."""
        assert self.running.get(req.slot) is req
        del self.running[req.slot]
        req.pages.release()
        self._free_slots.append(req.slot)
        req.slot = -1
        req.status = "finished"
