"""Serving engine: batched prefill + decode with sharded caches.

The decode KV cache is sharded along the *sequence* dim over the model axis
(batch over DP): attention against a sequence-sharded cache lowers to a
distributed flash-decode (per-shard partial softmax + cross-shard combine),
which GSPMD derives from the softmax over the sharded dim.  On one device
this degenerates to ordinary attention — the same code serves both.

Weights are pre-packed once (``prepack_params``) — the paper's amortized
standalone packing (§4.1) — so decode steps stream packed tiles directly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import prepack_params
from repro.distributed import sharding
from repro.models.model import ReproModel

__all__ = ["Engine"]


class Engine:
    def __init__(self, model: ReproModel, params, *, mesh=None,
                 prepack: bool = True):
        self.model = model
        self.mesh = mesh
        self.params = (prepack_params(params, model.ctx)
                       if prepack and model.cfg.family != "encdec" else params)
        self._step = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(model.decode_step, donate_argnums=(1,))

    def generate(self, batch: dict, max_new: int, *,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """batch: {"tokens": [B, L] prompt, (+frames/patches)}.

        Returns [B, max_new] generated tokens.
        """
        m = self.model
        prompts = jnp.asarray(batch["tokens"])
        b, plen = prompts.shape
        caches = m.prefill_cache(self.params, batch) if m.cfg.family == "encdec" \
            else m.init_cache(b, m.shape.seq_len)

        embeds = None
        if m.cfg.family == "vlm":
            embeds = m._embeds(self.params, batch)
            logits, caches = self._prefill(self.params, caches,
                                           jnp.zeros((b, embeds.shape[1]), jnp.int32),
                                           jnp.int32(0), embeds)
            pos = embeds.shape[1]
        else:
            logits, caches = self._prefill(self.params, caches, prompts,
                                           jnp.int32(0))
            pos = plen

        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(max_new - 1):
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(pos + i))
            if greedy:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            out.append(tok.astype(jnp.int32))
        return np.asarray(jnp.concatenate(out, axis=1))
