"""Serving engine: continuous batching over a paged, layout-aware KV cache.

The engine owns a fixed set of decode **slots** (the compiled decode step
shape never changes), a paged KV pool (page size = ``round_up(page_tokens,
m_r)`` of the active packed layout — KV pages are whole microkernel tiles),
and a FCFS :class:`~repro.serving.scheduler.Scheduler`.  Per engine step:

  1. admission: waiting requests take free slots; each is prefilled at its
     own (layout-bucketed) length — no cross-request prompt padding;
  2. decode: every running slot advances one token in a single fixed-shape
     batched ``paged_decode_step`` (inactive slots write to the trash page);
  3. eviction: finished requests release slot + pages immediately.

Rows are mathematically independent (per-row attention over per-row pages,
per-row softmax/argmax), so a request's greedy output is identical whatever
else shares the batch — admission order cannot change results.

The decode KV pool is sequence-shardable over the model axis (pages are the
sequence chunks; ``repro.distributed.sharding.cache_specs``) and weights are
pre-packed once (``prepack_params``) — the paper's amortized standalone
packing (§4.1) — so decode steps stream packed tiles directly.

``generate`` is a thin compatibility wrapper over add_request/step; the
encoder-decoder and VLM families (per-request encoder state, patch-prefix
prefill) still use the static-batch path (``generate_static``).
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import ceil_div, round_up
from repro.core.linear import prepack_params
from repro.distributed import sharding
from repro.models.model import ReproModel
from repro.serving.kv_cache import (PagedKVPool, fresh_slot_states,
                                    merge_slot, prefill_view)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine"]

_STATIC_FAMILIES = ("encdec", "vlm")


class Engine:
    def __init__(self, model: ReproModel, params, *, mesh=None,
                 prepack: bool = True, max_slots: Optional[int] = None,
                 page_tokens: int = 16, num_pages: Optional[int] = None):
        self.model = model
        self.mesh = mesh
        self.params = (prepack_params(params, model.ctx)
                       if prepack and model.cfg.family != "encdec" else params)
        # static-batch path (encdec/vlm generate, throughput baselines);
        # prefill ([B, plen]) and decode ([B, 1]) are two traces of the one
        # model-cached jit — engines over the same model share compilations
        self._step = self._prefill = model.jit_step("decode")

        self.continuous = model.cfg.family not in _STATIC_FAMILIES
        self._next_rid = 0
        if not self.continuous:
            return

        layout = model.ctx.layout(model.compute_dtype)
        self._bucket = layout.m_r if all(
            t == "attn" for t in model.cfg.layer_types) else 1
        self.slots = max_slots or model.shape.global_batch
        max_len = model.shape.seq_len
        page_tokens = round_up(page_tokens, layout.m_r)
        if num_pages is None:
            num_pages = 1 + self.slots * ceil_div(max_len, page_tokens)
        self.pool = PagedKVPool(num_pages, page_tokens)
        self.max_pages = ceil_div(max_len, self.pool.page_tokens)
        self.scheduler = Scheduler(self.slots, self.pool, max_len)
        self.caches = model.init_paged_cache(num_pages, self.pool.page_tokens,
                                             self.slots)
        if mesh is not None:
            specs = sharding.cache_specs(self.caches, mesh, model.run,
                                         self.slots)
            self.caches = jax.device_put(self.caches,
                                         sharding.named(mesh, specs))
        self._paged_step = model.jit_step("paged")

    # ------------------------------------------------------------------
    # continuous-batching API
    # ------------------------------------------------------------------
    def add_request(self, tokens, max_new: int, *, eos_id: Optional[int] = None,
                    arrival: float = 0.0) -> int:
        """Queue one request.  Returns its request id."""
        assert self.continuous, \
            f"{self.model.cfg.family} serves via generate_static"
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(tokens, np.int32).reshape(-1)
        self.scheduler.add(Request(rid=rid, prompt=prompt, max_new=max_new,
                                   eos_id=eos_id, arrival=arrival))
        return rid

    def step(self, *, now: Optional[float] = None, greedy: bool = True,
             seed: int = 0) -> List[Request]:
        """One engine step: admit + prefill, then batched decode.  Returns
        requests finished during this step."""
        finished = []
        for req in self.scheduler.admit(now):
            self._prefill_request(req, greedy, seed)
            if req.done():
                self.scheduler.finish(req)
                finished.append(req)
        running = self.scheduler.running
        if running:
            b, mp = self.slots, self.max_pages
            token = np.zeros((b, 1), np.int32)
            lens = np.zeros((b,), np.int32)
            counts = np.zeros((b,), np.int32)
            bt = np.zeros((b, mp), np.int32)
            for slot, req in running.items():
                token[slot, 0] = req.out_tokens[-1]
                lens[slot] = req.len
                counts[slot] = 1
                bt[slot] = req.pages.block_row(mp)
            logits, self.caches = self._paged_step(
                self.params, self.caches, jnp.asarray(token), jnp.asarray(bt),
                jnp.asarray(lens), jnp.asarray(counts))
            rows = np.asarray(logits[:, 0, :])
            for slot, req in list(running.items()):
                req.out_tokens.append(self._pick(rows[slot], req, greedy, seed))
                req.len += 1
                if req.done():
                    self.scheduler.finish(req)
                    finished.append(req)
        return finished

    def drain(self, *, greedy: bool = True, seed: int = 0) -> List[Request]:
        """Run steps until every queued request has finished."""
        finished = []
        while self.scheduler.has_work:
            finished.extend(self.step(greedy=greedy, seed=seed))
        return finished

    def _prefill_request(self, req: Request, greedy: bool, seed: int) -> None:
        """Prefill one admitted request at its own length (rounded up to a
        packed-tile bucket so prompt-length compilations amortize across
        requests; padded rows are masked into the trash page)."""
        l = req.prompt_len
        bucket = round_up(l, self._bucket)
        token = np.zeros((1, bucket), np.int32)
        token[0, :l] = req.prompt
        bt = req.pages.block_row(self.max_pages)[None]
        view = prefill_view(self.caches, fresh_slot_states(self.caches))
        logits, updated = self._paged_step(
            self.params, view, jnp.asarray(token), jnp.asarray(bt),
            jnp.zeros((1,), jnp.int32), jnp.full((1,), l, jnp.int32))
        self.caches = merge_slot(self.caches, updated, req.slot)
        req.len = l
        req.out_tokens.append(
            self._pick(np.asarray(logits[0, 0, :]), req, greedy, seed))

    def _pick(self, logits_row: np.ndarray, req: Request, greedy: bool,
              seed: int) -> int:
        if greedy:
            return int(np.argmax(logits_row))
        # per-request, per-position key: sampling is reproducible and
        # independent of batch composition, like the greedy path
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(seed), req.rid), len(req.out_tokens))
        return int(jax.random.categorical(key, jnp.asarray(logits_row)))

    # ------------------------------------------------------------------
    # batch API
    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new: int, *, greedy: bool = True,
                 seed: int = 0) -> np.ndarray:
        """batch: {"tokens": [B, L] prompt, (+frames/patches)}.

        Returns [B, max_new] generated tokens.  Compatibility wrapper: for
        decoder-only families each row becomes a request served by the
        continuous engine (results are identical to serving it alone);
        encdec/vlm use the static path.
        """
        if not self.continuous:
            return self.generate_static(batch, max_new, greedy=greedy,
                                        seed=seed)
        assert not self.scheduler.has_work, \
            "generate() needs an idle engine; use add_request/step instead"
        prompts = np.asarray(batch["tokens"])
        rids = [self.add_request(prompts[i], max_new)
                for i in range(prompts.shape[0])]
        by_rid = {r.rid: r for r in self.drain(greedy=greedy, seed=seed)}
        return np.stack([np.asarray(by_rid[rid].out_tokens[:max_new])
                         for rid in rids]).astype(np.int32)

    def generate_static(self, batch: dict, max_new: int, *,
                        greedy: bool = True, seed: int = 0) -> np.ndarray:
        """Static-batch generation (the pre-continuous-batching loop): every
        request in the batch shares one prompt length and decodes lock-step
        to ``max_new``.  Kept for encdec/vlm and as the benchmark baseline."""
        m = self.model
        prompts = jnp.asarray(batch["tokens"])
        b, plen = prompts.shape
        caches = m.prefill_cache(self.params, batch) if m.cfg.family == "encdec" \
            else m.init_cache(b, m.shape.seq_len)

        embeds = None
        if m.cfg.family == "vlm":
            embeds = m._embeds(self.params, batch)
            logits, caches = self._prefill(self.params, caches,
                                           jnp.zeros((b, embeds.shape[1]), jnp.int32),
                                           jnp.int32(0), embeds)
            pos = embeds.shape[1]
        else:
            logits, caches = self._prefill(self.params, caches, prompts,
                                           jnp.int32(0))
            pos = plen

        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(max_new - 1):
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(pos + i))
            if greedy:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            out.append(tok.astype(jnp.int32))
        return np.asarray(jnp.concatenate(out, axis=1))
