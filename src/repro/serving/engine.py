"""Serving engine: continuous batching over a paged, layout-aware KV cache.

The engine owns a fixed set of decode **slots** (the compiled decode step
shape never changes), a paged KV pool (page size = ``round_up(page_tokens,
m_r)`` of the active packed layout — KV pages are whole microkernel tiles),
and a FCFS :class:`~repro.serving.scheduler.Scheduler`.  Per engine step:

  1. admission: waiting requests take free slots when the pool has pages
     for their *prompt* plus a small watermark (lazy allocation — no
     full-lifetime reservation); each is prefilled at its own
     (layout-bucketed) length — no cross-request prompt padding;
  2. growth: every running slot gets a KV page for the position this step's
     token writes (``Scheduler.grow``); on pool exhaustion the
     youngest-admitted request is preempted — its pages are released, it is
     requeued at the front with generated tokens folded into the prompt,
     and re-admission recomputes the identical continuation;
  3. decode: every running slot advances one token in a single fixed-shape
     batched ``paged_decode_step``.  Slots preempted in phase 2 (and free
     slots) are masked into the trash page mid-step: their rows carry
     ``new_counts == 0`` and an all-zero block table, so the in-flight step
     writes their K/V to page 0 and can never corrupt a live request;
  4. eviction: finished requests release slot + pages immediately.

Rows are mathematically independent (per-row attention over per-row pages,
per-row softmax/argmax), so a request's greedy output is identical whatever
else shares the batch — admission order cannot change results.

The decode KV pool is sequence-shardable over the model axis (pages are the
sequence chunks; ``repro.distributed.sharding.cache_specs``) and weights are
pre-packed once (``prepack_params``) — the paper's amortized standalone
packing (§4.1) — so decode steps stream packed tiles directly.

``generate`` is a thin compatibility wrapper over add_request/step; the
encoder-decoder and VLM families (per-request encoder state, patch-prefix
prefill) still use the static-batch path (``generate_static``).
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import ceil_div, round_up
from repro.core.linear import prepack_params
from repro.distributed import sharding
from repro.models.model import ReproModel
from repro.serving.kv_cache import (PagedKVPool, fresh_slot_states,
                                    merge_slot, prefill_view)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine"]

_STATIC_FAMILIES = ("encdec", "vlm")


class Engine:
    def __init__(self, model: ReproModel, params, *, mesh=None,
                 prepack: bool = True, max_slots: Optional[int] = None,
                 page_tokens: int = 16, num_pages: Optional[int] = None,
                 eager: bool = False, watermark_pages: int = 1):
        self.model = model
        self.mesh = mesh
        self.params = (prepack_params(params, model.ctx)
                       if prepack and model.cfg.family != "encdec" else params)
        # static-batch path (encdec/vlm generate, throughput baselines);
        # prefill ([B, plen]) and decode ([B, 1]) are two traces of the one
        # model-cached jit — engines over the same model share compilations
        self._step = self._prefill = model.jit_step("decode")

        self.continuous = model.cfg.family not in _STATIC_FAMILIES
        self._next_rid = 0
        if not self.continuous:
            return

        layout = model.ctx.layout(model.compute_dtype)
        self._bucket = layout.m_r if all(
            t == "attn" for t in model.cfg.layer_types) else 1
        self.slots = max_slots or model.shape.global_batch
        max_len = model.shape.seq_len
        page_tokens = round_up(page_tokens, layout.m_r)
        if num_pages is None:
            num_pages = 1 + self.slots * ceil_div(max_len, page_tokens)
        self.pool = PagedKVPool(num_pages, page_tokens)
        self.max_pages = ceil_div(max_len, self.pool.page_tokens)
        self.scheduler = Scheduler(self.slots, self.pool, max_len,
                                   eager=eager,
                                   watermark_pages=watermark_pages)
        self.caches = model.init_paged_cache(num_pages, self.pool.page_tokens,
                                             self.slots)
        if mesh is not None:
            specs = sharding.cache_specs(self.caches, mesh, model.run,
                                         self.slots)
            self.caches = jax.device_put(self.caches,
                                         sharding.named(mesh, specs))
        self._paged_step = model.jit_step("paged")

    # ------------------------------------------------------------------
    # continuous-batching API
    # ------------------------------------------------------------------
    def add_request(self, tokens, max_new: int, *, eos_id: Optional[int] = None,
                    arrival: float = 0.0) -> int:
        """Queue one request.  Returns its request id."""
        assert self.continuous, \
            f"{self.model.cfg.family} serves via generate_static"
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(tokens, np.int32).reshape(-1)
        self.scheduler.add(Request(rid=rid, prompt=prompt, max_new=max_new,
                                   eos_id=eos_id, arrival=arrival))
        return rid

    @property
    def num_preemptions(self) -> int:
        return self.scheduler.num_preemptions

    def step(self, *, now: Optional[float] = None, greedy: bool = True,
             seed: int = 0) -> List[Request]:
        """One engine step: admit + prefill, grow (preempting on pool
        exhaustion), then batched decode.  Returns requests finished during
        this step."""
        finished = []
        for req in self.scheduler.admit(now):
            self._prefill_request(req, greedy, seed)
            if req.done():
                self.scheduler.finish(req)
                finished.append(req)
        # growth runs oldest-admission-first, so a just-prefilled arrival is
        # the preferred preemption victim; a preempted request simply drops
        # out of `running`, leaving its decode row with new_counts == 0 and
        # a zero block table — the fixed-shape step masks it into the trash
        # page mid-step instead of recompiling to a smaller batch
        self.scheduler.grow()
        running = self.scheduler.running
        if running:
            b, mp = self.slots, self.max_pages
            token = np.zeros((b, 1), np.int32)
            lens = np.zeros((b,), np.int32)
            counts = np.zeros((b,), np.int32)
            bt = np.zeros((b, mp), np.int32)
            for slot, req in running.items():
                token[slot, 0] = req.out_tokens[-1]
                lens[slot] = req.len
                counts[slot] = 1
                bt[slot] = req.pages.block_row(mp)
            logits, self.caches = self._paged_step(
                self.params, self.caches, jnp.asarray(token), jnp.asarray(bt),
                jnp.asarray(lens), jnp.asarray(counts))
            rows = np.asarray(logits[:, 0, :])
            for slot, req in list(running.items()):
                req.out_tokens.append(self._pick(rows[slot], req, greedy, seed))
                req.len += 1
                if req.done():
                    self.scheduler.finish(req)
                    finished.append(req)
        return finished

    def drain(self, *, greedy: bool = True, seed: int = 0) -> List[Request]:
        """Run steps until every queued request has finished."""
        finished = []
        while self.scheduler.has_work:
            finished.extend(self.step(greedy=greedy, seed=seed))
        return finished

    def _prefill_bucket(self, l: int) -> int:
        """Geometric (power-of-two tile-multiple) prefill bucket for a
        prompt of ``l`` tokens.  Preemption folds generated tokens into the
        prompt, so recompute prefills arrive at arbitrary lengths — linear
        ``round_up(l, m_r)`` bucketing would compile a fresh XLA program
        per distinct length, unbounded over a server's lifetime.  Geometric
        buckets cap the compile count at ``log2(max_len / m_r) + 1`` for at
        most 2x padded prefill compute (padding is masked into the trash
        page).  Only pure-attention models bucket (``_bucket > 1``):
        recurrent mixers carry state over *every* prefill token — padding
        is invisible to the KV mask but not to an ssm/rwkv scan — so hybrid
        archs prefill at exact length, as before."""
        if self._bucket == 1:
            return l
        b = self._bucket
        while b < l:
            b *= 2
        return min(b, round_up(self.scheduler.max_len, self._bucket))

    def warmup(self) -> None:
        """Pre-compile every step shape this engine can hit — the batched
        decode step and each geometric prefill bucket — before taking
        traffic.  Safe on an idle engine: the warmup calls run with
        ``new_counts == 0``, which routes every KV write to the trash page,
        so pool pages and live state are untouched."""
        assert self.continuous
        assert not self.scheduler.has_work, "warmup() needs an idle engine"
        zero = jnp.zeros((1,), jnp.int32)
        bt1 = jnp.zeros((1, self.max_pages), jnp.int32)
        if self._bucket > 1:       # hybrids prefill at exact (unbounded)
            b, seen = self._bucket, set()    # lengths — nothing to pre-compile
            while True:
                bucket = self._prefill_bucket(b)
                if bucket in seen:
                    break
                seen.add(bucket)
                view = prefill_view(self.caches,
                                    fresh_slot_states(self.caches))
                _, updated = self._paged_step(
                    self.params, view, jnp.zeros((1, bucket), jnp.int32), bt1,
                    zero, zero)
                self.caches = merge_slot(self.caches, updated, 0)
                b = bucket + 1
        zb = jnp.zeros((self.slots,), jnp.int32)
        _, self.caches = self._paged_step(
            self.params, self.caches, jnp.zeros((self.slots, 1), jnp.int32),
            jnp.zeros((self.slots, self.max_pages), jnp.int32), zb, zb)

    def _prefill_request(self, req: Request, greedy: bool, seed: int) -> None:
        """Prefill one admitted request at its own length (rounded up to a
        geometric packed-tile bucket so prompt-length compilations stay
        bounded and amortize across requests; padded rows are masked into
        the trash page)."""
        l = req.prompt_len
        bucket = self._prefill_bucket(l)
        token = np.zeros((1, bucket), np.int32)
        token[0, :l] = req.prompt
        bt = req.pages.block_row(self.max_pages)[None]
        view = prefill_view(self.caches, fresh_slot_states(self.caches))
        logits, updated = self._paged_step(
            self.params, view, jnp.asarray(token), jnp.asarray(bt),
            jnp.zeros((1,), jnp.int32), jnp.full((1,), l, jnp.int32))
        self.caches = merge_slot(self.caches, updated, req.slot)
        req.len = l
        req.out_tokens.append(
            self._pick(np.asarray(logits[0, 0, :]), req, greedy, seed))

    def _pick(self, logits_row: np.ndarray, req: Request, greedy: bool,
              seed: int) -> int:
        if greedy:
            return int(np.argmax(logits_row))
        # per-request, per-position key: sampling is reproducible and
        # independent of batch composition, like the greedy path
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(seed), req.rid), len(req.out_tokens))
        return int(jax.random.categorical(key, jnp.asarray(logits_row)))

    # ------------------------------------------------------------------
    # batch API
    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new: int, *, greedy: bool = True,
                 seed: int = 0, eos_id: Optional[int] = None,
                 return_reasons: bool = False):
        """batch: {"tokens": [B, L] prompt, (+frames/patches)}.

        Returns [B, max_new] generated tokens; rows that hit ``eos_id``
        before ``max_new`` are padded to the full width with ``eos_id``
        (rows never produce ragged lengths, so the result always stacks).
        With ``return_reasons=True`` also returns a length-B list of finish
        reasons ("eos" | "length").  Compatibility wrapper: for decoder-only
        families each row becomes a request served by the continuous engine
        (results are identical to serving it alone); encdec/vlm use the
        static path, where eos rows are truncated-and-padded post hoc.
        """
        if not self.continuous:
            # np.array: the static path hands back a buffer backed by a jax
            # array, which numpy imports read-only — copy before padding
            out = np.array(self.generate_static(batch, max_new, greedy=greedy,
                                                seed=seed))
            reasons = ["length"] * out.shape[0]
            if eos_id is not None:
                for i in range(out.shape[0]):
                    hits = np.flatnonzero(out[i] == eos_id)
                    # eos on the final token is "length", matching the
                    # continuous path (Request.done checks length first)
                    if hits.size and hits[0] < max_new - 1:
                        out[i, hits[0]:] = eos_id
                        reasons[i] = "eos"
            return (out, reasons) if return_reasons else out
        assert not self.scheduler.has_work, \
            "generate() needs an idle engine; use add_request/step instead"
        prompts = np.asarray(batch["tokens"])
        rids = [self.add_request(prompts[i], max_new, eos_id=eos_id)
                for i in range(prompts.shape[0])]
        by_rid = {r.rid: r for r in self.drain(greedy=greedy, seed=seed)}
        pad = 0 if eos_id is None else eos_id
        rows, reasons = [], []
        for rid in rids:
            req = by_rid[rid]
            toks = req.out_tokens[:max_new]
            rows.append(toks + [pad] * (max_new - len(toks)))
            reasons.append(req.finish_reason)
        out = np.asarray(rows, np.int32)
        return (out, reasons) if return_reasons else out

    def generate_static(self, batch: dict, max_new: int, *,
                        greedy: bool = True, seed: int = 0) -> np.ndarray:
        """Static-batch generation (the pre-continuous-batching loop): every
        request in the batch shares one prompt length and decodes lock-step
        to ``max_new``.  Kept for encdec/vlm and as the benchmark baseline."""
        m = self.model
        prompts = jnp.asarray(batch["tokens"])
        b, plen = prompts.shape
        caches = m.prefill_cache(self.params, batch) if m.cfg.family == "encdec" \
            else m.init_cache(b, m.shape.seq_len)

        embeds = None
        if m.cfg.family == "vlm":
            embeds = m._embeds(self.params, batch)
            logits, caches = self._prefill(self.params, caches,
                                           jnp.zeros((b, embeds.shape[1]), jnp.int32),
                                           jnp.int32(0), embeds)
            pos = embeds.shape[1]
        else:
            logits, caches = self._prefill(self.params, caches, prompts,
                                           jnp.int32(0))
            pos = plen

        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(max_new - 1):
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(pos + i))
            if greedy:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            out.append(tok.astype(jnp.int32))
        return np.asarray(jnp.concatenate(out, axis=1))
