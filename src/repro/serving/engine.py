"""Serving engine: continuous batching over a paged, layout-aware KV cache.

The engine owns a fixed set of decode **slots** (the compiled decode step
shape never changes), a paged KV pool (page size = ``round_up(page_tokens,
m_r)`` of the active packed layout — KV pages are whole microkernel tiles),
and a FCFS :class:`~repro.serving.scheduler.Scheduler`.  Per engine step:

  1. admission: waiting requests take free slots when the pool has pages
     for their *prompt* (chunked: their *next chunk*) plus a small
     watermark (lazy allocation — no full-lifetime reservation); each is
     prefilled at its own (layout-bucketed) length — no cross-request
     prompt padding;
  2. growth: every decoding slot gets a KV page for the position this
     step's token writes (``Scheduler.grow``); on pool exhaustion the
     youngest-admitted request is displaced — a decoding victim is
     preempted (pages released, generated tokens folded into the prompt,
     re-admission recomputes the identical continuation), a mid-prefill
     victim is paused (keeps pages + cursor, resumes instead of redoing
     written chunks);
  3. decode: every running slot advances one token in a single fixed-shape
     batched ``paged_decode_step``.  Slots displaced in phase 2 (and free
     slots) are masked into the trash page mid-step: their rows carry
     ``new_counts == 0`` and an all-zero block table, so the in-flight step
     writes their K/V to page 0 and can never corrupt a live request;
  4. eviction: finished requests release slot + pages immediately.

With ``chunk_tokens`` set (pure-attention models), prefill and decode fuse
into a **single ragged step under a per-step token budget**: the batch is
the fixed shape ``[slots, c]`` whenever any slot is prefilling — ``c``
drawn from a short geometric ladder ``chunk_tokens, chunk_tokens/2, ..
m_r`` sized to the step's largest chunk — and ``[slots, 1]`` otherwise
(``log2(chunk/m_r)+2`` compiled shapes, still below the monolithic
policy's ``log2`` prompt buckets), and every active row contributes between 1
token (decoding) and ``chunk_tokens`` (prefilling) via per-row
``new_counts``/positions — the paper's fixed-shape-grid argument (fix the
tile grid once, let occupancy vary) applied to the serving step.  A long
(or recompute-folded, hence unbounded) admission is spread across steps at
``chunk_tokens`` per step and **never stalls running decodes** — the
Sarathi-style chunked prefill ROADMAP asks for; inter-token latency during
an admission is bounded by one fused-step time instead of one full-prompt
prefill.  Chunk sizes are rounded up to the layout's ``m_r`` so chunk
writes land on whole microkernel tiles, like the (``m_r``-aligned) pages
they fill.  The same ragged multi-position row is the verify-step
primitive speculative decode needs (score k draft tokens in one step).
Monolithic prefill (``chunk_tokens=None``, the default) and ``eager=True``
remain the PR-1/2 baseline policies for the benchmark A/B; recurrent-mixer
families (ssm/rwkv/hybrid) always use them — a scan carries state through
*every* row position, so padded chunk rows are not inert for them.

With ``spec_tokens=k`` (pure-attention models) the engine decodes
**speculatively**: a pluggable :class:`~repro.serving.speculative.Drafter`
proposes up to ``k`` guesses per decoding row, the row feeds
``[fed-back token, d_1 .. d_k]`` through the same fixed-shape paged step
(``new_counts`` = 1 + draft length — per-row draft lengths ride the ragged
step exactly like per-row chunk lengths, zero new traces), ``logits_idx``
reads the target logits at every draft position from that one call, and
the acceptance rule in :mod:`repro.serving.speculative` keeps outputs
token-identical to the non-speculative engine — greedy and sampled — while
each accepted draft advances a row one extra token per step.  Page growth
books the ``k+1``-token ask speculatively (shed under pressure, never
preempted-for); rejected positions are rolled back by truncating the block
table (:meth:`SequencePages.truncate`), and a preemption can never fold a
rejected draft because ``out_tokens`` only ever holds accepted tokens.

With ``prefix_cache=True`` (pure-attention models, lazy allocation) the
engine shares KV pages across requests through a **layout-keyed prefix
cache** (:mod:`repro.serving.prefix_cache`): admission starts prefill at
the longest cached page-chain prefix of the prompt (shared pages are
refcounted and read-only; the one place the cursor can land inside a
shared page — a fully-cached prompt — CoW-splits it first), prefill
inserts newly-completed full pages as it goes (chunked) or at completion
(monolithic), and preemption releases pages *into the cache* so
re-admission recomputes only the uncached suffix.  Cached KV is
bit-identical to recomputed KV (pages are immutable once full and keyed by
layout + exact token content), so outputs are token-identical to
``prefix_cache=False`` by construction — greedy and sampled, both prefill
policies, speculation on or off — while shared system prompts prefill once
per *content* instead of once per request and preemption stops costing a
full recompute.  ``Engine.stats()["prefix_cache"]`` reports hit rate,
shared pages, CoW copies and evictions.

Rows are mathematically independent (per-row attention over per-row pages,
per-row softmax/argmax), so a request's greedy output is identical whatever
else shares the batch — admission order cannot change results.

The decode KV pool is sequence-shardable over the model axis (pages are the
sequence chunks; ``repro.distributed.sharding.cache_specs``) and weights are
pre-packed once (``prepack_params``) — the paper's amortized standalone
packing (§4.1) — so decode steps stream packed tiles directly.

``generate`` is a thin compatibility wrapper over add_request/step; the
encoder-decoder and VLM families (per-request encoder state, patch-prefix
prefill) still use the static-batch path (``generate_static``).
"""

from __future__ import annotations

import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import ceil_div, round_up
from repro.core.linear import prepack_params
from repro.distributed import sharding
from repro.models.model import ReproModel
from repro.obs.telemetry import NULL as OBS_NULL
from repro.obs.telemetry import NullTelemetry, Telemetry
from repro.serving.faults import StallError
from repro.serving.kv_cache import (PagedKVPool, PoolError, copy_pages,
                                    fresh_slot_states, merge_slot,
                                    prefill_view)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (AdmissionError, Request, Scheduler,
                                     finish_reason_for)
from repro.serving.speculative import Drafter, NgramDrafter, accept_tokens

__all__ = ["Engine"]

_STATIC_FAMILIES = ("encdec", "vlm")


class Engine:
    def __init__(self, model: ReproModel, params, *, mesh=None,
                 prepack: bool = True, max_slots: Optional[int] = None,
                 page_tokens: int = 16, num_pages: Optional[int] = None,
                 eager: bool = False, watermark_pages: int = 1,
                 chunk_tokens: Optional[int] = None,
                 flat: Optional[bool] = None,
                 token_budget: Optional[int] = None,
                 spec_tokens: Optional[int] = None,
                 drafter: Optional[Drafter] = None,
                 prefix_cache: bool = False,
                 queue_limit: Optional[int] = None,
                 queue_pages: Optional[int] = None,
                 watchdog_steps: int = 64,
                 nan_guard: bool = True,
                 telemetry=False):
        self.model = model
        self.mesh = mesh
        # observability (repro.obs): ``telemetry=True`` builds a live
        # Telemetry (metrics + trace recorder), a Telemetry instance is
        # used as-is, and the default keeps the no-op NULL recorder —
        # every instrumentation point below is then a single no-op call
        self.obs = (telemetry if isinstance(telemetry, NullTelemetry)
                    else Telemetry() if telemetry else OBS_NULL)
        # the roofline-grounded per-family step cost model; built once in
        # warmup() when telemetry is live (repro.obs.attrib) — the
        # warmup-only contract: nothing per-step ever lowers or compiles
        self.cost_model = None
        self.params = (prepack_params(params, model.ctx)
                       if prepack and model.cfg.family != "encdec" else params)
        # static-batch path (encdec/vlm generate, throughput baselines);
        # prefill ([B, plen]) and decode ([B, 1]) are two traces of the one
        # model-cached jit — engines over the same model share compilations
        self._step = self._prefill = model.jit_step("decode")

        self.continuous = model.cfg.family not in _STATIC_FAMILIES
        self._next_rid = 0
        if not self.continuous:
            assert not flat, \
                f"{model.cfg.family} serves via generate_static; the flat " \
                f"token-level step needs the continuous paged path"
            assert chunk_tokens is None, \
                f"{model.cfg.family} serves via generate_static; chunked " \
                f"prefill needs the continuous paged path"
            assert spec_tokens is None and drafter is None, \
                f"{model.cfg.family} serves via generate_static; " \
                f"speculative decode needs the continuous paged path"
            assert not prefix_cache, \
                f"{model.cfg.family} serves via generate_static; the " \
                f"prefix cache shares paged KV, which the static path " \
                f"does not use"
            return

        layout = model.ctx.layout(model.compute_dtype)
        all_attn = all(t == "attn" for t in model.cfg.layer_types)
        self._bucket = layout.m_r if all_attn else 1
        self.slots = max_slots or model.shape.global_batch
        max_len = model.shape.seq_len
        page_tokens = round_up(page_tokens, layout.m_r)
        if chunk_tokens is not None:
            assert chunk_tokens >= 1, \
                f"chunk_tokens={chunk_tokens}: a chunk must carry at least " \
                f"one token or prefills can never advance"
            assert all_attn, \
                f"chunked prefill: {model.cfg.name} mixes recurrent layers " \
                f"({model.cfg.layer_types}) — an ssm/rwkv scan carries " \
                f"state through padded chunk rows, so only pure-attention " \
                f"models fuse prefill chunks into the decode step"
            # chunk writes land on whole microkernel tiles, like pages
            chunk_tokens = min(round_up(chunk_tokens, layout.m_r),
                               round_up(max_len, layout.m_r))
        self.chunk_tokens = chunk_tokens
        self.chunked = chunk_tokens is not None
        # flat token-level batching (the default whenever chunking is on):
        # the fused step becomes one [1, W] m_r-packed token stream with
        # per-position row ids — a decode row costs its real 1+k positions
        # instead of a padded chunk-width row.  flat=False keeps the dense
        # [slots, chunk] step as the A/B baseline.
        self.flat = self.chunked if flat is None else bool(flat)
        if self.flat:
            assert self.chunked, \
                "flat=True needs chunk_tokens: the flat token-level step " \
                "rides the chunked scheduler (segments are its chunks)"
        # the fused step is dense, so its device cost is set by the SHAPE
        # (slots x chunk_tokens), not by how many of those positions carry
        # tokens — the rational default budget is therefore shape-limited
        # (throttling below it wastes padded compute); pass a smaller
        # token_budget to bound page-allocation raggedness instead
        self.token_budget = (token_budget if token_budget is not None
                             else max(1, self.slots * (chunk_tokens or 1)))
        assert self.token_budget >= 1
        if self.chunked:
            # liveness: when nothing is decoding, the oldest prefill must
            # be grantable one whole tile (plan_chunks rounds budget-clamped
            # grants down to the tile, so a sub-tile budget would zero
            # every grant forever)
            assert self.token_budget >= layout.m_r, \
                f"token_budget={self.token_budget} is below one microkernel " \
                f"tile (m_r={layout.m_r}); chunked prefill could never advance"
        if num_pages is None:
            num_pages = 1 + self.slots * ceil_div(max_len, page_tokens)
        self.pool = PagedKVPool(num_pages, page_tokens)
        self.pool.obs = self.obs
        self.max_pages = ceil_div(max_len, self.pool.page_tokens)
        # layout-keyed prefix cache: pages are shared byte-for-byte across
        # requests, so the hash chain is rooted in the layout geometry — a
        # layout change can never alias stale KV (pure-attention only: a
        # shared page rebuilds attention state by table lookup, but
        # recurrent scan state cannot be restored from cached pages)
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache:
            assert all_attn, \
                f"prefix cache: {model.cfg.name} mixes recurrent layers " \
                f"({model.cfg.layer_types}) — cached KV pages restore " \
                f"attention state by block-table lookup, but an ssm/rwkv " \
                f"scan state cannot be rebuilt from shared pages"
            assert not eager, \
                "prefix cache needs lazy allocation (eager=True reserves " \
                "full lifetimes, which refcounted shared pages would " \
                "double-count)"
            self.prefix_cache = PrefixCache(self.pool,
                                            layout_key=(layout.m_r,))
            self.prefix_cache.obs = self.obs
            self.pool.page_copier = self._copy_page
        self.scheduler = Scheduler(self.slots, self.pool, max_len,
                                   eager=eager,
                                   watermark_pages=watermark_pages,
                                   chunk_tokens=chunk_tokens,
                                   chunk_align=layout.m_r,
                                   prefix_cache=self.prefix_cache,
                                   queue_limit=queue_limit,
                                   queue_pages=queue_pages,
                                   telemetry=self.obs)
        # resilience ladder (overload + fault handling; faults.py injects,
        # this engine degrades): shed/cancelled requests leave through an
        # out-of-band finished buffer, a stuck drain trips the watchdog,
        # non-finite logits quarantine their row, and a failing drafter is
        # auto-disabled for the rest of the drain
        self.watchdog_steps = watchdog_steps
        self.nan_guard = nan_guard
        self._finished_oob: List[Request] = []
        self._retired_rids: set = set()    # every finished rid (analysis:
                                           # no retired rid may hold pages)
        self._no_progress_steps = 0
        self._watchdog_trips = 0
        self._drafter_errors = 0
        self._drafter_fail_streak = 0
        self._drafter_fail_limit = 3
        self._spec_disabled = False
        self._spec_auto_disables = 0
        # speculative decode (spec_tokens=k): every decode row may carry
        # 1 + k positions through the same fused ragged step
        self.spec_tokens = spec_tokens
        self.drafter: Optional[Drafter] = None
        if spec_tokens is not None:
            assert spec_tokens >= 1, \
                f"spec_tokens={spec_tokens}: speculation needs at least " \
                f"one draft position (use spec_tokens=None to disable)"
            assert all_attn, \
                f"speculative decode: {model.cfg.name} mixes recurrent " \
                f"layers ({model.cfg.layer_types}) — a rejected draft's " \
                f"KV rolls back by page truncation, but an ssm/rwkv scan " \
                f"state cannot un-absorb rejected positions"
            if self.chunked:
                assert self.chunk_tokens >= spec_tokens + 1, \
                    f"spec_tokens={spec_tokens} needs verify rows of " \
                    f"{spec_tokens + 1} positions, wider than " \
                    f"chunk_tokens={self.chunk_tokens} — the fused step's " \
                    f"shape ladder must cover the verify width"
            self.drafter = drafter if drafter is not None else NgramDrafter()
            self.drafter.attach(self)
            self.drafter.obs = self.obs
        else:
            assert drafter is None, "a drafter needs spec_tokens set"
        # step counters (Engine.stats)
        self._steps = 0
        self._step_time = 0.0
        self._active_rows = 0            # rows with new_counts > 0, summed
        self._mixed_steps = 0            # steps carrying >= 1 prefill chunk
        self._finished_count = 0
        self._finished_served = 0        # finished AND actually ran (was
                                         # admitted): the chunks-per-prompt
                                         # denominator — shed/expired-in-
                                         # queue rows never prefilled, so
                                         # counting them would understate it
        self._chunk_steps_total = 0      # prefill calls/chunks over finished
        self._prefill_tokens = 0         # prompt tokens actually computed
                                         # (cache hits skip theirs)
        # flat-step counters (token-exactness telemetry)
        self._flat_steps = 0
        self._flat_tokens = 0            # real tokens fed, summed over steps
        self._flat_width = 0             # compiled widths W, summed
        # speculative counters
        self._draft_time = 0.0           # host wall time inside the drafter
        self._drafted = 0                # draft tokens actually verified
        self._accepted = 0               # draft tokens accepted
        self._decode_tokens = 0          # tokens appended by decode rows
        self._decode_rows = 0            # decode row-steps (verify calls)
        self._spec_trims = 0             # draft lists trimmed by page caps
        self._rollback_pages = 0         # pages freed by rejected-KV truncate
        self.caches = model.init_paged_cache(num_pages, self.pool.page_tokens,
                                             self.slots)
        if mesh is not None:
            specs = sharding.cache_specs(self.caches, mesh, model.run,
                                         self.slots)
            self.caches = jax.device_put(self.caches,
                                         sharding.named(mesh, specs))
        self._paged_step = model.jit_step("paged")
        self._flat_step = model.jit_step("flat") if self.flat else None
        # opt-in runtime sanitizer (analysis.sanitize): wraps the jitted
        # steps with host-side pool-write contract checks — every written
        # page private (ref == 1), in range, never the trash page, and
        # every step width a declared ladder member
        self.sanitizer = None
        if os.environ.get("REPRO_SANITIZE", "0") not in ("", "0"):
            from repro.analysis.sanitize import install as _install_sanitizer
            _install_sanitizer(self)

    def _copy_page(self, src: int, dst: int) -> None:
        """Device-side copy-on-write: duplicate page ``src`` into ``dst``
        across every layer group's K/V pool (installed as the pool's
        ``page_copier``; host bookkeeping lives in ``PagedKVPool.cow``)."""
        self.caches = copy_pages(self.caches, jnp.int32(src), jnp.int32(dst))

    # ------------------------------------------------------------------
    # continuous-batching API
    # ------------------------------------------------------------------
    def add_request(self, tokens, max_new: int, *, eos_id: Optional[int] = None,
                    arrival: float = 0.0, temperature: float = 1.0,
                    seed: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    max_queue_s: Optional[float] = None) -> int:
        """Queue one request.  Returns its request id.

        ``temperature``/``seed`` are per-request sampling params (one batch
        mixes them freely): ``temperature=0`` forces greedy for this
        request even in a sampled drain; ``seed=None`` inherits the step's
        seed.  Per-request keys are what make sampled decode reproducible
        under preemption and speculation alike.

        ``deadline_s``/``max_queue_s`` bound the request's wall-clock
        lifetime / queue wait relative to ``arrival``, enforced whenever
        ``step(now=...)`` carries a clock.  Under admission control
        (``queue_limit``/``queue_pages``) an over-capacity add is shed:
        the request finishes immediately with ``finish_reason="rejected"``
        (delivered by the next ``step``/``drain``) instead of queueing
        unboundedly — only an *impossible* request (its lifetime can never
        fit the pool) still raises :class:`AdmissionError`."""
        assert self.continuous, \
            f"{self.model.cfg.family} serves via generate_static"
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(tokens, np.int32).reshape(-1)
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, arrival=arrival,
                      temperature=temperature, seed=seed,
                      deadline_s=deadline_s, max_queue_s=max_queue_s)
        try:
            self.scheduler.add(req)
        except AdmissionError as e:
            if e.kind == "impossible":
                raise              # a config error, not an overload signal
            req.status = "finished"
            req.finish_reason = "rejected"
            self.obs.request_shed(req, e.kind)
            self._finished_oob.append(req)
        return rid

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Cancel a live request from any lifecycle state (queued,
        prefilling, paused, decoding, mid-spec-rollback).  Its pages are
        released (into the prefix cache when one is attached) and it is
        delivered by the next ``step``/``drain`` with
        ``finish_reason=reason``.  Returns False if ``rid`` is not live."""
        req = self.scheduler.cancel(rid, reason)
        if req is None:
            return False
        self._finished_oob.append(req)
        return True

    @property
    def num_preemptions(self) -> int:
        return self.scheduler.num_preemptions

    @property
    def num_pauses(self) -> int:
        return self.scheduler.num_pauses

    def stats(self) -> dict:
        """Cumulative serving counters: per-step wall time, mean slot
        occupancy (active rows / slots, averaged over steps), prefill-stall
        steps, chunks-per-prompt over finished requests, displacements, XLA
        trace counts (zero growth after :meth:`warmup` is the no-recompile
        contract), plus scheduler and pool sub-stats."""
        assert self.continuous
        steps = max(1, self._steps)
        out = {
            "steps": self._steps,
            "mean_step_ms": 1e3 * self._step_time / steps,
            "mean_slot_occupancy": self._active_rows / (steps * self.slots),
            "mixed_steps": self._mixed_steps,
            "prefill_stall_steps": self.scheduler.prefill_stall_steps,
            "chunks_per_prompt": (self._chunk_steps_total
                                  / max(1, self._finished_served)),
            "finished": self._finished_count,
            "finished_served": self._finished_served,
            "num_preemptions": self.scheduler.num_preemptions,
            "num_pauses": self.scheduler.num_pauses,
            "prefill_tokens": self._prefill_tokens,
            "compiles": dict(self.model.trace_counts),
            "scheduler": self.scheduler.stats(),
            "pool": self.pool.stats(),
        }
        out["resilience"] = {
            "queue_depth": len(self.scheduler.waiting),
            "queue_limit": self.scheduler.queue_limit,
            "queue_pages": self.scheduler.queue_pages,
            "sheds": self.scheduler.num_rejected,
            "timeouts": self.scheduler.num_timeouts,
            "cancels": self.scheduler.num_cancels,
            "quarantines": self.scheduler.num_quarantines,
            "drafter_errors": self._drafter_errors,
            "spec_auto_disables": self._spec_auto_disables,
            "spec_disabled": self._spec_disabled,
            "watchdog_trips": self._watchdog_trips,
        }
        if self.prefix_cache is not None:
            out["prefix_cache"] = self.prefix_cache.stats()
        if self.obs.enabled:
            # the observability fragment of ROADMAP item 5: goodput
            # (tokens emitted inside deadline_s) and the headline p99s —
            # drain-scoped, like the registry metrics they read
            good = self.obs.c_goodput_tokens.value
            toks = self.obs.c_tokens_out.value
            lat = self.obs.latency_summary()
            out["slo"] = {
                "goodput_tokens": good,
                "tokens_out": toks,
                "goodput_ratio": good / max(1, toks),
                "ttft_p99_s": lat["ttft_s"]["p99"],
                "itl_p99_s": lat["itl_s"]["p99"],
                "e2e_p99_s": lat["e2e_s"]["p99"],
            }
        if self.flat:
            fs = max(1, self._flat_steps)
            out["flat"] = {
                "token_budget": self.token_budget,
                "steps": self._flat_steps,
                "mean_tokens": self._flat_tokens / fs,
                "mean_width": self._flat_width / fs,
                # real tokens per compiled position: the padding tax the
                # flat layout pays (1.0 = none; the dense [slots, chunk]
                # grid pays slots*chunk/real)
                "fill": self._flat_tokens / max(1, self._flat_width),
            }
        if self.spec_tokens is not None:
            out["speculative"] = {
                "spec_tokens": self.spec_tokens,
                "drafted": self._drafted,
                "accepted": self._accepted,
                "acceptance_rate": self._accepted / max(1, self._drafted),
                "accepted_per_step": self._accepted / steps,
                # decode tokens per decode-row activation: the speedup a
                # decode row sees from riding drafts (1.0 = no speculation)
                "decode_tokens_per_row_step": (self._decode_tokens
                                               / max(1, self._decode_rows)),
                "draft_time_ms": 1e3 * self._draft_time,
                "draft_overhead": (self._draft_time / self._step_time
                                   if self._step_time > 0 else 0.0),
                "spec_trims": self._spec_trims,
                "spec_grow_fallbacks": self.scheduler.spec_grow_fallbacks,
                "rollback_pages": self._rollback_pages,
                "drafter": self.drafter.stats(),
            }
        return out

    def telemetry(self, *, reset: bool = False, report=None) -> dict:
        """The unified observability view (continuous engine):

        - ``components`` — the classic per-component :meth:`stats` tree
          (engine/scheduler/pool/prefix-cache/drafter counters).  These
          are **lifetime**-cumulative and are never reset, with two
          documented exceptions that are per-drain by design
          (``spec_disabled`` and the drafter fail streak reset at the top
          of every :meth:`drain`) and one bounded window
          (``scheduler.resume_events``, a 256-entry deque).
        - ``metrics`` — the streaming registry snapshot (counters,
          gauges, histograms with p50/p95/p99); its ``_scope`` map labels
          each metric ``drain`` or ``lifetime``.
        - ``latency`` — the headline percentile summaries (TTFT, ITL,
          queue wait, e2e), empty when telemetry is off.
        - ``attribution`` — the per-drain roll-up from
          :mod:`repro.obs.attrib`: wall-time components, per-family
          predicted-vs-measured, MFU/MBU, padding waste, goodput.
        - ``alerts`` — the monitor bank's typed findings
          (:mod:`repro.obs.monitors`), as dicts, newest last.

        ``report="/path/base"`` additionally writes ``base.html`` (the
        single-file attribution report) and ``base.prom`` (Prometheus
        text exposition) via :func:`repro.obs.export.write_report`, and
        returns the paths under ``"report"``.

        ``reset=True`` zeroes the **drain-scoped registry metrics and
        attribution aggregates only**, after the snapshot is taken — the
        explicit per-drain reset (see :mod:`repro.obs.metrics`); nothing
        resets implicitly, so two drains without a reset read as one
        window, never double-counted.  ``stats()`` counters, the cost
        model and the alert history are untouched by ``reset``."""
        obs = self.obs
        out = {
            "enabled": obs.enabled,
            "components": self.stats() if self.continuous else {},
            "metrics": (obs.registry.snapshot()
                        if obs.registry is not None else {}),
            "latency": obs.latency_summary() if obs.enabled else {},
            "attribution": obs.attribution_summary(),
            "alerts": [a.to_dict() for a in obs.alerts],
        }
        if report is not None:
            assert obs.enabled, \
                "telemetry(report=...) needs a live telemetry engine"
            from repro.obs.export import write_report
            out["report"] = write_report(obs, report)
        if reset:
            obs.reset_drain()
        return out

    def step(self, *, now: Optional[float] = None, greedy: bool = True,
             seed: int = 0) -> List[Request]:
        """One engine step: admit, grow (displacing on pool exhaustion),
        then one fixed-shape batched model call — monolithic policy: a
        per-admission prefill plus a ``[slots, 1]`` decode; chunked policy:
        a single fused ragged ``[slots, chunk_tokens]`` step in which every
        active row carries 1 (decoding) to ``chunk_tokens`` (prefilling)
        new positions.  Returns requests finished during this step —
        including requests shed at admission, cancelled via
        :meth:`cancel`, and (when ``now`` carries a clock) requests whose
        ``deadline_s``/``max_queue_s`` elapsed, with finish reasons
        ``rejected``/``cancelled``/``timeout``/``error``."""
        t0 = time.perf_counter()
        self.obs.step_begin()
        finished = list(self._finished_oob)      # shed/cancelled since
        self._finished_oob.clear()               # the previous step
        if now is not None:
            finished.extend(self.scheduler.expire(now))
        if self.flat:
            finished.extend(self._step_flat(now, greedy, seed))
        elif self.chunked:
            finished.extend(self._step_chunked(now, greedy, seed))
        else:
            finished.extend(self._step_monolithic(now, greedy, seed))
        # idle ticks (an online replay polling before the next arrival) do
        # no work and must not dilute the per-step stats
        if self.scheduler.running or finished:
            self._steps += 1
            self._step_time += time.perf_counter() - t0
            self._no_progress_steps = 0
        else:
            self._watchdog(now)
        for req in finished:
            self._finished_count += 1
            if req.admit_seq >= 0:
                self._finished_served += 1
            self._chunk_steps_total += req.chunk_steps
            self._retired_rids.add(req.rid)
            if self.drafter is not None:
                self.drafter.forget(req.rid)
        self.obs.step_end(self.scheduler, self.pool, finished, now=now)
        return finished

    def _watchdog(self, now) -> None:
        """A step that admitted, advanced and finished nothing while
        already-arrived work sat waiting is a stall symptom.  One is
        legal (a displacement can empty the running set for a step);
        after ``watchdog_steps`` consecutive ones the drain is provably
        stuck — the termination proof guarantees the waiting head is
        eventually admitted, so a persistent no-progress streak means
        that guarantee was broken (e.g. a fault left the pool
        unsatisfiable) — and the watchdog turns the silent spin into a
        diagnosable :class:`StallError` naming the non-advancing rids."""
        stuck = [r for r in self.scheduler.waiting
                 if now is None or r.arrival <= now]
        if not stuck:
            self._no_progress_steps = 0          # idle poll before arrivals
            return
        self._no_progress_steps += 1
        if self._no_progress_steps >= self.watchdog_steps:
            self._watchdog_trips += 1
            self._no_progress_steps = 0
            raise StallError(
                f"no request advanced for {self.watchdog_steps} "
                f"consecutive steps; waiting: " +
                ", ".join(f"rid {r.rid} ({r.status}, cursor "
                          f"{r.prefill_cursor}/{r.prompt_len})"
                          for r in stuck) +
                f"; pool: {self.pool.num_available} of "
                f"{self.pool.usable_pages} pages available")

    def _quarantine(self, req: Request, finished: List[Request]) -> None:
        """Degradation ladder, bottom rung: a poisoned row (non-finite
        logits, failed rollback) is retired alone — pages freed, nothing
        inserted into the prefix cache — instead of poisoning the batch
        or the cache.  Survivors are unaffected: rows are independent
        and picks are (seed, rid, position)-keyed."""
        self.scheduler.cancel(req.rid, "error", cache_pages=False)
        finished.append(req)

    def _step_monolithic(self, now, greedy: bool, seed: int) -> List[Request]:
        finished = []
        # one admission at a time: each prefill lands its pages in the
        # prefix cache before the next admission's lookup runs, so
        # same-step arrivals sharing a prompt prefix share pages too
        # (without a cache this is byte-identical to batch admission)
        while True:
            admitted = self.scheduler.admit(now, limit=1)
            if not admitted:
                break
            req = admitted[0]
            if not self._prefill_request(req, greedy, seed):
                finished.append(req)             # quarantined at prefill
                continue
            if req.done():
                self.scheduler.finish(req)
                finished.append(req)
        # growth runs oldest-admission-first, so a just-prefilled arrival is
        # the preferred preemption victim; a preempted request simply drops
        # out of `running`, leaving its decode row with new_counts == 0 and
        # a zero block table — the fixed-shape step masks it into the trash
        # page mid-step instead of recompiling to a smaller batch.  Drafts
        # are proposed first so growth can book the k+1-token speculative
        # ask (a preempted row's proposal is simply dropped with the row)
        drafts = self._draft_and_grow()
        running = self.scheduler.running
        if running:
            neff = self._grant_drafts(running, drafts)
            b, mp = self.slots, self.max_pages
            # two compiled decode shapes: [slots, 1] (no drafts anywhere
            # this step) and the verify shape [slots, spec_tokens+1]
            spec = max(neff.values()) > 1
            s = self.spec_tokens + 1 if spec else 1
            token = np.zeros((b, s), np.int32)
            lens = np.zeros((b,), np.int32)
            counts = np.zeros((b,), np.int32)
            bt = np.zeros((b, mp), np.int32)
            idx = np.zeros((b, s), np.int32) if spec else None
            for slot, req in running.items():
                self._fill_decode_row(slot, req, neff[slot], drafts,
                                      token, lens, counts, bt, idx)
            self._active_rows += len(running)
            td = self.obs.clock()
            rows = self._run_paged(token, bt, lens, counts, idx)
            self.obs.device_span(td)
            self.obs.step_family(
                f"verify[{b},{s}]" if spec else f"decode[{b},1]",
                int(counts.sum()), b * s)
            for slot, req in list(running.items()):
                self._verify_decode_row(req, drafts.get(slot, []), rows[slot],
                                        neff[slot], greedy, seed, finished)
        return finished

    def _step_chunked(self, now, greedy: bool, seed: int) -> List[Request]:
        """The fused ragged step.  Decoding rows carry their fed-back token
        at position ``len`` (``new_counts == 1``); prefilling rows carry the
        next ``plan[slot]``-token slice of their prompt at positions
        ``prefill_cursor ..`` (``new_counts == n``); displaced/stalled/free
        rows are inert (``new_counts == 0``, zero block table — masked into
        the trash page).  Causal masking *within* a chunk against the paged
        past comes from the per-row 2-D positions the paged attention path
        already implements, so a chunk's logits at its last valid token
        equal the monolithic prefill's — chunking is invisible in the
        tokens (asserted by tests and the benchmark A/B)."""
        sched = self.scheduler
        finished = []
        sched.admit(now)
        # decode growth first: decodes are never stalled behind prefill work
        # (Sarathi's decode-prioritized schedule); a mid-prefill victim is
        # paused with its pages, not recomputed.  Speculation rides the
        # same fused step: a decode row's new_counts becomes 1 + its draft
        # length, pulled from the same shape ladder as prefill chunks
        drafts = self._draft_and_grow()
        running = sched.running
        if not running:
            return finished
        neff = self._grant_drafts(running, drafts)
        ndecode = sum(neff.values())
        plan = sched.plan_chunks(self.token_budget - ndecode)
        use_chunk = any(n > 0 for n in plan.values())
        b, mp = self.slots, self.max_pages
        widest = max(max(plan.values(), default=0),
                     max(neff.values(), default=0))
        s = self._chunk_shape(widest) if (use_chunk or widest > 1) else 1
        spec = any(n > 1 for n in neff.values())
        k1 = self.spec_tokens + 1 if spec else 1
        token = np.zeros((b, s), np.int32)
        lens = np.zeros((b,), np.int32)
        counts = np.zeros((b,), np.int32)
        bt = np.zeros((b, mp), np.int32)
        idx = np.zeros((b, k1), np.int32) if spec else None
        for slot, req in running.items():
            if req.status == "running":
                self._fill_decode_row(slot, req, neff[slot], drafts,
                                      token, lens, counts, bt, idx)
            else:
                n = plan.get(slot, 0)
                if n == 0:
                    continue              # stalled this step: inert row
                cur = req.prefill_cursor
                token[slot, :n] = req.prompt[cur:cur + n]
                lens[slot] = cur
                counts[slot] = n
                bt[slot] = req.pages.block_row(mp)
                if spec:
                    idx[slot] = n - 1     # its last chunk token, read at j=0
        total_new = int(counts.sum())
        if total_new == 0:
            self._watchdog_trips += 1
            raise StallError(
                "fused step scheduled zero tokens with live slots: " +
                ", ".join(f"rid {r.rid} ({r.status}, cursor "
                          f"{r.prefill_cursor}/{r.prompt_len}, len {r.len})"
                          for r in running.values()))
        # decodes (and their drafts) are unconditional; only prefill tokens
        # are budget-capped
        assert total_new <= max(self.token_budget, ndecode)
        self._active_rows += int((counts > 0).sum())
        self._mixed_steps += int(use_chunk)
        td = self.obs.clock()
        rows = self._run_paged(token, bt, lens, counts, idx)
        self.obs.device_span(td)
        self.obs.step_family(f"chunk[{b},{s}]" + ("/verify" if spec else ""),
                             total_new, b * s)
        for slot, req in list(running.items()):
            if req.status == "running":
                self._verify_decode_row(req, drafts.get(slot, []), rows[slot],
                                        neff[slot], greedy, seed, finished)
            else:
                n = plan.get(slot, 0)
                if n == 0:
                    continue
                if self.nan_guard and not np.isfinite(rows[slot]).all():
                    # before the cursor advance and the cache insert:
                    # a poisoned chunk's pages must never be shared
                    self._quarantine(req, finished)
                    continue
                req.prefill_cursor += n
                req.len = req.prefill_cursor
                req.chunk_steps += 1
                self._prefill_tokens += n
                self.obs.request_prefill_chunk(req, n)
                if self.prefix_cache is not None:
                    # write newly-completed full pages into the cache as
                    # the cursor advances — a later arrival (or this
                    # request's own preempt-resume) shares them mid-stream
                    self.prefix_cache.insert(req.prompt, req.pages.pages,
                                             req.prefill_cursor)
                if req.prefill_cursor < req.prompt_len:
                    continue              # more chunks to come
                # prefill complete: the logits at the last prompt token are
                # the first-token distribution, exactly as in monolithic
                req.status = "running"
                self.obs.request_prefill_done(req)
                req.out_tokens.append(
                    self._pick(rows[slot, 0], req, greedy, seed))
                if req.done():
                    sched.finish(req)
                    finished.append(req)
        return finished

    def _step_flat(self, now, greedy: bool, seed: int) -> List[Request]:
        """The flat token-level step (vLLM/Sarathi-style flat batching; the
        paper's fixed-shape-grid argument at token granularity).  One
        ``[1, W]`` stream — ``W`` from a geometric ladder over the token
        budget, ``m_r``-aligned — carries every scheduled row as a
        contiguous *segment*: per-position ``row_ids`` (-1 = padding) and
        absolute ``q_pos`` replace the dense step's per-row
        ``lens``/``new_counts``, and the segment-aware causal ragged
        attention (kernels/ragged_attn) reads each position's own row.  A
        decode row costs exactly its 1 + drafts real positions — no
        chunk-width padding tax — so the budget is token-exact.  Scheduling
        (admission, growth, chunk planning, stalls, preemption) is byte-
        identical to the dense chunked step; only the layout of the fed
        tokens changes, and outputs stay token-identical to both the dense
        and monolithic policies (asserted by tests/test_flat_step.py)."""
        sched = self.scheduler
        finished: List[Request] = []
        sched.admit(now)
        drafts = self._draft_and_grow()
        running = sched.running
        if not running:
            return finished
        neff = self._grant_drafts(running, drafts)
        decode_counts = {s: n for s, n in neff.items() if n > 0}
        segs = sched.plan_segments(decode_counts, self.token_budget)
        total = sum(n for _, _, n in segs)
        if total == 0:
            self._watchdog_trips += 1
            raise StallError(
                "flat step scheduled zero tokens with live slots: " +
                ", ".join(f"rid {r.rid} ({r.status}, cursor "
                          f"{r.prefill_cursor}/{r.prompt_len}, len {r.len})"
                          for r in running.values()))
        ndecode = sum(decode_counts.values())
        # decodes (and their drafts) are unconditional; only prefill
        # tokens are budget-capped — token-exact, not shape-limited
        assert total <= max(self.token_budget, ndecode)
        w = self._flat_shape(total)
        spec = any(n > 1 for n in decode_counts.values())
        k1 = self.spec_tokens + 1 if spec else 1
        token = np.zeros((1, w), np.int32)
        row_ids = np.full((w,), -1, np.int32)
        q_pos = np.zeros((w,), np.int32)
        bt = np.zeros((self.slots, self.max_pages), np.int32)
        idx = np.zeros((self.slots * k1,), np.int32)
        pos = 0
        segrefs = []
        for slot, kind, n in segs:
            req = running[slot]
            if kind == "decode":
                token[0, pos] = req.out_tokens[-1]
                if n > 1:
                    token[0, pos + 1:pos + n] = drafts[slot]
                q_pos[pos:pos + n] = req.len + np.arange(n)
            else:
                cur = req.prefill_cursor
                token[0, pos:pos + n] = req.prompt[cur:cur + n]
                q_pos[pos:pos + n] = cur + np.arange(n)
            row_ids[pos:pos + n] = slot
            bt[slot] = req.pages.block_row(self.max_pages)
            # decode rows read logits after every fed position (clamped to
            # their own width); prefill rows read their last chunk token
            if kind == "decode":
                idx[slot * k1:(slot + 1) * k1] = \
                    pos + np.minimum(np.arange(k1), n - 1)
            else:
                idx[slot * k1:(slot + 1) * k1] = pos + n - 1
            segrefs.append((slot, kind, n, req))
            pos += n
        self._active_rows += len(segrefs)
        self._mixed_steps += int(any(kind == "prefill"
                                     for _, kind, _ in segs))
        self._flat_steps += 1
        self._flat_tokens += total
        self._flat_width += w
        td = self.obs.clock()
        rows = self._run_flat(token, bt, row_ids, q_pos, idx)
        self.obs.device_span(td)
        self.obs.step_family(f"flat[1,{w}]/k{k1}", total, w)
        rows = rows.reshape(self.slots, k1, -1)
        for slot, kind, n, req in segrefs:
            if kind == "decode":
                self._verify_decode_row(req, drafts.get(slot, []),
                                        rows[slot], n, greedy, seed, finished)
                continue
            if self.nan_guard and not np.isfinite(rows[slot]).all():
                self._quarantine(req, finished)  # before the cache insert
                continue
            req.prefill_cursor += n
            req.len = req.prefill_cursor
            req.chunk_steps += 1
            self._prefill_tokens += n
            self.obs.request_prefill_chunk(req, n)
            if self.prefix_cache is not None:
                self.prefix_cache.insert(req.prompt, req.pages.pages,
                                         req.prefill_cursor)
            if req.prefill_cursor < req.prompt_len:
                continue                  # more chunks to come
            req.status = "running"
            self.obs.request_prefill_done(req)
            req.out_tokens.append(
                self._pick(rows[slot, 0], req, greedy, seed))
            if req.done():
                sched.finish(req)
                finished.append(req)
        return finished

    def _run_flat(self, token, bt, row_ids, q_pos, idx) -> np.ndarray:
        """One flat step; returns logits [K_out, V] at the flat ``idx``
        positions (K_out = slots * (spec_tokens+1 or 1))."""
        logits, self.caches = self._flat_step(
            self.params, self.caches, jnp.asarray(token), jnp.asarray(bt),
            jnp.asarray(row_ids), jnp.asarray(q_pos), jnp.asarray(idx))
        return np.asarray(logits)[0]

    def _flat_shapes(self) -> List[int]:
        """The flat step's geometric width ladder, descending: the token
        budget's ``m_r``-aligned cap (raised to ``slots * (spec_tokens+1)``
        when speculation can outgrow the budget — decode tokens are
        unconditional) plus every power-of-two multiple of ``m_r`` below
        it.  A decode-only step rides a width near its real token count
        instead of the full budget; compile count stays logarithmic."""
        cap = round_up(max(self.token_budget,
                           self.slots * ((self.spec_tokens or 0) + 1)),
                       self._bucket)
        shapes = {cap}
        v = self._bucket
        while v < cap:
            shapes.add(v)
            v *= 2
        return sorted(shapes, reverse=True)

    def _flat_shape(self, n: int) -> int:
        """Smallest ladder width holding ``n`` flat tokens."""
        shapes = self._flat_shapes()
        s = shapes[0]
        for cand in shapes:
            if cand >= n:
                s = cand
        return s

    # ------------------------------------------------------------------
    # speculative decode plumbing (no-ops when spec_tokens is None: every
    # row proposes nothing, carries n_eff == 1, and the verify loop
    # degenerates to the baseline one-pick decode)
    # ------------------------------------------------------------------
    def _propose_drafts(self) -> dict:
        """``{slot: [draft tokens]}`` for decoding rows, trimmed so a draft
        can never outlive ``max_new`` (the final generated token is never
        fed back, so at most ``max_new - generated - 1`` fed positions
        remain useful).  Host wall time is accounted as draft overhead.

        Degradation ladder: a drafter exception costs only that step's
        drafts (rows decode one token, same acceptance path, identical
        tokens); ``_drafter_fail_limit`` *consecutive* failures
        auto-disable speculation for the rest of the drain — a broken
        drafter degrades throughput, never correctness or liveness."""
        if self.drafter is None or self._spec_disabled:
            return {}
        t0 = time.perf_counter()
        jobs, slot_of = [], {}
        for slot, req in self.scheduler.running.items():
            if req.status != "running":
                continue
            k = min(self.spec_tokens, req.max_new - len(req.out_tokens) - 1)
            if k <= 0:
                continue
            jobs.append((req, k))
            slot_of[req.rid] = slot
        drafts = {}
        if jobs:
            # one batched call for the whole step's rows — a model-backed
            # drafter runs one [slots, 1] step per draft position instead
            # of k sequential [1, 1] steps per row (Drafter.propose_all;
            # the base class degenerates to the per-row loop)
            try:
                proposals = self.drafter.propose_all(jobs)
            except Exception:
                self._drafter_errors += 1
                self._drafter_fail_streak += 1
                self.obs.drafter_error()
                if self._drafter_fail_streak >= self._drafter_fail_limit:
                    self._spec_disabled = True
                    self._spec_auto_disables += 1
                proposals = {}
            else:
                self._drafter_fail_streak = 0
            for req, k in jobs:
                d = [int(t) for t in proposals.get(req.rid, [])][:k]
                if d:
                    drafts[slot_of[req.rid]] = d
        self._draft_time += time.perf_counter() - t0
        self.obs.draft_span(t0)
        return drafts

    def _draft_and_grow(self):
        """Propose drafts, then grow with the per-row ``1 + draft length``
        speculative ask (``grow`` sheds an ask rather than letting it force
        a displacement).  Returns the proposals, keyed by slot."""
        drafts = self._propose_drafts()
        self.scheduler.grow(want={s: 1 + len(d) for s, d in drafts.items()}
                            if drafts else None)
        return drafts

    def _fill_decode_row(self, slot: int, req: Request, n: int, drafts: dict,
                         token, lens, counts, bt, idx) -> None:
        """One decode row of the fused batch: the fed-back token plus the
        row's granted drafts at positions ``req.len ..``; ``idx`` (when the
        step carries any drafted row) reads logits at each fed position,
        clamped to the row's own width."""
        token[slot, 0] = req.out_tokens[-1]
        if n > 1:
            token[slot, 1:n] = drafts[slot]
        lens[slot] = req.len
        counts[slot] = n
        bt[slot] = req.pages.block_row(bt.shape[1])
        if idx is not None:
            idx[slot] = np.minimum(np.arange(idx.shape[1]), n - 1)

    def _grant_drafts(self, running, drafts) -> dict:
        """Per-row verify width actually granted: the fed-back token plus
        as many drafts as the row's post-grow page capacity covers —
        ``grow`` sheds a speculative ask under pool pressure rather than
        preempting for tokens that may be rejected, and page rounding can
        cover a draft or two for free.  Trims ``drafts`` in place; returns
        ``{slot: n_eff}`` (0 for prefilling rows, whose widths come from
        ``plan_chunks``)."""
        neff = {}
        for slot, req in running.items():
            if req.status != "running":
                neff[slot] = 0
                continue
            n = 1
            d = drafts.get(slot)
            if d:
                n = max(1, min(1 + len(d), req.pages.capacity - req.len))
                if len(d) > n - 1:
                    self._spec_trims += 1
                    if n == 1:
                        del drafts[slot]
                    else:
                        drafts[slot] = d[:n - 1]
            neff[slot] = n
        return neff

    def _run_paged(self, token, bt, lens, counts, idx) -> np.ndarray:
        """One fused paged step; returns per-row logits [B, K, V] (K = 1
        without speculation)."""
        logits, self.caches = self._paged_step(
            self.params, self.caches, jnp.asarray(token), jnp.asarray(bt),
            jnp.asarray(lens), jnp.asarray(counts),
            None if idx is None else jnp.asarray(idx))
        return np.asarray(logits)

    def _verify_decode_row(self, req: Request, drafts: List[int],
                           rows_slot: np.ndarray, n: int, greedy: bool,
                           seed: int, finished: List[Request]) -> None:
        """Accept the row's draft prefix (token-identical rule — see
        :mod:`repro.serving.speculative`), advance the cache length by the
        tokens whose KV is now live, truncate the block table past them
        (rejected-KV rollback), and retire the request if it completed.
        A non-finite logits row is quarantined *before* any token is
        committed; a rollback whose CoW split fails is quarantined after
        (its block table no longer matches its committed length, so the
        next step could read rejected KV) — either way pages are freed
        and nothing reaches the prefix cache."""
        if self.nan_guard and not np.isfinite(rows_slot).all():
            self._quarantine(req, finished)
            return
        appended, accepted = accept_tokens(
            req, drafts, rows_slot, n,
            lambda row, rq: self._pick(row, rq, greedy, seed))
        req.len += appended
        self._decode_rows += 1
        self._decode_tokens += appended
        if n > 1:
            self._drafted += n - 1
            self._accepted += accepted
            try:
                freed = req.pages.truncate(req.len)
            except PoolError:
                self._quarantine(req, finished)
                return
            self._rollback_pages += freed
            if freed:
                self.obs.spec_rollback(req, freed)
            # mid-draft eos (or any early stop): the block table must end
            # exactly at the last committed token — a page past it could
            # carry rejected/post-eos draft KV into a later prefix-cache
            # insert (preemption inserts up to req.len, but only pages
            # that exist can ever be shared)
            assert len(req.pages.pages) == self.pool.pages_for(req.len), \
                f"rollback left {len(req.pages.pages)} pages for " \
                f"len={req.len} (expected {self.pool.pages_for(req.len)})"
        if req.done():
            self.scheduler.finish(req)
            finished.append(req)

    def drain(self, *, greedy: bool = True, seed: int = 0,
              now: Optional[float] = None) -> List[Request]:
        """Run steps until every queued request has finished (including
        shed/cancelled/expired ones, delivered with their finish
        reasons).  The speculative auto-disable ladder is per-drain: a
        fresh drain gets its drafter back."""
        finished = []
        while self.scheduler.has_work or self._finished_oob:
            finished.extend(self.step(now=now, greedy=greedy, seed=seed))
        self._spec_disabled = False
        self._drafter_fail_streak = 0
        return finished

    def _prefill_bucket(self, l: int) -> int:
        """Geometric (power-of-two tile-multiple) prefill bucket for a
        prompt of ``l`` tokens.  Preemption folds generated tokens into the
        prompt, so recompute prefills arrive at arbitrary lengths — linear
        ``round_up(l, m_r)`` bucketing would compile a fresh XLA program
        per distinct length, unbounded over a server's lifetime.  Geometric
        buckets cap the compile count at ``log2(max_len / m_r) + 1`` for at
        most 2x padded prefill compute (padding is masked into the trash
        page).  Only pure-attention models bucket (``_bucket > 1``):
        recurrent mixers carry state over *every* prefill token — padding
        is invisible to the KV mask but not to an ssm/rwkv scan — so hybrid
        archs prefill at exact length, as before."""
        if self._bucket == 1:
            return l
        b = self._bucket
        while b < l:
            b *= 2
        return min(b, round_up(self.scheduler.max_len, self._bucket))

    def _chunk_shapes(self) -> List[int]:
        """The fused step's geometric shape ladder: ``chunk_tokens`` halved
        down to the layout tile (``m_r``), descending.  A step only pays
        for the largest chunk it actually carries — a final remainder chunk
        or a short-prompt admission rides a half/quarter-size shape — while
        the compile count stays ``log2(chunk/m_r)+2`` with the ``[slots,1]``
        decode shape, still below the monolithic policy's prompt buckets."""
        shapes = [self.chunk_tokens]
        while (shapes[-1] % 2 == 0 and shapes[-1] // 2 >= self._bucket
               and (shapes[-1] // 2) % self._bucket == 0):
            shapes.append(shapes[-1] // 2)
        return shapes

    def _chunk_shape(self, n: int) -> int:
        """Smallest ladder shape holding an ``n``-token chunk."""
        s = self.chunk_tokens
        for cand in self._chunk_shapes():
            if cand >= n:
                s = cand
        return s

    def warmup(self) -> None:
        """Pre-compile every step shape this engine can hit before taking
        traffic (:meth:`_warmup_shapes`), then — when telemetry is live —
        build the roofline-grounded per-family step cost model
        (:func:`repro.obs.attrib.build_cost_model`): every just-compiled
        family is lowered once more with ``ShapeDtypeStruct`` stand-ins
        (fresh ``jax.jit`` wrappers, so the counted ``jit_step`` caches
        and the zero-post-warmup-trace invariant are untouched) and priced
        against the host's :class:`~repro.core.hardware.HardwareSpec`.
        This is the **warmup-only cost-model contract**: prediction
        happens here and only here; per-step attribution is dict lookups
        on the frozen model, nothing per-step ever lowers, compiles, or
        reaches a jitted function."""
        self._warmup_shapes()
        if self.obs.enabled:
            from repro.obs.attrib import build_cost_model
            self.cost_model = build_cost_model(self)
            self.obs.attach_cost_model(self.cost_model)

    def _warmup_shapes(self) -> None:
        """Pre-compile every step shape this engine can hit before taking
        traffic — chunked: the fused ``[slots, c]`` step for every ladder
        shape ``c`` (``chunk_tokens`` halved down to ``m_r``) plus the
        ``[slots, 1]`` decode step; monolithic: the
        decode step plus each geometric prefill bucket.  With speculation
        on, additionally the verify variants: each decode-capable shape
        with the ``[slots, spec_tokens+1]`` logits gather (drafted steps
        read k+1 positions per row), and the drafter's own step shapes
        (``Drafter.warmup``).  After warmup a
        trace with admissions, chunked prefills, growth, preemption and
        speculation triggers zero new XLA compilations (regression-tested
        via the model's trace counter).  Safe on an idle engine: the warmup
        calls run with ``new_counts == 0``, which routes every KV write to
        the trash page, so pool pages and live state are untouched."""
        assert self.continuous
        assert not self.scheduler.has_work, "warmup() needs an idle engine"
        if self.prefix_cache is not None:
            # prime the CoW page-copy program (trash page onto itself:
            # contents are garbage by definition, live pages untouched)
            self._copy_page(0, 0)
        zb = jnp.zeros((self.slots,), jnp.int32)
        btb = jnp.zeros((self.slots, self.max_pages), jnp.int32)
        if self.flat:
            # every ladder width × every logits-gather width (spec steps
            # read slots*(k+1) flat positions, draft-free steps slots*1);
            # all-padding streams (row_ids == -1) route writes to the trash
            # page, so live state is untouched
            k1s = [1] + ([self.spec_tokens + 1]
                         if self.spec_tokens is not None else [])
            for w in self._flat_shapes():
                pad = jnp.full((w,), -1, jnp.int32)
                qz = jnp.zeros((w,), jnp.int32)
                for k1 in k1s:
                    _, self.caches = self._flat_step(
                        self.params, self.caches,
                        jnp.zeros((1, w), jnp.int32), btb, pad, qz,
                        jnp.zeros((self.slots * k1,), jnp.int32))
            if self.spec_tokens is not None:
                self.drafter.warmup()
            return
        idxz = (None if self.spec_tokens is None else
                jnp.zeros((self.slots, self.spec_tokens + 1), jnp.int32))
        if self.chunked:
            for s in self._chunk_shapes() + [1]:
                _, self.caches = self._paged_step(
                    self.params, self.caches,
                    jnp.zeros((self.slots, s), jnp.int32), btb, zb, zb, None)
            if idxz is not None:
                # any ladder shape can carry drafted rows (verify width
                # rides the chunk ladder; [slots, 1] never does — a drafted
                # step is at least spec_tokens+1 wide)
                for s in self._chunk_shapes():
                    _, self.caches = self._paged_step(
                        self.params, self.caches,
                        jnp.zeros((self.slots, s), jnp.int32), btb, zb, zb,
                        idxz)
                self.drafter.warmup()
            return
        zero = jnp.zeros((1,), jnp.int32)
        bt1 = jnp.zeros((1, self.max_pages), jnp.int32)
        if self._bucket > 1:       # hybrids prefill at exact (unbounded)
            b, seen = self._bucket, set()    # lengths — nothing to pre-compile
            while True:
                bucket = self._prefill_bucket(b)
                if bucket in seen:
                    break
                seen.add(bucket)
                view = prefill_view(self.caches,
                                    fresh_slot_states(self.caches))
                _, updated = self._paged_step(
                    self.params, view, jnp.zeros((1, bucket), jnp.int32), bt1,
                    zero, zero, None)
                self.caches = merge_slot(self.caches, updated, 0)
                b = bucket + 1
        _, self.caches = self._paged_step(
            self.params, self.caches, jnp.zeros((self.slots, 1), jnp.int32),
            btb, zb, zb, None)
        if idxz is not None:       # the monolithic verify shape
            _, self.caches = self._paged_step(
                self.params, self.caches,
                jnp.zeros((self.slots, self.spec_tokens + 1), jnp.int32),
                btb, zb, zb, idxz)
            self.drafter.warmup()

    def _prefill_request(self, req: Request, greedy: bool, seed: int) -> bool:
        """Prefill one admitted request at its own length (rounded up to a
        geometric packed-tile bucket so prompt-length compilations stay
        bounded and amortize across requests; padded rows are masked into
        the trash page).  With a prefix cache, admission already parked the
        cursor at the hit, so only the uncached suffix is computed — the
        shared prefix pages enter the step read-only through the block
        table, exactly like a decode row's past (lens = cursor).  Returns
        False when the row was quarantined for non-finite logits (before
        its KV is merged or its pages reach the prefix cache)."""
        l = req.prompt_len
        start = req.prefill_cursor
        n = l - start
        bucket = self._prefill_bucket(n)
        token = np.zeros((1, bucket), np.int32)
        token[0, :n] = req.prompt[start:]
        bt = req.pages.block_row(self.max_pages)[None]
        view = prefill_view(self.caches, fresh_slot_states(self.caches))
        td = self.obs.clock()
        logits, updated = self._paged_step(
            self.params, view, jnp.asarray(token), jnp.asarray(bt),
            jnp.full((1,), start, jnp.int32), jnp.full((1,), n, jnp.int32),
            None)
        row = np.asarray(logits[0, 0, :])
        self.obs.device_span(td)
        self.obs.step_family(f"prefill[1,{bucket}]", n, bucket)
        if self.nan_guard and not np.isfinite(row).all():
            self.scheduler.cancel(req.rid, "error", cache_pages=False)
            return False
        self.caches = merge_slot(self.caches, updated, req.slot)
        req.len = l
        req.prefill_cursor = l
        req.chunk_steps += 1        # a monolithic prefill is one big chunk
        self._prefill_tokens += n
        self.obs.request_prefill_chunk(req, n)
        self.obs.request_prefill_done(req)
        if self.prefix_cache is not None:
            self.prefix_cache.insert(req.prompt, req.pages.pages, l)
        req.out_tokens.append(self._pick(row, req, greedy, seed))
        return True

    def _pick(self, logits_row: np.ndarray, req: Request, greedy: bool,
              seed: int) -> int:
        if greedy or req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        # per-request, per-position key: sampling is reproducible and
        # independent of batch composition, like the greedy path — and of
        # speculation, whose acceptance rule recomputes exactly these picks
        s = seed if req.seed is None else req.seed
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(s), req.rid), len(req.out_tokens))
        row = jnp.asarray(logits_row)
        if req.temperature != 1.0:
            row = row / jnp.float32(req.temperature)
        return int(jax.random.categorical(key, row))

    # ------------------------------------------------------------------
    # batch API
    # ------------------------------------------------------------------
    def generate(self, batch: dict, max_new: int, *, greedy: bool = True,
                 seed: int = 0, eos_id: Optional[int] = None,
                 return_reasons: bool = False,
                 deadline_s: Optional[float] = None):
        """batch: {"tokens": [B, L] prompt, (+frames/patches)}.

        Returns [B, max_new] generated tokens; rows that finish early —
        ``eos_id`` hit, but also ``timeout``/``rejected``/``error`` under
        deadlines, admission control or quarantine — are padded to the
        full width exactly like eos rows (with ``eos_id``, or 0 when no
        eos is set), so rows never produce ragged lengths and the result
        always stacks.  With ``return_reasons=True`` also returns a
        length-B list of finish reasons ("eos" | "length" | "timeout" |
        "rejected" | "error").  ``deadline_s`` bounds each row's
        wall-clock lifetime (continuous engine only): the drain runs on a
        real clock and overdue rows finish with ``"timeout"``.
        Compatibility wrapper: for decoder-only families each row becomes
        a request served by the continuous engine (results are identical
        to serving it alone); encdec/vlm use the static path, where eos
        rows are truncated-and-padded post hoc.
        """
        if not self.continuous:
            assert deadline_s is None, \
                "deadline_s needs the continuous engine (the static path " \
                "decodes lock-step, with no per-request lifecycle)"
            # np.array: the static path hands back a buffer backed by a jax
            # array, which numpy imports read-only — copy before padding
            out = np.array(self.generate_static(batch, max_new, greedy=greedy,
                                                seed=seed))
            reasons = ["length"] * out.shape[0]
            if eos_id is not None:
                for i in range(out.shape[0]):
                    # one shared classification rule with the continuous
                    # path (scheduler.finish_reason_for) — the two can
                    # never drift: eos on the final token is "length"
                    kept, reasons[i] = finish_reason_for(out[i], max_new,
                                                         eos_id)
                    if reasons[i] == "eos":
                        out[i, kept - 1:] = eos_id
            return (out, reasons) if return_reasons else out
        assert not self.scheduler.has_work and not self._finished_oob, \
            "generate() needs an idle engine; use add_request/step instead"
        prompts = np.asarray(batch["tokens"])
        rids = [self.add_request(prompts[i], max_new, eos_id=eos_id,
                                 deadline_s=deadline_s)
                for i in range(prompts.shape[0])]
        if deadline_s is None:
            done = self.drain(greedy=greedy, seed=seed)
        else:
            done, t0 = [], time.perf_counter()
            while self.scheduler.has_work or self._finished_oob:
                done.extend(self.step(now=time.perf_counter() - t0,
                                      greedy=greedy, seed=seed))
            self._spec_disabled = False
            self._drafter_fail_streak = 0
        by_rid = {r.rid: r for r in done}
        pad = 0 if eos_id is None else eos_id
        rows, reasons = [], []
        for rid in rids:
            req = by_rid[rid]
            toks = req.out_tokens[:max_new]
            rows.append(toks + [pad] * (max_new - len(toks)))
            reasons.append(req.finish_reason)
        out = np.asarray(rows, np.int32)
        return (out, reasons) if return_reasons else out

    def generate_static(self, batch: dict, max_new: int, *,
                        greedy: bool = True, seed: int = 0) -> np.ndarray:
        """Static-batch generation (the pre-continuous-batching loop): every
        request in the batch shares one prompt length and decodes lock-step
        to ``max_new``.  Kept for encdec/vlm and as the benchmark baseline."""
        m = self.model
        prompts = jnp.asarray(batch["tokens"])
        b, plen = prompts.shape
        caches = m.prefill_cache(self.params, batch) if m.cfg.family == "encdec" \
            else m.init_cache(b, m.shape.seq_len)

        embeds = None
        if m.cfg.family == "vlm":
            embeds = m._embeds(self.params, batch)
            logits, caches = self._prefill(self.params, caches,
                                           jnp.zeros((b, embeds.shape[1]), jnp.int32),
                                           jnp.int32(0), embeds)
            pos = embeds.shape[1]
        else:
            logits, caches = self._prefill(self.params, caches, prompts,
                                           jnp.int32(0))
            pos = plen

        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(max_new - 1):
            logits, caches = self._step(self.params, caches, tok,
                                        jnp.int32(pos + i))
            if greedy:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            out.append(tok.astype(jnp.int32))
        return np.asarray(jnp.concatenate(out, axis=1))
