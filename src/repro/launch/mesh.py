"""Device meshes for the production topology.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run launcher
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
any JAX initialization.

Production topology (TPU v5e-like): 16x16 = 256 chips per pod; the
multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips) used for pure
data parallelism across pods (ICI within a pod, DCN across pods).

``make_elastic_mesh`` derives a best-effort (data, model) mesh from whatever
devices are currently alive — the restart path after a node failure
(checkpoints are mesh-agnostic, so training resumes on the reduced mesh).
"""

from __future__ import annotations

import math
from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_elastic_mesh", "dp_axes", "MESHES"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(model_parallel: int = 0):
    """Best-effort mesh over the currently-available devices.

    ``model_parallel=0`` picks the largest power-of-two TP degree that
    divides the device count and is <= 16 (one ICI dimension); the rest is
    data parallelism.  Used by the trainer on (re)start so a shrunken
    device set still yields a valid mesh.
    """
    n = len(jax.devices())
    if model_parallel <= 0:
        model_parallel = 1
        while (model_parallel < 16 and n % (model_parallel * 2) == 0
               and model_parallel * 2 <= n):
            model_parallel *= 2
    data = n // model_parallel
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


MESHES = {
    "pod": lambda: make_production_mesh(multi_pod=False),
    "multipod": lambda: make_production_mesh(multi_pod=True),
}
