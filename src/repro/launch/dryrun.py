import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell, build the jitted step
(train_step for train shapes, forward for prefill, decode_step for decode),
``.lower().compile()`` it against ShapeDtypeStruct inputs on the production
mesh, and record memory / cost / collective statistics for §Roofline.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); tests/benches that want 1 device must NOT
import this module — they use the library directly.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh pod --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, RunConfig, cells, get_config
from repro.configs.base import ShapeSpec
from repro.core.hardware import query
from repro.distributed import sharding
from repro.launch.mesh import MESHES
from repro.models.model import build_model
from repro.roofline.analysis import roofline_terms
from repro.training.optimizer import make_optimizer
from repro.training.step import make_train_step
from repro.training.train_state import TrainState


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def build_lowerable(arch: str, shape_name: str, run: RunConfig, mesh):
    """Returns (fn, example_args, in_shardings) for the cell's step."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg, run, shape, mesh=mesh)

    if shape.kind == "train":
        optimizer = make_optimizer(run)
        step = make_train_step(model, optimizer, run)
        params = _abstract(model.init, jax.random.PRNGKey(0))
        state = _abstract(lambda p: TrainState.create(p, optimizer), params)
        batch = model.input_specs("train")
        st_specs = sharding.state_specs(state, run, mesh)
        b_specs = sharding.batch_specs(batch, mesh)
        return step, (state, batch), (st_specs, b_specs), model, (0,)

    params = _abstract(model.init, jax.random.PRNGKey(0))
    p_specs = sharding.param_specs(params, run, mesh)

    if shape.kind == "prefill":
        def prefill(p, batch):
            # serving prefill: last-token logits only (the [B,S,vocab]
            # projection is skipped -- decode starts from these logits)
            logits, _ = model.forward(p, batch, last_only=True)
            return logits
        batch = model.input_specs("prefill")
        b_specs = sharding.batch_specs(batch, mesh)
        return prefill, (params, batch), (p_specs, b_specs), model, ()

    # decode
    specs = model.input_specs("decode")
    caches, token, pos = specs["caches"], specs["token"], specs["pos"]
    c_specs = sharding.cache_specs(caches, mesh, run, shape.global_batch)
    t_specs = sharding.batch_specs(token, mesh)
    from jax.sharding import PartitionSpec as P

    def serve_step(p, c, t, pos_):
        return model.decode_step(p, c, t, pos_)

    return (serve_step, (params, caches, token, pos),
            (p_specs, c_specs, t_specs, P()), model, (1,))


def run_cell(arch: str, shape_name: str, mesh_name: str, run: RunConfig,
             out_dir: Optional[str] = None, verbose: bool = True) -> dict:
    t0 = time.time()
    mesh = MESHES[mesh_name]()
    fn, args, in_specs, model, donate = build_lowerable(arch, shape_name, run, mesh)
    shardings = sharding.named(mesh, in_specs)
    with mesh:
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.roofline.hlo_cost import xla_cost_dict
    cost = xla_cost_dict(compiled.cost_analysis())
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {k: int(getattr(ma, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(ma, k)}
    except Exception:
        pass
    hlo = compiled.as_text()

    cfg = get_config(arch)
    report = roofline_terms(
        arch=arch, shape_spec=SHAPES[shape_name], mesh_name=mesh_name,
        chips=mesh.size, cfg=cfg, hw=query(), cost=cost, hlo_text=hlo,
        compute_dtype=run.compute_dtype, memory_stats=mem)
    rec = report.to_dict()
    rec.update({
        "status": "ok", "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "policy": run.layout_policy, "propagate": run.propagate,
        "fsdp": run.fsdp, "microbatch": run.microbatch,
        "params_total": cfg.param_counts()["total"],
        "params_active": cfg.param_counts()["active"],
        "hlo_bytes_len": len(hlo),
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} "
              f"({mesh.size} chips): OK  "
              f"compute {report.compute_s*1e3:.1f}ms  "
              f"memory {report.memory_s*1e3:.1f}ms  "
              f"collective {report.collective_s*1e3:.1f}ms  "
              f"-> {report.bottleneck}-bound  "
              f"roofline {report.roofline_fraction:.2f}  "
              f"(compile {t_compile:.0f}s)")
        if mem:
            print(f"         memory_analysis: {mem}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_name}_{run.layout_policy}" \
              + ("_noprop" if not run.propagate else "")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def serving_cell(arch: str, run: RunConfig, *, slots: int = 4,
                 max_len: int = 2048, page_tokens: int = 16,
                 chunk_tokens: int = 64, spec_tokens: Optional[int] = None,
                 out_dir: Optional[str] = None, verbose: bool = True) -> dict:
    """Serving dry-run cell (the first bite of ROADMAP item 2): predict
    the **flat paged decode step**'s cost before launch.  Builds the
    engine with abstract parameters (``jax.eval_shape`` over ``init`` —
    no weights are materialized) and the real paged-cache geometry, then
    prices every flat ladder width with the same warmup cost model live
    serving uses (:func:`repro.obs.attrib.build_cost_model`): roofline
    compute/memory seconds per step plus the two paged-attention traffic
    terms — per-step **KV-page gather bytes** (rows x block-table window
    x per-token KV bytes over the cache pools) and the **block-table
    gather bytes** themselves (rows x max_pages x 4B int32 indices)."""
    from repro.models.model import build_model as _build
    from repro.obs.attrib import build_cost_model, kv_page_bytes_per_token
    from repro.serving.engine import Engine

    t0 = time.time()
    cfg = get_config(arch)
    shape = ShapeSpec("serve_dryrun", max_len, slots, "decode")
    model = _build(cfg, run, shape)
    params = _abstract(model.init, jax.random.PRNGKey(0))
    eng = Engine(model, params, prepack=False, max_slots=slots,
                 page_tokens=page_tokens, chunk_tokens=chunk_tokens,
                 spec_tokens=spec_tokens)
    hw = query()
    cm = build_cost_model(eng, hw=hw)
    kv_tok = kv_page_bytes_per_token(eng.caches, eng.pool.num_pages,
                                     eng.pool.page_tokens)
    bt_bytes = eng.slots * eng.max_pages * 4        # int32 block table
    rec = {
        "status": "ok", "arch": arch, "kind": "serving-flat",
        "slots": slots, "max_len": max_len,
        "page_tokens": eng.pool.page_tokens,
        "num_pages": eng.pool.num_pages,
        "chunk_tokens": eng.chunk_tokens,
        "token_budget": eng.token_budget,
        "spec_tokens": spec_tokens,
        "kv_bytes_per_token": kv_tok,
        "block_table_gather_bytes": bt_bytes,
        "block_table_gather_s": bt_bytes / hw.hbm_bw,
        "cost_model": cm.to_dict(),
        "build_s": round(time.time() - t0, 2),
    }
    if verbose:
        print(f"[dryrun] {arch} serving flat step ({slots} slots, "
              f"max_len {max_len}, pages {eng.pool.num_pages} x "
              f"{eng.pool.page_tokens}t, KV {kv_tok:.0f} B/token, "
              f"block-table gather {bt_bytes} B/step):")
        for label in sorted(cm.families):
            fc = cm.families[label]
            print(f"  {label:>18}: predicted {fc.predicted_s * 1e6:8.1f}us "
                  f"({fc.bottleneck}-bound)  KV gather "
                  f"{fc.kv_gather_bytes / 2 ** 20:7.2f} MiB "
                  f"({fc.kv_gather_s * 1e6:7.1f}us at "
                  f"{hw.hbm_bw / 1e9:.0f} GB/s)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}_serving_flat.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--policy", default="scalable",
                    choices=["scalable", "fixed", "unpacked"])
    ap.add_argument("--no-propagate", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--serving", action="store_true",
                    help="dry-run the flat paged decode step instead of "
                         "the distributed train/prefill/decode cells")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=2048)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=64)
    ap.add_argument("--spec-tokens", type=int, default=None)
    args = ap.parse_args()

    run = RunConfig(layout_policy=args.policy, propagate=not args.no_propagate,
                    fsdp=not args.no_fsdp, microbatch=args.microbatch)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if args.serving:
        assert args.arch, "--serving needs --arch"
        serving_cell(args.arch, run, slots=args.slots, max_len=args.max_len,
                     page_tokens=args.page_tokens,
                     chunk_tokens=args.chunk_tokens,
                     spec_tokens=args.spec_tokens, out_dir=args.out)
        return

    if args.all:
        todo = [(a, s) for a, s, ok, _ in cells() if ok]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        for mesh_name in meshes:
            try:
                run_cell(arch, shape, mesh_name, run, out_dir=args.out)
            except Exception as e:  # a failure here is a sharding bug
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[dryrun] {arch} x {shape} x {mesh_name}: FAIL {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print(f"[dryrun] all {len(todo) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
