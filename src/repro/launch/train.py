"""Training launcher.

Single-process form of the per-host entrypoint a multi-controller launch
would run (jax.distributed.initialize + the same code).  Derives an elastic
mesh from live devices, shards state/batches by the rule engine, and runs
the fault-tolerant trainer (auto-resume, atomic checkpoints, straggler
watchdog).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm2-135m \
        --steps 200 --seq 256 --batch 8 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import RunConfig, get_config, reduced_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.training.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for CPU-scale runs")
    ap.add_argument("--policy", default="scalable",
                    choices=["scalable", "fixed", "unpacked"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--adam-8bit", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (restart drills)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    run = RunConfig(layout_policy=args.policy, microbatch=args.microbatch,
                    param_dtype=args.dtype, compute_dtype=args.dtype,
                    lr=args.lr, adam_8bit=args.adam_8bit,
                    grad_compression=args.grad_compression,
                    remat=False, warmup_steps=min(20, args.steps // 5 + 1))

    model = build_model(cfg, run, shape)
    data = SyntheticLM(cfg, shape, seed=args.seed,
                       text_len=model.text_len)
    trainer = Trainer(model, data, run, ckpt_dir=args.ckpt_dir,
                      total_steps=args.steps, ckpt_every=args.ckpt_every)
    state, history = trainer.fit(jax.random.PRNGKey(args.seed),
                                 fail_at=args.fail_at)
    if history:
        print(f"[train] {cfg.name}: step {int(state.step)}  "
              f"loss {history[0]:.3f} -> {history[-1]:.3f}  "
              f"stragglers={trainer.straggler_events}")
    else:
        print(f"[train] {cfg.name}: already at step {int(state.step)}, "
              f"nothing to do")
    return state, history


if __name__ == "__main__":
    main()
