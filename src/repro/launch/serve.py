"""Serving launcher: continuous-batching generation with packed weights.

Requests arrive with ragged prompt lengths and per-request token budgets;
the engine admits them into decode slots over a paged KV cache and streams
per-request completions (``--static`` runs the old lock-step batch loop for
comparison).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm2-135m \
        --reduced --requests 8 --slots 4 --new 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, reduced_config
from repro.configs.base import ShapeSpec
from repro.models.model import build_model
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length (lengths are mixed up to this)")
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="KV pool size in pages (default: ample); undersized "
                    "pools are served via preemption-by-recomputation")
    ap.add_argument("--eager", action="store_true",
                    help="reserve each request's full KV lifetime at "
                    "admission (the pre-lazy baseline policy)")
    ap.add_argument("--policy", default="scalable")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="static-batch baseline (one shared prompt length)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeSpec("serve", args.max_len, args.slots, "decode")
    run = RunConfig(layout_policy=args.policy, param_dtype="float32",
                    compute_dtype="float32", remat=False)
    model = build_model(cfg, run, shape)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, max_slots=args.slots,
                    page_tokens=args.page_tokens, num_pages=args.pool_pages,
                    eager=args.eager)

    key = jax.random.PRNGKey(args.seed + 1)
    if args.static or not engine.continuous:
        batch = {"tokens": jax.random.randint(
            key, (args.slots, args.prompt_len), 0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                key, (args.slots, args.max_len // cfg.audio_downsample,
                      cfg.d_model))
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (args.slots, cfg.vision_tokens, cfg.d_model))
        out = engine.generate_static(batch, args.new)
        print(f"[serve] {cfg.name} (static): generated {out.shape} tokens")
        print(out[:, :16])
        return out

    rng = np.random.default_rng(args.seed + 2)
    for i in range(args.requests):
        plen = int(rng.integers(2, args.prompt_len + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        engine.add_request(prompt, int(rng.integers(1, args.new + 1)))
    finished = engine.drain()
    total = sum(len(r.out_tokens) for r in finished)
    print(f"[serve] {cfg.name}: {len(finished)} requests, {total} tokens "
          f"(paged KV: {engine.pool.page_tokens} tok/page, "
          f"{engine.pool.num_pages} pages, peak {engine.pool.peak_used} "
          f"used, {engine.num_preemptions} preemptions)")
    for r in sorted(finished, key=lambda r: r.rid)[:8]:
        print(f"  rid={r.rid} prompt={r.prompt_len:>3} "
              f"new={len(r.out_tokens):>3} [{r.finish_reason}] "
              f"{r.out_tokens[:8]}")
    return finished


if __name__ == "__main__":
    main()
