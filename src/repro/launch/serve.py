"""Serving launcher: batched greedy/sampled generation with packed weights.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm2-135m \
        --reduced --batch 4 --prompt-len 16 --new 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config, reduced_config
from repro.configs.base import ShapeSpec
from repro.models.model import build_model
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--policy", default="scalable")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeSpec("serve", args.max_len, args.batch, "decode")
    run = RunConfig(layout_policy=args.policy, param_dtype="float32",
                    compute_dtype="float32", remat=False)
    model = build_model(cfg, run, shape)
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.max_len // cfg.audio_downsample, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vision_tokens, cfg.d_model))

    engine = Engine(model, params)
    out = engine.generate(batch, args.new)
    print(f"[serve] {cfg.name}: generated {out.shape} tokens")
    print(out[:, :16])
    return out


if __name__ == "__main__":
    main()
