"""Chrome ``trace_event`` recorder — drains become Perfetto timelines.

The recorder accumulates a flat list of trace-event dicts in the format
consumed by Perfetto and ``chrome://tracing`` (the Trace Event Format's
JSON flavour: ``{"traceEvents": [...]}``).  Events used here:

- ``"X"`` **complete** spans — a named interval with ``ts`` + ``dur``
  (microseconds).  Used for everything that nests cleanly on one track:
  per-slot prefill chunks and decode runs, per-step ``step`` spans and
  their ``device`` / ``draft`` sub-spans on the engine track.
- ``"b"`` / ``"e"`` **async** spans — id-matched begin/end pairs that may
  overlap on a track.  Used for queue-wait episodes on the scheduler
  track (many requests wait concurrently) — ``cat`` + ``id`` pair them.
- ``"i"`` **instant** events — point markers: preemptions, pauses,
  reclaims, CoW copies, spec rollbacks, sheds, timeouts, quarantines,
  injected faults, prefix-cache hits and evictions.
- ``"C"`` **counter** events — stacked series (pool pages in use, queue
  depth, running slots) sampled once per engine step.
- ``"M"`` **metadata** — ``thread_name`` records, one per track, so the
  UI shows ``slot 3`` / ``scheduler`` / ``pool`` instead of bare tids.

Track model: one process (``pid`` 1), one thread (track) per serving
slot plus dedicated ``engine`` / ``scheduler`` / ``pool`` tracks.
Timestamps are ``time.perf_counter()`` deltas from recorder birth,
scaled to integer microseconds — monotone by construction, which is what
the schema test asserts per track.

The recorder is bounded: ``max_events`` (default 1 << 20) caps memory on
unbounded drains; when full, new events are dropped and counted
(``dropped``) rather than growing without limit.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

__all__ = ["TraceRecorder"]

_PID = 1


class TraceRecorder:
    """Accumulates Chrome trace events host-side; :meth:`export` writes
    the ``{"traceEvents": [...]}`` JSON Perfetto loads directly."""

    def __init__(self, *, clock=time.perf_counter, max_events: int = 1 << 20):
        self._clock = clock
        self._t0 = clock()
        self._events: List[dict] = []
        self._tracks: Dict[str, int] = {}
        self._next_tid = 1
        self._max_events = max_events
        self.dropped = 0

    # ------------------------------------------------------------------
    def now_us(self) -> int:
        """Current trace timestamp (µs since recorder birth)."""
        return int((self._clock() - self._t0) * 1e6)

    def to_us(self, t: float) -> int:
        """Convert an absolute ``perf_counter()`` reading to trace µs."""
        return int((t - self._t0) * 1e6)

    def track(self, name: str) -> int:
        """Get-or-create the tid for a named track (emits the
        ``thread_name`` metadata record on first use)."""
        tid = self._tracks.get(name)
        if tid is None:
            tid = self._next_tid
            self._next_tid += 1
            self._tracks[name] = tid
            self._events.append({
                "ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
                "args": {"name": name},
            })
        return tid

    def _emit(self, ev: dict) -> None:
        if len(self._events) >= self._max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    # ------------------------------------------------------------------
    def complete(self, track: str, name: str, t0: float, t1: float,
                 args: Optional[dict] = None) -> None:
        """A closed ``"X"`` span from absolute clock readings ``t0..t1``."""
        ts = self.to_us(t0)
        ev = {"ph": "X", "name": name, "pid": _PID, "tid": self.track(track),
              "ts": ts, "dur": max(0, self.to_us(t1) - ts)}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, track: str, name: str, t: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        ev = {"ph": "i", "name": name, "pid": _PID, "tid": self.track(track),
              "ts": self.now_us() if t is None else self.to_us(t), "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_begin(self, track: str, name: str, id_: int,
                    t: Optional[float] = None,
                    args: Optional[dict] = None) -> None:
        """Open an overlappable span (queue-wait episodes share a track)."""
        ev = {"ph": "b", "cat": "req", "name": name, "id": id_,
              "pid": _PID, "tid": self.track(track),
              "ts": self.now_us() if t is None else self.to_us(t)}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_end(self, track: str, name: str, id_: int,
                  t: Optional[float] = None) -> None:
        self._emit({"ph": "e", "cat": "req", "name": name, "id": id_,
                    "pid": _PID, "tid": self.track(track),
                    "ts": self.now_us() if t is None else self.to_us(t)})

    def counter(self, track: str, name: str, values: Dict[str, float],
                t: Optional[float] = None) -> None:
        """A ``"C"`` sample — ``values`` become stacked series in the UI."""
        self._emit({"ph": "C", "name": name, "pid": _PID,
                    "tid": self.track(track),
                    "ts": self.now_us() if t is None else self.to_us(t),
                    "args": dict(values)})

    # ------------------------------------------------------------------
    def events(self) -> List[dict]:
        """The event list (live reference; treat as read-only)."""
        return self._events

    def to_json(self) -> dict:
        """The full trace document, events sorted by timestamp (metadata
        first) as the viewers prefer."""
        order = {"M": 0}
        evs = sorted(self._events,
                     key=lambda e: (order.get(e["ph"], 1), e.get("ts", 0)))
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def export(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
