"""Exposition formats: Prometheus text and a single-file HTML report.

Zero-dependency (stdlib string building only) renderers over the
telemetry layer's already-computed state — nothing here observes, times,
or mutates anything; both functions are pure views a caller invokes
after (or between) drains, typically via ``Engine.telemetry(report=...)``.

**Prometheus** (:func:`prometheus_text`): the text exposition format,
version 0.0.4.  Counters become ``<prefix><name>_total`` counter
samples, gauges become gauges, histograms become *summaries* (quantile
label per percentile plus ``_sum``/``_count``) — the streaming
histograms already answer percentiles in O(buckets), so shipping ~120
cumulative ``le`` buckets per metric would cost exposition size for no
extra fidelity.  Per-family attribution rows ride a ``family`` label;
alerts ship as an ``alerts_total`` counter by ``kind``.  Metric and
label naming, sample uniqueness and counter monotonicity are linted by
:func:`lint_prometheus` (pure python, used by both ``tests/test_attrib``
and the ``scripts/tier1.sh --report`` smoke).

**HTML** (:func:`html_report`): one self-contained file — inline CSS,
no scripts, no external fetches — with the attribution waterfall
(sched/device/draft/host plus padding waste as a device sub-bar), the
per-family predicted-vs-measured table, latency percentiles, and the
alert log.  Opens from a file:// URL on an air-gapped box.
"""

from __future__ import annotations

import html
import re
from typing import List

__all__ = ["prometheus_text", "lint_prometheus", "html_report",
           "write_report"]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_QUANTILES = (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99"))


def _esc_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v) -> str:
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def prometheus_text(telemetry, *, prefix: str = "repro_") -> str:
    """Render a live :class:`~repro.obs.telemetry.Telemetry` (its
    registry, attribution aggregates, and alerts) as Prometheus text."""
    from repro.obs.metrics import Counter, Gauge, Histogram

    lines: List[str] = []

    def head(name: str, kind: str, help_: str) -> None:
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")

    reg = telemetry.registry
    for name in sorted(reg._metrics):
        m = reg._metrics[name]
        if isinstance(m, Counter):
            full = f"{prefix}{name}_total"
            head(full, "counter", f"{name} ({m.scope}-scoped counter)")
            lines.append(f"{full} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            full = f"{prefix}{name}"
            head(full, "gauge", f"{name} (momentary level)")
            lines.append(f"{full} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            full = f"{prefix}{name}"
            head(full, "summary", f"{name} (streaming histogram)")
            snap = m.snapshot()
            for q, key in _QUANTILES:
                lines.append(f'{full}{{quantile="{q}"}} {_fmt(snap[key])}')
            lines.append(f"{full}_sum {_fmt(m.total)}")
            lines.append(f"{full}_count {_fmt(m.count)}")

    summary = telemetry.attribution_summary()
    fams = summary.get("families", {})
    if fams:
        specs = [("family_steps_total", "counter", "steps", 1.0,
                  "steps executed per shape family"),
                 ("family_real_tokens_total", "counter", "real_tokens", 1.0,
                  "real tokens fed per shape family"),
                 ("family_padded_tokens_total", "counter", "padded_tokens",
                  1.0, "padded grid positions per shape family"),
                 ("family_device_seconds_total", "counter", "device_s", 1.0,
                  "measured device seconds per shape family"),
                 ("family_predicted_seconds_total", "counter", "predicted_s",
                  1.0, "roofline-predicted seconds per shape family"),
                 ("family_padding_waste_seconds_total", "counter",
                  "padding_waste_s", 1.0,
                  "padded-position device seconds per shape family")]
        for mname, kind, key, scale, help_ in specs:
            full = f"{prefix}{mname}"
            head(full, kind, help_)
            for label in sorted(fams):
                lines.append(
                    f'{full}{{family="{_esc_label(label)}"}} '
                    f"{_fmt(fams[label][key] * scale)}")
    for key in ("mfu", "mbu", "padding_waste_ratio", "goodput_ratio"):
        if key in summary:
            full = f"{prefix}{key}"
            head(full, "gauge", f"per-drain {key}")
            lines.append(f"{full} {_fmt(summary[key])}")

    counts: dict = {}
    for a in telemetry.alerts:
        counts[a.kind] = counts.get(a.kind, 0) + 1
    if telemetry.monitors is not None:
        full = f"{prefix}alerts_total"
        head(full, "counter", "anomaly alerts by kind")
        for kind in sorted(counts):
            lines.append(f'{full}{{kind="{_esc_label(kind)}"}} '
                         f"{_fmt(counts[kind])}")

    return "\n".join(lines) + "\n"


def lint_prometheus(text: str) -> List[str]:
    """Pure-python lint of the text exposition format.  Returns a list of
    problem strings (empty == clean): metric/label naming, TYPE declared
    before samples, no duplicate ``(name, labelset)`` samples, counters
    named ``_total`` with finite non-negative values, parseable floats."""
    problems: List[str] = []
    types: dict = {}
    seen: set = set()
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)(\s+\d+)?$")
    label_re = re.compile(r'([a-zA-Z0-9_]+)=("(?:[^"\\]|\\.)*")')
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "summary", "histogram", "untyped"):
                problems.append(f"line {i}: malformed TYPE line")
                continue
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = sample_re.match(line)
        if not m:
            problems.append(f"line {i}: unparseable sample {line!r}")
            continue
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        if not _NAME_RE.match(name):
            problems.append(f"line {i}: bad metric name {name!r}")
        base = name
        for suffix in ("_sum", "_count", "_bucket", "_total"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                break
        mtype = types.get(base) or types.get(name)
        if mtype is None:
            problems.append(f"line {i}: sample {name!r} has no TYPE")
        if labels:
            body = labels[1:-1]
            if body and label_re.sub("", body).strip(", ") != "":
                problems.append(f"line {i}: malformed labels {labels!r}")
            for lname, _ in label_re.findall(body):
                if not _LABEL_RE.match(lname) or lname.startswith("__"):
                    problems.append(f"line {i}: bad label name {lname!r}")
        key = (name, labels)
        if key in seen:
            problems.append(f"line {i}: duplicate sample {name}{labels}")
        seen.add(key)
        try:
            v = float(value.replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            problems.append(f"line {i}: unparseable value {value!r}")
            continue
        if mtype == "counter":
            if not name.endswith("_total"):
                problems.append(
                    f"line {i}: counter {name!r} must end in _total")
            if not (v >= 0.0):
                problems.append(
                    f"line {i}: counter {name!r} negative ({v})")
    return problems


# ----------------------------------------------------------------------
# HTML report
# ----------------------------------------------------------------------

_CSS = """
body{font-family:system-ui,sans-serif;margin:2em;max-width:70em;
     color:#1a1a2e}
h1{font-size:1.4em} h2{font-size:1.1em;margin-top:1.6em}
table{border-collapse:collapse;font-size:0.85em;font-variant-numeric:
      tabular-nums}
th,td{border:1px solid #ccc;padding:0.3em 0.6em;text-align:right}
th:first-child,td:first-child{text-align:left;font-family:monospace}
.bar{display:flex;height:1.6em;border:1px solid #999;max-width:60em}
.bar div{height:100%;overflow:hidden;font-size:0.7em;color:#fff;
         white-space:nowrap;padding-left:0.2em}
.sched{background:#6c5ce7}.device{background:#00896f}
.draft{background:#e17055}.host{background:#636e72}
.waste{background:#d63031}.useful{background:#00896f}
.crit{color:#d63031;font-weight:bold}.warn{color:#e17055}
.kv{color:#555;font-size:0.85em}
"""


def _bar(parts, total: float) -> str:
    if total <= 0:
        return "<div class='bar'></div>"
    cells = []
    for cls, label, v in parts:
        pct = 100.0 * v / total
        if pct < 0.05:
            continue
        cells.append(f"<div class='{cls}' style='width:{pct:.2f}%' "
                     f"title='{html.escape(label)}: {v:.4f}s "
                     f"({pct:.1f}%)'>{html.escape(label)}</div>")
    return "<div class='bar'>" + "".join(cells) + "</div>"


def html_report(telemetry, *, title: str = "serving report") -> str:
    """Render the attribution waterfall, per-family table, latency
    percentiles and alert log as one self-contained HTML page."""
    summary = telemetry.attribution_summary()
    tot = summary.get("totals", {})
    fams = summary.get("families", {})
    cm = telemetry.cost_model

    out = [f"<!doctype html><html><head><meta charset='utf-8'>"
           f"<title>{html.escape(title)}</title>"
           f"<style>{_CSS}</style></head><body>"
           f"<h1>{html.escape(title)}</h1>"]
    if cm is not None:
        out.append(f"<p class='kv'>cost model: {html.escape(cm.hw_name)} "
                   f"@ {html.escape(cm.dtype)} — peak "
                   f"{cm.peak_flops / 1e12:.1f} TFLOP/s, HBM "
                   f"{cm.hbm_bw / 1e9:.0f} GB/s (built at warmup; "
                   f"frozen since)</p>")

    out.append("<h2>Attribution waterfall (drain totals)</h2>")
    wall = tot.get("wall_s", 0.0)
    out.append(_bar([("sched", "sched", tot.get("sched_s", 0.0)),
                     ("device", "device", tot.get("device_s", 0.0)),
                     ("draft", "draft", tot.get("draft_s", 0.0)),
                     ("host", "host", tot.get("host_s", 0.0))], wall))
    dev = tot.get("device_s", 0.0)
    waste = min(tot.get("padding_waste_s", 0.0), dev)
    out.append("<p class='kv'>device time split: useful vs padding "
               "waste (padded grid positions priced at the family's "
               "roofline per-token cost)</p>")
    out.append(_bar([("useful", "useful", dev - waste),
                     ("waste", "padding waste", waste)], dev))
    rows = [("steps", f"{tot.get('steps', 0)}"),
            ("wall_s", f"{wall:.4f}"),
            ("real tokens", f"{tot.get('real_tokens', 0)}"),
            ("padded tokens", f"{tot.get('padded_tokens', 0)}")]
    for key in ("mfu", "mbu", "padding_waste_ratio",
                "achieved_tokens_per_s", "roofline_tokens_per_s",
                "goodput_ratio"):
        if key in summary:
            v = summary[key]
            rows.append((key, f"{v:.6g}"))
    rows.append(("goodput tokens",
                 f"{summary.get('goodput_tokens', 0)}"
                 f" / {summary.get('tokens_out', 0)}"))
    out.append("<table><tr><th>metric</th><th>value</th></tr>")
    for k, v in rows:
        out.append(f"<tr><td>{html.escape(k)}</td>"
                   f"<td>{html.escape(v)}</td></tr>")
    out.append("</table>")

    out.append("<h2>Per-family predicted vs measured</h2>")
    out.append("<table><tr><th>family</th><th>steps</th><th>fill</th>"
               "<th>device s</th><th>predicted s</th><th>pred/meas</th>"
               "<th>waste s</th><th>roof</th><th>KV gather MB/step</th>"
               "</tr>")
    for label in sorted(fams):
        f = fams[label]
        fc = cm.get(label) if cm is not None else None
        roof = html.escape(fc.bottleneck) if fc is not None else "-"
        gather = (f"{fc.kv_gather_bytes / 2 ** 20:.2f}"
                  if fc is not None else "-")
        out.append(
            f"<tr><td>{html.escape(label)}</td><td>{f['steps']}</td>"
            f"<td>{f['fill']:.3f}</td><td>{f['device_s']:.4f}</td>"
            f"<td>{f['predicted_s']:.6f}</td>"
            f"<td>{f['predicted_vs_measured']:.3g}</td>"
            f"<td>{f['padding_waste_s']:.6f}</td>"
            f"<td>{roof}</td><td>{gather}</td></tr>")
    out.append("</table>")

    out.append("<h2>Latency percentiles (s)</h2>")
    out.append("<table><tr><th>metric</th><th>count</th><th>p50</th>"
               "<th>p95</th><th>p99</th><th>max</th></tr>")
    for name, snap in telemetry.latency_summary().items():
        out.append(f"<tr><td>{html.escape(name)}</td>"
                   f"<td>{snap['count']}</td><td>{snap['p50']:.4f}</td>"
                   f"<td>{snap['p95']:.4f}</td><td>{snap['p99']:.4f}</td>"
                   f"<td>{snap['max']:.4f}</td></tr>")
    out.append("</table>")

    out.append("<h2>Alerts</h2>")
    alerts = list(telemetry.alerts)
    if not alerts:
        out.append("<p class='kv'>none</p>")
    else:
        out.append("<table><tr><th>kind</th><th>severity</th><th>step</th>"
                   "<th>value</th><th>threshold</th><th>message</th></tr>")
        for a in alerts:
            out.append(
                f"<tr><td>{html.escape(a.kind)}</td>"
                f"<td class='{html.escape(a.severity)}'>"
                f"{html.escape(a.severity)}</td><td>{a.step}</td>"
                f"<td>{a.value:.4g}</td><td>{a.threshold:.4g}</td>"
                f"<td style='text-align:left'>{html.escape(a.message)}"
                f"</td></tr>")
        out.append("</table>")
    out.append("</body></html>")
    return "".join(out)


def write_report(telemetry, path, *, title: str = "serving report") -> dict:
    """Write the HTML report to ``path`` (an ``.html`` suffix is kept,
    anything else gets one) and the Prometheus text next to it with a
    ``.prom`` suffix.  Returns ``{"html": ..., "prom": ...}`` paths."""
    import os

    path = os.fspath(path)
    base = path[:-5] if path.endswith(".html") else path
    html_path, prom_path = base + ".html", base + ".prom"
    with open(html_path, "w") as f:
        f.write(html_report(telemetry, title=title))
    with open(prom_path, "w") as f:
        f.write(prometheus_text(telemetry))
    return {"html": html_path, "prom": prom_path}
