"""``repro.obs`` — serving observability: lifecycle tracing, streaming
metrics, Perfetto trace export.

Zero-dependency (stdlib only) and strictly host-side: every event is a
Python method call timed with ``time.perf_counter()``; nothing here
touches a jitted code path, a device array, or the token math.  The
standing serving invariants therefore hold by construction — telemetry
on/off is token-identical, adds zero post-warmup XLA traces, and the
disabled default (:data:`~repro.obs.telemetry.NULL`) costs one no-op
call per event with no clock reads (all checked in ``tests/test_obs.py``).

Event taxonomy
==============

**Request lifecycle** (per-request; mirrors the scheduler's state
machine, see :mod:`repro.serving.scheduler`).  Durations render as
spans, transitions as instants; each also lands in the request's own
``obs_events`` list as ``(label, t)``:

========================  ==========================================
event                     meaning
========================  ==========================================
``queued``                entered the scheduler's waiting queue
``admitted``              took a slot (queue-wait span closes)
``prefill_chunk``         one chunk of prompt KV written (span per
                          chunk on the slot's track)
``prefill_done``          prompt KV complete; decode span opens
``preempted``             pages released, tokens folded, requeued
``paused``                mid-prefill victim: slot surrendered,
                          pages + cursor kept, requeued
``reclaimed``             a paused request's pages were reclaimed
``finished``              happy-path exit (eos | length)
``cancelled:<reason>``    retired early: ``timeout`` | ``cancelled``
                          | ``error`` (the NaN-logit quarantine)
``shed:<kind>``           rejected at ``add()`` by admission control
                          (never queued)
========================  ==========================================

**Step phases** (per engine step, on the ``engine`` track): a ``step``
span wrapping ``device`` (jitted forward) and ``draft`` (drafter
proposal) sub-spans; host planning time is the remainder.

**Component instants**: ``cow`` (copy-on-write page split),
``prefix_hit`` / ``prefix_evict`` (prefix cache), ``spec_rollback``
(rejected speculative pages truncated), ``drafter_error``,
``fault:<kind>`` (injected by :mod:`repro.serving.faults`).

Streaming metrics
=================

A :class:`~repro.obs.metrics.MetricsRegistry` of counters, gauges, and
fixed-bucket geometric histograms (p50/p95/p99 without retaining
samples): TTFT, ITL, queue wait, e2e latency, and the per-step
wall/host/device/draft breakdown.  Reset semantics are explicit and
documented in :mod:`repro.obs.metrics` — drain-scoped metrics reset
only via ``Engine.telemetry(reset=True)``; lifetime metrics never.
``Engine.telemetry()`` is the one unified view: components' classic
``stats()`` dicts + the registry snapshot + headline percentiles.

Trace file format
=================

Chrome ``trace_event`` JSON (the ``{"traceEvents": [...]}`` flavour),
loadable in Perfetto or ``chrome://tracing``; microsecond timestamps
relative to recorder birth.  One track (thread) per serving slot plus
``engine`` / ``scheduler`` / ``pool`` tracks; ``"X"`` complete spans
for prefill chunks, decode runs, and step phases; ``"b"``/``"e"``
async spans for (overlapping) queue waits keyed by rid; ``"i"``
instants for the transition events above; ``"C"`` counters for pool
occupancy and scheduler load.  Details in :mod:`repro.obs.trace`.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.telemetry import NULL, NullTelemetry, Telemetry
from repro.obs.trace import TraceRecorder

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL", "NullTelemetry", "Telemetry", "TraceRecorder",
]
