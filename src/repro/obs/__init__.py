"""``repro.obs`` — serving observability: lifecycle tracing, streaming
metrics, performance attribution, anomaly monitors, Perfetto trace
export, Prometheus/HTML exposition.

Zero-dependency (stdlib only) and strictly host-side: every event is a
Python method call timed with ``time.perf_counter()``; nothing here
touches a jitted code path, a device array, or the token math.  The
standing serving invariants therefore hold by construction — telemetry
on/off is token-identical, adds zero post-warmup XLA traces, and the
disabled default (:data:`~repro.obs.telemetry.NULL`) costs one no-op
call per event with no clock reads (all checked in ``tests/test_obs.py``).

Event taxonomy
==============

**Request lifecycle** (per-request; mirrors the scheduler's state
machine, see :mod:`repro.serving.scheduler`).  Durations render as
spans, transitions as instants; each also lands in the request's own
``obs_events`` list as ``(label, t)``:

========================  ==========================================
event                     meaning
========================  ==========================================
``queued``                entered the scheduler's waiting queue
``admitted``              took a slot (queue-wait span closes)
``prefill_chunk``         one chunk of prompt KV written (span per
                          chunk on the slot's track)
``prefill_done``          prompt KV complete; decode span opens
``preempted``             pages released, tokens folded, requeued
``paused``                mid-prefill victim: slot surrendered,
                          pages + cursor kept, requeued
``reclaimed``             a paused request's pages were reclaimed
``finished``              happy-path exit (eos | length)
``cancelled:<reason>``    retired early: ``timeout`` | ``cancelled``
                          | ``error`` (the NaN-logit quarantine)
``shed:<kind>``           rejected at ``add()`` by admission control
                          (never queued)
========================  ==========================================

**Step phases** (per engine step, on the ``engine`` track): a ``step``
span wrapping ``device`` (jitted forward) and ``draft`` (drafter
proposal) sub-spans; host planning time is the remainder.

**Component instants**: ``cow`` (copy-on-write page split),
``prefix_hit`` / ``prefix_evict`` (prefix cache), ``spec_rollback``
(rejected speculative pages truncated), ``drafter_error``,
``fault:<kind>`` (injected by :mod:`repro.serving.faults`).

Streaming metrics
=================

A :class:`~repro.obs.metrics.MetricsRegistry` of counters, gauges, and
fixed-bucket geometric histograms (p50/p95/p99 without retaining
samples): TTFT, ITL, queue wait, e2e latency, and the per-step
wall/host/device/draft breakdown.  Reset semantics are explicit and
documented in :mod:`repro.obs.metrics` — drain-scoped metrics reset
only via ``Engine.telemetry(reset=True)``; lifetime metrics never.
``Engine.telemetry()`` is the one unified view: components' classic
``stats()`` dicts + the registry snapshot + headline percentiles.

Performance attribution
=======================

:mod:`repro.obs.attrib` grounds the measured numbers in the paper's
predictability story.  ``Engine.warmup()`` (telemetry on) builds a
:class:`~repro.obs.attrib.StepCostModel` — one roofline-priced
:class:`~repro.obs.attrib.FamilyCost` per compiled shape family on the
engine's ladder, from abstract ``lower().compile()`` + XLA cost
analysis plus an explicit KV-page-gather traffic term — and freezes it
(the warmup-only contract: the per-step hot path only ever does dict
lookups).  Each measured step is tagged with its family label(s) and
its wall split into ``sched + device + draft + host`` — complete by
construction, the components sum back to the wall (asserted in
``tests/test_attrib.py``).  Drain roll-ups report MFU/MBU, padding
waste (padded-minus-real grid positions priced at the family's
roofline per-token cost), predicted-vs-measured per family, achieved-
vs roofline-tokens/s, and goodput (tokens emitted inside
``deadline_s``, surfaced via ``Engine.stats()["slo"]``).

Anomaly monitors
================

:mod:`repro.obs.monitors` runs five host-side online detectors once per
step — ``step-outlier`` (per-family device time vs rolling median),
``preempt-storm``, ``prefix-churn``, ``queue-growth``, and ``slo-burn``
(TTFT/ITL target violation rate) — emitting typed
:class:`~repro.obs.monitors.Alert`\\ s that land in
``Engine.telemetry()["alerts"]``, the ``alerts_emitted`` counter, and
the ``monitor`` trace track.  One alert per excursion (re-arm on
clearing), bounded retention.

Exposition formats
==================

:mod:`repro.obs.export` renders the above without observing anything:
:func:`~repro.obs.export.prometheus_text` (text format 0.0.4, linted by
the pure-python :func:`~repro.obs.export.lint_prometheus`) and
:func:`~repro.obs.export.html_report` (one self-contained file —
attribution waterfall, per-family table, latency percentiles, alert
log).  ``Engine.telemetry(report=path)`` writes the ``.html``/``.prom``
pair; ``scripts/report_smoke.py`` (``tier1.sh --report``) smoke-checks
both end to end.

Trace file format
=================

Chrome ``trace_event`` JSON (the ``{"traceEvents": [...]}`` flavour),
loadable in Perfetto or ``chrome://tracing``; microsecond timestamps
relative to recorder birth.  One track (thread) per serving slot plus
``engine`` / ``scheduler`` / ``pool`` tracks; ``"X"`` complete spans
for prefill chunks, decode runs, and step phases; ``"b"``/``"e"``
async spans for (overlapping) queue waits keyed by rid; ``"i"``
instants for the transition events above; ``"C"`` counters for pool
occupancy and scheduler load.  Details in :mod:`repro.obs.trace`.
"""

from repro.obs.attrib import (FamilyCost, StepCostModel, build_cost_model,
                              summarize)
from repro.obs.export import (html_report, lint_prometheus, prometheus_text,
                              write_report)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.monitors import Alert, Monitors
from repro.obs.telemetry import NULL, NullTelemetry, Telemetry
from repro.obs.trace import TraceRecorder

__all__ = [
    "Alert", "Counter", "FamilyCost", "Gauge", "Histogram",
    "MetricsRegistry", "Monitors", "NULL", "NullTelemetry",
    "StepCostModel", "Telemetry", "TraceRecorder", "build_cost_model",
    "html_report", "lint_prometheus", "prometheus_text", "summarize",
    "write_report",
]
