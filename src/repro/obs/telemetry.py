"""The telemetry facade the serving stack calls into.

One :class:`Telemetry` instance per engine, threaded by reference into
the scheduler, KV pool, prefix cache, drafter, and fault layer.  Every
instrumentation point in the serving stack is a single method call on
this object; the default is the module-level :data:`NULL` —
a :class:`NullTelemetry` whose methods are all no-ops and whose
``clock()`` never reads the time — so a telemetry-off engine pays one
attribute load plus one no-op call per event and takes **no** clock
reads on the hot path.

Everything here is host-side Python over ``time.perf_counter()``; no
method ever touches a jitted code path or a device array, which is how
the on/off token-identity and zero-retrace invariants hold by
construction (checked end-to-end in ``tests/test_obs.py``).

Per-request event log: when telemetry is live, every lifecycle event is
also appended to ``request.obs_events`` as ``(label, t_seconds)`` — the
request's own latency ledger, readable after ``drain()`` without going
through the trace file.

See :mod:`repro.obs` for the event taxonomy and the trace file format,
and :mod:`repro.obs.metrics` for the drain-vs-lifetime reset contract.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.obs.attrib import finalize_summary, fresh_totals as _fresh_totals, \
    update_aggregates
from repro.obs.metrics import MetricsRegistry
from repro.obs.monitors import Monitors
from repro.obs.trace import TraceRecorder

__all__ = ["NullTelemetry", "Telemetry", "NULL"]


class NullTelemetry:
    """The telemetry-off stand-in: every event method is an explicit
    no-op and :meth:`clock` returns 0.0 without reading the time — the
    disabled path costs one method call, never a syscall."""

    enabled = False
    registry = None
    tracer = None
    cost_model = None
    monitors = None
    alerts: tuple = ()

    def clock(self) -> float:
        return 0.0

    # -- lifecycle -----------------------------------------------------
    def request_queued(self, req) -> None: pass
    def request_admitted(self, req) -> None: pass
    def request_prefill_chunk(self, req, n) -> None: pass
    def request_prefill_done(self, req) -> None: pass
    def request_preempted(self, req) -> None: pass
    def request_paused(self, req) -> None: pass
    def request_reclaimed(self, req) -> None: pass
    def request_finished(self, req) -> None: pass
    def request_cancelled(self, req, reason) -> None: pass
    def request_shed(self, req, kind) -> None: pass

    # -- step phases ---------------------------------------------------
    def step_begin(self) -> None: pass
    def device_span(self, t0) -> None: pass
    def draft_span(self, t0) -> None: pass
    def step_family(self, label, real, width) -> None: pass
    def step_end(self, scheduler, pool, finished, now=None) -> None: pass

    # -- attribution (repro.obs.attrib) --------------------------------
    def attach_cost_model(self, cost_model) -> None: pass
    def attribution_summary(self) -> dict: return {}
    def reset_drain(self) -> None: pass

    # -- component instants --------------------------------------------
    def cow(self) -> None: pass
    def prefix_hit(self, tokens, pages) -> None: pass
    def prefix_evict(self, freed) -> None: pass
    def spec_rollback(self, req, pages) -> None: pass
    def draft_batch(self, rows, tokens) -> None: pass
    def drafter_error(self) -> None: pass
    def fault(self, kind, step) -> None: pass


NULL = NullTelemetry()


class Telemetry(NullTelemetry):
    """Live telemetry: streaming metrics always, trace recording unless
    ``trace=False``.  All timestamps come from one monotonic ``clock``
    (``time.perf_counter`` by default; injectable for tests)."""

    enabled = True

    def __init__(self, *, trace: bool = True, clock=time.perf_counter,
                 max_trace_events: int = 1 << 20):
        self._clock = clock
        self.registry = MetricsRegistry()
        self.tracer = (TraceRecorder(clock=clock,
                                     max_events=max_trace_events)
                       if trace else None)
        r = self.registry
        # latency histograms (seconds)
        self.h_ttft = r.histogram("ttft_s")
        self.h_itl = r.histogram("itl_s")
        self.h_queue_wait = r.histogram("queue_wait_s")
        self.h_e2e = r.histogram("e2e_s")
        # per-step phase breakdown (seconds).  Attribution completeness:
        # wall == sched + device + draft + host by construction (the
        # split is derived from the step's own span timestamps; asserted
        # within tolerance in tests/test_attrib.py).  ``sched`` is the
        # host time before the first device/draft span (admission, page
        # growth, chunk planning); ``host`` is the interleaved + post-
        # device remainder (verify loop, numpy staging).
        self.h_step_wall = r.histogram("step_wall_s")
        self.h_step_host = r.histogram("step_host_s")
        self.h_step_device = r.histogram("step_device_s")
        self.h_step_draft = r.histogram("step_draft_s")
        self.h_step_sched = r.histogram("step_sched_s")
        # padding waste: padded-minus-real grid positions priced at the
        # step family's roofline per-token cost (needs the warmup-built
        # cost model; observes 0.0 until one is attached)
        self.h_step_waste = r.histogram("step_padding_waste_s")
        # event counters (drain-scoped: reset via Engine.telemetry(reset=True))
        self.c_queued = r.counter("requests_queued")
        self.c_admitted = r.counter("requests_admitted")
        self.c_finished = r.counter("requests_finished")
        self.c_tokens_out = r.counter("tokens_out")
        self.c_prefill_tokens = r.counter("prefill_tokens")
        self.c_preemptions = r.counter("preemptions")
        self.c_pauses = r.counter("pauses")
        self.c_reclaims = r.counter("reclaims")
        self.c_sheds = r.counter("sheds")
        self.c_timeouts = r.counter("timeouts")
        self.c_cancels = r.counter("cancels")
        self.c_quarantines = r.counter("quarantines")
        self.c_cow = r.counter("cow_copies")
        self.c_rollback_pages = r.counter("spec_rollback_pages")
        self.c_prefix_hits = r.counter("prefix_hits")
        self.c_prefix_hit_tokens = r.counter("prefix_hit_tokens")
        self.c_prefix_evictions = r.counter("prefix_evictions")
        self.c_faults = r.counter("faults_injected")
        self.c_drafter_errors = r.counter("drafter_errors")
        self.c_draft_rows = r.counter("draft_rows")
        self.c_draft_tokens = r.counter("draft_tokens")
        self.c_steps = r.counter("steps")
        self.c_goodput_tokens = r.counter("goodput_tokens")
        self.c_alerts = r.counter("alerts_emitted")
        # momentary levels, sampled once per step
        self.g_queue_depth = r.gauge("queue_depth")
        self.g_running = r.gauge("running_slots")
        self.g_pool_used = r.gauge("pool_pages_used")
        # live per-request records: rid -> phase bookkeeping
        self._live: Dict[int, dict] = {}
        # current step's accumulators
        self._step_t0: Optional[float] = None
        self._dev_s = 0.0
        self._draft_s = 0.0
        self._dev_window = None        # (t0, t1) of the latest device call
        # attribution state (repro.obs.attrib): the warmup-frozen cost
        # model, this step's family tags, a bounded window of per-step
        # attribution records (tests + the HTML waterfall), and running
        # per-family aggregates that survive the window bound
        self.cost_model = None
        self.monitors = Monitors()
        self._families: list = []      # (label, real, width, dev_s) tags
        self._first_span_t0: Optional[float] = None
        self._last_dev = 0.0
        self.step_records: Deque[dict] = deque(maxlen=4096)
        self._agg_tot: dict = _fresh_totals()
        self._agg_fams: Dict[str, dict] = {}

    @property
    def alerts(self):
        return self.monitors.alerts

    # ------------------------------------------------------------------
    def clock(self) -> float:
        return self._clock()

    def _mark(self, req, label: str, t: float) -> None:
        req.obs_events.append((label, t))

    @staticmethod
    def _slot_track(req) -> str:
        return f"slot {req.slot}" if req.slot >= 0 else "scheduler"

    # -- lifecycle -----------------------------------------------------
    def request_queued(self, req) -> None:
        t = self._clock()
        self.c_queued.inc()
        self._live[req.rid] = {
            "born": t, "phase": "queued", "phase_t0": t,
            "emitted": 0, "last_emit": t,
        }
        self._mark(req, "queued", t)
        if self.tracer:
            self.tracer.async_begin("scheduler", "queue", req.rid, t,
                                    args={"rid": req.rid})

    def request_admitted(self, req) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        self.c_admitted.inc()
        self.h_queue_wait.observe(t - rec["phase_t0"])
        rec["phase"] = "prefill"
        rec["phase_t0"] = t
        self._mark(req, "admitted", t)
        if self.tracer:
            self.tracer.async_end("scheduler", "queue", req.rid, t)

    def request_prefill_chunk(self, req, n: int) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        self.c_prefill_tokens.inc(n)
        t = self._clock()
        self._mark(req, "prefill_chunk", t)
        if self.tracer:
            w = self._dev_window or (t, t)
            self.tracer.complete(self._slot_track(req), "prefill",
                                 w[0], w[1],
                                 args={"rid": req.rid, "tokens": n,
                                       "cursor": req.prefill_cursor})

    def request_prefill_done(self, req) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        rec["phase"] = "decode"
        rec["phase_t0"] = t
        self._mark(req, "prefill_done", t)

    def _close_decode(self, req, rec, t: float) -> None:
        if rec["phase"] == "decode" and self.tracer:
            self.tracer.complete(self._slot_track(req), "decode",
                                 rec["phase_t0"], t,
                                 args={"rid": req.rid,
                                       "tokens": len(req.out_tokens)})

    def request_preempted(self, req) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        self.c_preemptions.inc()
        self._close_decode(req, rec, t)
        self._mark(req, "preempted", t)
        if self.tracer:
            self.tracer.instant(self._slot_track(req), "preempt", t,
                                args={"rid": req.rid})
            self.tracer.async_begin("scheduler", "queue", req.rid, t,
                                    args={"rid": req.rid, "requeue": True})
        rec["phase"] = "queued"
        rec["phase_t0"] = t

    def request_paused(self, req) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        self.c_pauses.inc()
        self._mark(req, "paused", t)
        if self.tracer:
            self.tracer.instant(self._slot_track(req), "pause", t,
                                args={"rid": req.rid,
                                      "cursor": req.prefill_cursor})
            self.tracer.async_begin("scheduler", "queue", req.rid, t,
                                    args={"rid": req.rid, "paused": True})
        rec["phase"] = "queued"
        rec["phase_t0"] = t

    def request_reclaimed(self, req) -> None:
        if req.rid not in self._live:
            return
        t = self._clock()
        self.c_reclaims.inc()
        self._mark(req, "reclaimed", t)
        if self.tracer:
            self.tracer.instant("scheduler", "reclaim", t,
                                args={"rid": req.rid})

    def request_finished(self, req) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        self.c_finished.inc()
        self.h_e2e.observe(t - rec["born"])
        self._close_decode(req, rec, t)
        rec["phase"] = "done"
        self._mark(req, "finished", t)

    def request_cancelled(self, req, reason: str) -> None:
        rec = self._live.get(req.rid)
        if rec is None:
            return
        t = self._clock()
        if reason == "timeout":
            self.c_timeouts.inc()
        elif reason == "error":
            self.c_quarantines.inc()
        else:
            self.c_cancels.inc()
        self._close_decode(req, rec, t)
        if self.tracer:
            if rec["phase"] == "queued":
                self.tracer.async_end("scheduler", "queue", req.rid, t)
            name = "quarantine" if reason == "error" else reason
            self.tracer.instant(self._slot_track(req), name, t,
                                args={"rid": req.rid})
        rec["phase"] = "done"
        self._mark(req, f"cancelled:{reason}", t)

    def request_shed(self, req, kind: str) -> None:
        # shed at add(): the request never entered the queue, so there is
        # no live record and no open queue span — just the mark
        t = self._clock()
        self.c_sheds.inc()
        self._mark(req, f"shed:{kind}", t)
        if self.tracer:
            self.tracer.instant("scheduler", "shed", t,
                                args={"rid": req.rid, "kind": kind})

    # -- step phases ---------------------------------------------------
    def step_begin(self) -> None:
        self._step_t0 = self._clock()
        self._dev_s = 0.0
        self._draft_s = 0.0
        self._dev_window = None
        self._families = []
        self._first_span_t0 = None
        self._last_dev = 0.0

    def device_span(self, t0: float) -> None:
        t1 = self._clock()
        self._dev_s += t1 - t0
        self._dev_window = (t0, t1)
        self._last_dev = t1 - t0
        if self._first_span_t0 is None:
            self._first_span_t0 = t0
        if self.tracer:
            self.tracer.complete("engine", "device", t0, t1)

    def draft_span(self, t0: float) -> None:
        t1 = self._clock()
        self._draft_s += t1 - t0
        if self._first_span_t0 is None:
            self._first_span_t0 = t0
        if self.tracer:
            self.tracer.complete("engine", "draft", t0, t1)

    def step_family(self, label: str, real: int, width: int) -> None:
        """Tag the device span just recorded with its compiled shape
        family (called by the engine right after ``device_span``):
        ``real`` useful tokens rode a ``width``-position grid."""
        self._families.append((label, int(real), int(width),
                               self._last_dev))

    def step_end(self, scheduler, pool, finished, now=None) -> None:
        t1 = self._clock()
        running = list(scheduler.running.values())
        # token accounting first: one TTFT observation per request (its
        # first emission), one ITL observation per emission *episode* —
        # a speculative burst of k tokens in one step is one episode
        for req in running + list(finished):
            rec = self._live.get(req.rid)
            if rec is None:
                continue
            cur = len(req.out_tokens)
            if cur > rec["emitted"]:
                if rec["emitted"] == 0:
                    ttft = t1 - rec["born"]
                    self.h_ttft.observe(ttft)
                    self.monitors.observe_ttft(ttft)
                else:
                    itl = t1 - rec["last_emit"]
                    self.h_itl.observe(itl)
                    self.monitors.observe_itl(itl)
                emitted = cur - rec["emitted"]
                self.c_tokens_out.inc(emitted)
                # goodput: emissions land inside the request deadline.
                # Judged on the *engine's* clock (``now``), the same one
                # deadline cancellation uses — no deadline or no engine
                # clock means every token counts.
                deadline = getattr(req, "deadline_s", None)
                if (deadline is None or now is None
                        or now - req.arrival <= deadline):
                    self.c_goodput_tokens.inc(emitted)
                rec["emitted"] = cur
                rec["last_emit"] = t1
        for req in finished:
            self._live.pop(req.rid, None)
        # momentary levels
        self.g_queue_depth.set(len(scheduler.waiting))
        self.g_running.set(len(running))
        if pool is not None:
            self.g_pool_used.set(pool.num_used)
        # a step that moved nothing (idle poll before arrivals) draws no
        # span and no wall-time sample, mirroring Engine._steps
        if not running and not finished:
            return
        t0 = self._step_t0 if self._step_t0 is not None else t1
        wall = t1 - t0
        # wall decomposition — complete by construction: ``sched`` is
        # host time before the first device/draft span, ``host`` is the
        # remainder after subtracting the measured spans, so the four
        # components sum back to wall exactly (up to float rounding;
        # asserted in tests/test_attrib.py)
        first = self._first_span_t0
        sched = min(max(0.0, (first if first is not None else t1) - t0),
                    wall)
        host = max(0.0, wall - sched - self._dev_s - self._draft_s)
        waste = 0.0
        if self.cost_model is not None:
            for label, real, width, _dev in self._families:
                fc = self.cost_model.get(label)
                if fc is not None:
                    waste += (width - real) * fc.per_token_s
        self.c_steps.inc()
        self.h_step_wall.observe(wall)
        self.h_step_host.observe(host)
        self.h_step_device.observe(self._dev_s)
        self.h_step_draft.observe(self._draft_s)
        self.h_step_sched.observe(sched)
        self.h_step_waste.observe(waste)
        rec = {"wall": wall, "sched": sched, "device": self._dev_s,
               "draft": self._draft_s, "host": host,
               "families": tuple(self._families)}
        self.step_records.append(rec)
        update_aggregates(self._agg_tot, self._agg_fams, rec,
                          self.cost_model)
        alerts = self.monitors.observe_step(
            t=t1, scheduler=scheduler, telemetry=self,
            families=self._families, device_s=self._dev_s)
        for a in alerts:
            self.c_alerts.inc()
            if self.tracer:
                self.tracer.instant("monitor", f"alert:{a.kind}", a.t,
                                    args=a.to_dict())
        if self.tracer:
            self.tracer.complete(
                "engine", "step", t0, t1,
                args={"running": len(running),
                      "finished": len(finished),
                      "families": [f[0] for f in self._families]})
            if pool is not None:
                self.tracer.counter("pool", "pages",
                                    {"used": pool.num_used,
                                     "free": pool.num_free}, t1)
            self.tracer.counter("scheduler", "load",
                                {"waiting": len(scheduler.waiting),
                                 "running": len(running)}, t1)

    # -- attribution (repro.obs.attrib) --------------------------------
    def attach_cost_model(self, cost_model) -> None:
        """Install the warmup-built :class:`~repro.obs.attrib.
        StepCostModel`.  Called once, from ``Engine.warmup()`` — the
        warmup-only contract: nothing per-step ever lowers or compiles."""
        self.cost_model = cost_model

    def attribution_summary(self) -> dict:
        """The per-drain attribution roll-up (totals, per-family
        predicted-vs-measured, MFU/MBU, goodput)."""
        return finalize_summary(
            self._agg_tot, self._agg_fams, self.cost_model,
            goodput_tokens=self.c_goodput_tokens.value,
            tokens_out=self.c_tokens_out.value)

    def reset_drain(self) -> None:
        """Drop drain-scoped state: metrics, per-step attribution
        records and aggregates.  Lifetime metrics, the cost model, the
        monitors' alert history and the trace all survive."""
        self.registry.reset("drain")
        self.step_records.clear()
        self._agg_tot = _fresh_totals()
        self._agg_fams = {}

    # -- component instants --------------------------------------------
    def cow(self) -> None:
        self.c_cow.inc()
        if self.tracer:
            self.tracer.instant("pool", "cow", self._clock())

    def prefix_hit(self, tokens: int, pages: int) -> None:
        self.c_prefix_hits.inc()
        self.c_prefix_hit_tokens.inc(tokens)
        if self.tracer:
            self.tracer.instant("pool", "prefix_hit", self._clock(),
                                args={"tokens": tokens, "pages": pages})

    def prefix_evict(self, freed: int) -> None:
        self.c_prefix_evictions.inc(freed)
        if self.tracer:
            self.tracer.instant("pool", "prefix_evict", self._clock(),
                                args={"pages": freed})

    def spec_rollback(self, req, pages: int) -> None:
        self.c_rollback_pages.inc(pages)
        if self.tracer:
            self.tracer.instant(self._slot_track(req), "spec_rollback",
                                self._clock(),
                                args={"rid": req.rid, "pages": pages})

    def draft_batch(self, rows: int, tokens: int) -> None:
        self.c_draft_rows.inc(rows)
        self.c_draft_tokens.inc(tokens)

    def drafter_error(self) -> None:
        self.c_drafter_errors.inc()
        if self.tracer:
            self.tracer.instant("engine", "drafter_error", self._clock())

    def fault(self, kind: str, step: int) -> None:
        self.c_faults.inc()
        if self.tracer:
            self.tracer.instant("engine", f"fault:{kind}", self._clock(),
                                args={"step": step})

    # ------------------------------------------------------------------
    def latency_summary(self) -> dict:
        """The headline percentiles — TTFT / ITL / queue wait / e2e."""
        return {name: h.snapshot() for name, h in
                (("ttft_s", self.h_ttft), ("itl_s", self.h_itl),
                 ("queue_wait_s", self.h_queue_wait),
                 ("e2e_s", self.h_e2e))}

    def export_trace(self, path) -> None:
        assert self.tracer is not None, "telemetry was built with trace=False"
        self.tracer.export(path)
