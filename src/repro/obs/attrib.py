"""Performance attribution: a roofline-grounded per-family step cost model.

The paper's whole pitch is *predictability*: packed layouts exist so tile
shapes — and therefore step cost — are known before execution.  This
module joins the repo's two halves of that story.  At ``Engine.warmup()``
time (and **only** then — the warmup-only contract below) it builds a
:class:`StepCostModel`: for every compiled shape family on the engine's
ladder (monolithic prefill buckets, chunked widths, flat widths, verify
widths — the exact enumeration :func:`repro.analysis.shapes.step_families`
derives from the warmup loop), the step function is lowered with
``ShapeDtypeStruct`` stand-ins and compiled, XLA's ``cost_analysis()`` is
normalized via :func:`repro.roofline.hlo_cost.xla_cost_dict`, the
while-aware HLO parse re-derives dot FLOPs and HBM bytes, and the result
is priced against a :class:`repro.core.hardware.HardwareSpec`:

    compute_s   = dot_flops / peak_flops(compute dtype)
    memory_s    = hbm_bytes / hbm_bw
    predicted_s = max(compute_s, memory_s)        (the roofline)

KV-page **gather** bytes are additionally counted explicitly from the
engine's own cache geometry (rows x block-table window x per-token KV
bytes summed over the paged pools) — the paged-attention traffic term the
serving dry-run cell (``launch/dryrun.py --serving``) reports before
launch.

Per-step attribution then happens entirely on the telemetry side
(:mod:`repro.obs.telemetry`): each measured step is tagged with the
family label(s) it executed, its wall time is split into
``sched + device + draft + host`` (exact by construction — the split is
derived from the step's own span timestamps, so the components sum to the
measured wall; asserted within tolerance in ``tests/test_attrib.py``),
and *padding waste* prices the flat step's ``fill`` in time units:
``(width - real_tokens) * per_token_s`` of the family's roofline cost.
Per-drain rollups (:func:`summarize`) report MFU/MBU, achieved- vs
roofline-tokens/s, padding-waste ratio and goodput.

Warmup-only contract: nothing in this module runs per step.  The cost
model is a frozen dict after ``build_cost_model`` returns; the per-step
hot path only ever does a dict lookup and float arithmetic on the host.
Lowering here uses *fresh* ``jax.jit`` wrappers around the raw step
functions, so the model's counted ``jit_step`` caches — and with them the
zero-post-warmup-trace invariant — are untouched.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

__all__ = ["FamilyCost", "StepCostModel", "build_cost_model",
           "kv_page_bytes_per_token", "fresh_totals", "update_aggregates",
           "finalize_summary", "summarize"]


@dataclasses.dataclass(frozen=True)
class FamilyCost:
    """Predicted cost of one compiled step family (one ladder shape)."""

    label: str                 # e.g. "flat[1,64]/k1", "chunk[4,16]/verify"
    width: int                 # padded token positions per step (the grid)
    flops: float               # while-aware dot FLOPs per step
    hbm_bytes: float           # while-aware HBM traffic per step
    kv_gather_bytes: float     # block-table-window KV gather traffic
    compute_s: float           # flops / peak_flops(dtype)
    memory_s: float            # hbm_bytes / hbm_bw
    kv_gather_s: float         # kv_gather_bytes / hbm_bw
    predicted_s: float         # max(compute_s, memory_s) — the roofline
    per_token_s: float         # predicted_s / width (padding-waste price)
    bottleneck: str            # "compute" | "memory"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class StepCostModel:
    """The per-family roofline table, frozen at warmup.

    ``families`` maps the family label (the same string the engine tags
    measured steps with) to its :class:`FamilyCost`.  ``hw``/``dtype``
    record what the prediction was priced against; ``flops_per_token`` is
    the model-FLOPs rate used for MFU (2·N_active per token)."""

    hw_name: str
    dtype: str
    peak_flops: float
    hbm_bw: float
    flops_per_token: float
    families: Dict[str, FamilyCost]

    def get(self, label: str) -> Optional[FamilyCost]:
        return self.families.get(label)

    def to_dict(self) -> dict:
        return {
            "hw": self.hw_name, "dtype": self.dtype,
            "peak_flops": self.peak_flops, "hbm_bw": self.hbm_bw,
            "flops_per_token": self.flops_per_token,
            "families": {k: v.to_dict() for k, v in self.families.items()},
        }


def kv_page_bytes_per_token(caches, num_pages: int, page_tokens: int) -> float:
    """Bytes of paged K/V per cached token, summed over every page-pool
    leaf — those with an adjacent ``(num_pages, page_tokens)`` dim pair
    (``[layers, num_pages, page_tokens, heads, d_head]`` in the grouped
    attention caches).  Per-slot recurrent state (no such pair) is
    excluded: it is not gathered through the block table."""
    total = 0.0
    for leaf in _tree_leaves(caches):
        shape = tuple(int(d) for d in getattr(leaf, "shape", ()))
        paged = any(shape[i] == num_pages and shape[i + 1] == page_tokens
                    for i in range(len(shape) - 1))
        if paged:
            nbytes = float(np.dtype(leaf.dtype).itemsize)
            for d in shape:
                nbytes *= d
            total += nbytes / (num_pages * page_tokens)
    return total


def _tree_leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


def _family_geometry(label: str, engine) -> tuple:
    """(padded token positions, gathering rows) of a family, parsed from
    its label — the same grammar ``analysis.shapes.step_families`` emits:
    ``flat[1,W]/kK`` | ``chunk[B,S](/verify)`` | ``prefill[1,L]`` |
    ``decode[B,1]`` | ``verify[B,K]``."""
    dims = label.split("[", 1)[1].split("]", 1)[0]
    a, b = (int(x) for x in dims.split(","))
    width = a * b
    rows = engine.slots if label.startswith("flat") else a
    return width, rows


def build_cost_model(engine, hw=None) -> StepCostModel:
    """Lower + compile every step family with abstract stand-ins and price
    it against ``hw`` (default: :func:`repro.core.hardware.query`).  Runs
    once, at warmup — see the module docstring for the contract."""
    import jax

    from repro.analysis.shapes import step_families
    from repro.core.hardware import query
    from repro.roofline.hlo_cost import parse_hlo, xla_cost_dict

    hw = hw if hw is not None else query()
    dtype = engine.model.compute_dtype
    peak = hw.peak_flops(dtype)
    kv_per_token = kv_page_bytes_per_token(
        engine.caches, engine.pool.num_pages, engine.pool.page_tokens)
    window_tokens = engine.max_pages * engine.pool.page_tokens

    families: Dict[str, FamilyCost] = {}
    for label, fn, abstract_args in step_families(engine):
        compiled = jax.jit(fn).lower(*abstract_args).compile()
        cost = xla_cost_dict(compiled.cost_analysis())
        parsed = parse_hlo(compiled.as_text())
        flops = float(parsed.dot_flops) or float(cost.get("flops", 0.0))
        nbytes = float(parsed.hbm_bytes) \
            or float(cost.get("bytes accessed", 0.0))
        width, rows = _family_geometry(label, engine)
        gather = rows * window_tokens * kv_per_token
        compute_s = flops / peak
        memory_s = nbytes / hw.hbm_bw
        predicted = max(compute_s, memory_s)
        families[label] = FamilyCost(
            label=label, width=width, flops=flops, hbm_bytes=nbytes,
            kv_gather_bytes=gather, compute_s=compute_s, memory_s=memory_s,
            kv_gather_s=gather / hw.hbm_bw, predicted_s=predicted,
            per_token_s=predicted / max(1, width),
            bottleneck="compute" if compute_s >= memory_s else "memory")

    n_active = engine.model.cfg.param_counts()["active"]
    return StepCostModel(hw_name=hw.name, dtype=str(dtype), peak_flops=peak,
                         hbm_bw=hw.hbm_bw,
                         flops_per_token=2.0 * n_active,
                         families=families)


def fresh_totals() -> dict:
    """A zeroed drain-total accumulator (see :func:`update_aggregates`)."""
    return {"steps": 0, "wall_s": 0.0, "sched_s": 0.0, "device_s": 0.0,
            "draft_s": 0.0, "host_s": 0.0, "predicted_s": 0.0,
            "padding_waste_s": 0.0, "real_tokens": 0, "padded_tokens": 0}


def update_aggregates(tot: dict, fams: Dict[str, dict], rec: dict,
                      cost_model: Optional[StepCostModel]) -> None:
    """Fold one per-step attribution record into the running drain
    aggregates (mutates ``tot``/``fams`` in place).  Incremental so the
    telemetry's bounded per-step window can drop old records without the
    drain summary losing them."""
    tot["steps"] += 1
    tot["wall_s"] += rec["wall"]
    tot["sched_s"] += rec["sched"]
    tot["device_s"] += rec["device"]
    tot["draft_s"] += rec["draft"]
    tot["host_s"] += rec["host"]
    for label, real, width, dev_s in rec["families"]:
        f = fams.setdefault(label, {
            "steps": 0, "real_tokens": 0, "padded_tokens": 0,
            "device_s": 0.0, "predicted_s": 0.0, "padding_waste_s": 0.0})
        f["steps"] += 1
        f["real_tokens"] += real
        f["padded_tokens"] += width
        f["device_s"] += dev_s
        fc = cost_model.get(label) if cost_model is not None else None
        if fc is not None:
            f["predicted_s"] += fc.predicted_s
            f["padding_waste_s"] += (width - real) * fc.per_token_s
        tot["real_tokens"] += real
        tot["padded_tokens"] += width
        tot["predicted_s"] += fc.predicted_s if fc is not None else 0.0
        tot["padding_waste_s"] += ((width - real) * fc.per_token_s
                                   if fc is not None else 0.0)


def finalize_summary(tot: dict, fams: Dict[str, dict],
                     cost_model: Optional[StepCostModel], *,
                     goodput_tokens: int = 0,
                     tokens_out: int = 0) -> dict:
    """The per-drain attribution view over the running aggregates:
    component totals, per-family predicted-vs-measured, MFU/MBU, padding
    waste, achieved- vs roofline-tokens/s and goodput.

    MFU uses *useful* model FLOPs (real tokens x 2·N_active) over
    measured wall x peak; MBU uses the families' modelled HBM bytes over
    wall x bandwidth — both are honest about padding (padded positions
    burn wall time but earn no useful FLOPs, so waste lowers MFU exactly
    as it should)."""
    fams = {label: dict(f) for label, f in fams.items()}
    for f in fams.values():
        f["fill"] = f["real_tokens"] / max(1, f["padded_tokens"])
        f["predicted_vs_measured"] = (f["predicted_s"] / f["device_s"]
                                      if f["device_s"] > 0 else 0.0)
    wall = tot["wall_s"]
    out = {"totals": dict(tot), "families": fams}
    if cost_model is not None and wall > 0:
        useful_flops = tot["real_tokens"] * cost_model.flops_per_token
        modelled_bytes = sum(
            f["steps"] * cost_model.get(l).hbm_bytes
            for l, f in fams.items() if cost_model.get(l) is not None)
        out["mfu"] = useful_flops / (wall * cost_model.peak_flops)
        out["mbu"] = modelled_bytes / (wall * cost_model.hbm_bw)
        out["padding_waste_ratio"] = (tot["padding_waste_s"]
                                      / max(tot["device_s"], 1e-12))
        out["achieved_tokens_per_s"] = tot["real_tokens"] / wall
        out["roofline_tokens_per_s"] = (
            tot["real_tokens"] / tot["predicted_s"]
            if tot["predicted_s"] > 0 else math.inf)
        out["roofline_fraction"] = (tot["predicted_s"] / wall
                                    if wall > 0 else 0.0)
    out["goodput_tokens"] = goodput_tokens
    out["tokens_out"] = tokens_out
    out["goodput_ratio"] = goodput_tokens / max(1, tokens_out)
    return out


def summarize(step_records: List[dict], cost_model: Optional[StepCostModel],
              *, goodput_tokens: int = 0, tokens_out: int = 0) -> dict:
    """One-shot :func:`finalize_summary` over a list of step records
    (the standalone path; the live telemetry aggregates incrementally)."""
    tot, fams = fresh_totals(), {}
    for rec in step_records:
        update_aggregates(tot, fams, rec, cost_model)
    return finalize_summary(tot, fams, cost_model,
                            goodput_tokens=goodput_tokens,
                            tokens_out=tokens_out)
