"""Streaming metrics: counters, gauges, and fixed-bucket histograms.

Zero-dependency (stdlib + the host's float math), zero-retention: a
:class:`Histogram` folds every observation into a fixed geometric bucket
grid at ``observe`` time and answers p50/p95/p99 by interpolating inside
the bucket the requested rank lands in — memory is O(buckets) forever,
never O(samples), which is what lets the serving engine keep latency
percentiles on every step of a long-lived drain without growing state.

Accuracy contract: with bucket ``factor`` f (adjacent bucket edges are a
ratio f apart), any percentile estimate is within a factor of f of the
exact sample quantile — the default ``f = 2**0.25`` bounds the relative
error at ~19% of the value, far below the run-to-run noise of host wall
timings, for 120-odd int buckets per histogram.  Estimates are clamped
to the observed ``[min, max]``, so single-sample histograms are exact.

Reset semantics (the registry's per-metric ``scope``):

- ``"drain"`` (the default) — the metric measures a *serving window*:
  it accumulates until the owner explicitly resets it
  (``MetricsRegistry.reset()``; the engine exposes this as
  ``Engine.telemetry(reset=True)``, typically called once per drain).
  Nothing resets implicitly — two back-to-back drains without a reset
  read as one window, by design, never double-counted.
- ``"lifetime"`` — never reset: monotone totals and peaks that mirror
  the classic ``stats()`` counters.
"""

from __future__ import annotations

from bisect import bisect_right
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """A monotone event count (``inc``).  ``scope`` says who resets it."""

    __slots__ = ("name", "scope", "value")

    def __init__(self, name: str, *, scope: str = "drain"):
        assert scope in ("drain", "lifetime"), scope
        self.name = name
        self.scope = scope
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snapshot(self):
        return self.value


class Gauge:
    """A momentary level (``set``) — queue depth, live slots, pool pages.
    A gauge has no window to reset: it always reads the last value."""

    __slots__ = ("name", "scope", "value")

    def __init__(self, name: str):
        self.name = name
        self.scope = "lifetime"      # momentary; reset would be meaningless
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        pass

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket streaming histogram over positive reals.

    Buckets are geometric: edges ``lo * factor**i`` spanning ``[lo, hi]``,
    plus an underflow bucket ``[0, lo)`` and an overflow bucket
    ``[hi, inf)``.  ``observe`` is a bisect plus counter bumps; percentiles
    walk the cumulative counts once and interpolate log-linearly inside
    the landing bucket (linearly inside the underflow bucket, whose lower
    edge is 0).  No samples are retained.
    """

    __slots__ = ("name", "scope", "_edges", "_counts", "count", "total",
                 "_min", "_max")

    def __init__(self, name: str, *, lo: float = 1e-6, hi: float = 1e3,
                 factor: float = 2 ** 0.25, scope: str = "drain"):
        assert scope in ("drain", "lifetime"), scope
        assert 0 < lo < hi and factor > 1
        self.name = name
        self.scope = scope
        edges: List[float] = [lo]
        while edges[-1] < hi:
            edges.append(edges[-1] * factor)
        self._edges = edges                       # len(edges)+1 buckets
        self._counts = [0] * (len(edges) + 1)
        self.count = 0
        self.total = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        if v < 0.0:
            v = 0.0                 # clock skew guard; latencies are >= 0
        self._counts[bisect_right(self._edges, v)] += 1
        self.count += 1
        self.total += v
        if self._min is None or v < self._min:
            self._min = v
        if self._max is None or v > self._max:
            self._max = v

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-quantile (``0 <= q <= 1``) of everything
        observed so far; 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        need = q * self.count
        cum = 0.0
        for i, c in enumerate(self._counts):
            if c and cum + c >= need:
                frac = min(1.0, max(0.0, (need - cum) / c))
                lo = 0.0 if i == 0 else self._edges[i - 1]
                hi = (self._edges[i] if i < len(self._edges)
                      else (self._max if self._max is not None else lo))
                if lo <= 0.0 or hi <= lo:
                    est = lo + (hi - lo) * frac
                else:
                    est = lo * (hi / lo) ** frac       # log-linear
                return min(max(est, self._min), self._max)
            cum += c
        return self._max if self._max is not None else 0.0

    def reset(self) -> None:
        self._counts = [0] * len(self._counts)
        self.count = 0
        self.total = 0.0
        self._min = self._max = None

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean": self.total / self.count if self.count else 0.0,
            "min": self._min if self._min is not None else 0.0,
            "max": self._max if self._max is not None else 0.0,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """The one named home for every streaming metric the telemetry layer
    keeps (counters, gauges, histograms), with uniform get-or-create
    accessors, one ``snapshot()`` and one explicit ``reset()``.

    Scope contract (see the module docstring): ``"drain"`` metrics are
    window counters the *caller* resets — ``reset()`` zeroes exactly
    those and nothing else; ``"lifetime"`` metrics and gauges survive.
    A metric's scope is fixed at first registration; re-registering with
    a different kind or scope is a bug and asserts.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, **kw)
            self._metrics[name] = m
        else:
            assert type(m) is kind, \
                f"metric {name!r} already registered as {type(m).__name__}"
            want = kw.get("scope")
            assert want is None or m.scope == want, \
                f"metric {name!r} registered with scope {m.scope!r}, " \
                f"asked for {want!r}"
        return m

    def counter(self, name: str, *, scope: str = "drain") -> Counter:
        return self._get(name, Counter, scope=scope)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def snapshot(self) -> dict:
        """``{name: value-or-histogram-summary}`` plus a ``_scope`` map
        so a reader can tell window counters from lifetime ones."""
        out = {name: m.snapshot() for name, m in self._metrics.items()}
        out["_scope"] = {name: m.scope for name, m in self._metrics.items()}
        return out

    def reset(self, scope: str = "drain") -> None:
        """Zero every metric of ``scope`` (the explicit per-drain reset —
        nothing in this module resets implicitly)."""
        for m in self._metrics.values():
            if m.scope == scope:
                m.reset()
