"""Online serving anomaly monitors over the live metrics registry.

Host-side, allocation-light detectors the telemetry layer runs once per
``step_end``: each keeps a small bounded window of recent observations
and emits a typed :class:`Alert` when its rule trips.  Alerts land in
three places — the bounded ``Monitors.alerts`` deque (surfaced as
``Engine.telemetry()["alerts"]``), an ``alert:<kind>`` instant on the
``monitor`` trace track, and the ``alerts_emitted`` counter.  Like every
other ``repro.obs`` component the monitors are strict observers: they
read scheduler/pool/step state that the engine already computed, never
touch a jitted path, and a drain with monitors on is token-identical to
one without (checked in ``tests/test_attrib.py``).

Monitors (all windows are step-indexed, sizes are constructor knobs):

``step-outlier``
    Per-family step device time vs the family's rolling median: a step
    slower than ``outlier_factor`` x median over a warm window (>=
    ``outlier_min`` samples) is an anomaly — a GC stall, a page-copy
    storm, a noisy neighbour.  Per family, not global, so a legitimate
    wide-prefill step never shadows a slow decode step.
``preempt-storm``
    Preemptions over the last ``window`` steps above ``storm_limit``:
    the pool is thrashing (working set over capacity) and throughput is
    going to recompute, not progress.
``prefix-churn``
    Prefix-cache evictions over the window above ``churn_limit`` while
    the same window's hit count stays at or below it: the cache is
    cycling entries without serving them (capacity too small or keys
    never reused).
``queue-growth``
    Wait-queue depth sampled each step grew monotonically across the
    full window and by at least ``growth_min``: arrivals outpace service
    and the backlog is diverging, the page admission control should be
    shedding.
``slo-burn``
    TTFT/ITL observations violating the configured SLO targets
    (``slo_ttft_s`` / ``slo_itl_s``; ``None`` disables) at a rate above
    ``burn_rate`` over the last ``slo_window`` observations: the error
    budget is burning faster than sustainable.  Disabled by default —
    set the targets to enable (``examples/serve_decode.py --slo-ttft``).

Every alert kind re-arms only after its condition clears (one alert per
excursion, not one per step), so a pathological drain cannot flood the
trace; the deque bound caps total retention regardless.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["Alert", "Monitors"]


@dataclasses.dataclass(frozen=True)
class Alert:
    """One typed anomaly finding."""

    kind: str          # step-outlier | preempt-storm | prefix-churn |
                       # queue-growth | slo-burn
    severity: str      # "warn" | "crit"
    step: int          # engine step index the rule tripped at
    t: float           # telemetry clock at emission
    value: float       # the observed quantity
    threshold: float   # the bound it crossed
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


class Monitors:
    """The monitor bank one :class:`~repro.obs.telemetry.Telemetry` owns.
    ``observe_step`` is the single per-step entry point; TTFT/ITL
    observations stream in via ``observe_ttft``/``observe_itl``."""

    def __init__(self, *, window: int = 32, outlier_factor: float = 4.0,
                 outlier_min: int = 8, storm_limit: Optional[int] = None,
                 churn_limit: int = 8, growth_min: Optional[int] = None,
                 slo_ttft_s: Optional[float] = None,
                 slo_itl_s: Optional[float] = None,
                 burn_rate: float = 0.10, slo_window: int = 32,
                 max_alerts: int = 256):
        self.window = window
        self.outlier_factor = outlier_factor
        self.outlier_min = outlier_min
        self.storm_limit = storm_limit      # None -> scheduler slots
        self.churn_limit = churn_limit
        self.growth_min = growth_min        # None -> scheduler slots
        self.slo_ttft_s = slo_ttft_s
        self.slo_itl_s = slo_itl_s
        self.burn_rate = burn_rate
        self.alerts: Deque[Alert] = deque(maxlen=max_alerts)
        self._step = 0
        self._fam_dev: Dict[str, Deque[float]] = {}
        self._preempt: Deque[int] = deque(maxlen=window)
        self._evict: Deque[int] = deque(maxlen=window)
        self._hits: Deque[int] = deque(maxlen=window)
        self._depth: Deque[int] = deque(maxlen=window)
        self._ttft_viol: Deque[bool] = deque(maxlen=slo_window)
        self._itl_viol: Deque[bool] = deque(maxlen=slo_window)
        self._last_preempt = 0
        self._last_evict = 0
        self._last_hits = 0
        self._armed = {k: True for k in
                       ("step-outlier", "preempt-storm", "prefix-churn",
                        "queue-growth", "slo-burn:ttft", "slo-burn:itl")}
        self._emitted: List[Alert] = []     # this step's fresh alerts

    # ------------------------------------------------------------------
    def observe_ttft(self, v: float) -> None:
        if self.slo_ttft_s is not None:
            self._ttft_viol.append(v > self.slo_ttft_s)

    def observe_itl(self, v: float) -> None:
        if self.slo_itl_s is not None:
            self._itl_viol.append(v > self.slo_itl_s)

    def observe_step(self, *, t: float, scheduler, telemetry,
                     families, device_s: float) -> List[Alert]:
        """Run every rule against this step; returns the alerts that
        fired *this step* (already appended to ``self.alerts``)."""
        self._step += 1
        self._emitted = []
        slots = max(1, scheduler.max_slots)
        storm_limit = (self.storm_limit if self.storm_limit is not None
                       else slots)
        growth_min = (self.growth_min if self.growth_min is not None
                      else slots)

        # per-family step-time outlier vs the rolling median.  The
        # current sample joins the window only after the comparison, so
        # a single spike cannot drag its own baseline up.
        for label, real, width, dev_s in families:
            win = self._fam_dev.setdefault(
                label, deque(maxlen=self.window))
            if len(win) >= self.outlier_min:
                med = _median(win)
                bound = self.outlier_factor * med
                if med > 0 and dev_s > bound:
                    self._fire("step-outlier", "warn", t, dev_s, bound,
                               f"{label}: device {dev_s * 1e3:.2f}ms > "
                               f"{self.outlier_factor:.0f}x rolling median "
                               f"{med * 1e3:.2f}ms")
                elif dev_s <= bound:
                    self._armed["step-outlier"] = True
            win.append(dev_s)

        # preemption storm: window sum of per-step preemption deltas
        cur = scheduler.num_preemptions
        self._preempt.append(cur - self._last_preempt)
        self._last_preempt = cur
        storm = sum(self._preempt)
        if storm > storm_limit:
            self._fire("preempt-storm", "crit", t, storm, storm_limit,
                       f"{storm} preemptions in the last "
                       f"{len(self._preempt)} steps (> {storm_limit}): "
                       f"the pool is thrashing")
        else:
            self._armed["preempt-storm"] = True

        # prefix-cache churn: evictions without hits over the window
        reg = telemetry.registry
        evict = reg.counter("prefix_evictions").value
        hits = reg.counter("prefix_hits").value
        self._evict.append(evict - self._last_evict)
        self._hits.append(hits - self._last_hits)
        self._last_evict, self._last_hits = evict, hits
        churn, served = sum(self._evict), sum(self._hits)
        if churn > self.churn_limit and served <= self.churn_limit:
            self._fire("prefix-churn", "warn", t, churn, self.churn_limit,
                       f"{churn} prefix-cache evictions vs {served} hits "
                       f"over {len(self._evict)} steps: the cache is "
                       f"cycling without serving")
        else:
            self._armed["prefix-churn"] = True

        # queue growth: depth monotonically increasing across the window
        self._depth.append(len(scheduler.waiting))
        d = self._depth
        if len(d) == d.maxlen and d[-1] - d[0] >= growth_min \
                and all(b >= a for a, b in zip(d, list(d)[1:])):
            self._fire("queue-growth", "crit", t, d[-1] - d[0], growth_min,
                       f"wait queue grew {d[0]} -> {d[-1]} monotonically "
                       f"over {len(d)} steps: arrivals outpace service")
        else:
            self._armed["queue-growth"] = True

        # SLO burn rate over the recent observation window
        for name, win in (("ttft", self._ttft_viol),
                          ("itl", self._itl_viol)):
            key = f"slo-burn:{name}"
            if len(win) < max(4, win.maxlen // 4):
                continue
            rate = sum(win) / len(win)
            if rate > self.burn_rate:
                self._fire(key, "crit", t, rate, self.burn_rate,
                           f"{name} SLO violated on {rate:.0%} of the "
                           f"last {len(win)} observations "
                           f"(budget {self.burn_rate:.0%})",
                           kind="slo-burn")
            else:
                self._armed[key] = True
        return self._emitted

    # ------------------------------------------------------------------
    def _fire(self, key: str, severity: str, t: float, value: float,
              threshold: float, message: str, *,
              kind: Optional[str] = None) -> None:
        if not self._armed.get(key, True):
            return                          # one alert per excursion
        self._armed[key] = False
        alert = Alert(kind=kind or key, severity=severity, step=self._step,
                      t=t, value=float(value), threshold=float(threshold),
                      message=message)
        self.alerts.append(alert)
        self._emitted.append(alert)
