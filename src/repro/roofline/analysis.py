"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = collective_bytes / ICI_link_bw   (per chip)

``compiled.cost_analysis()`` on the SPMD-partitioned module reports the
*per-device* program, so terms are per-chip directly.  Hardware constants
come from the HardwareSpec (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI).

Also reports MODEL_FLOPS = 6·N·D (train; 2·N·D inference) with N the
(active) parameter count, and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs · chips) that exposes remat/padding/redundancy
waste.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.hardware import HardwareSpec
from repro.roofline.hlo_parse import collective_bytes, count_ops

__all__ = ["model_flops", "roofline_terms", "RooflineReport"]


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n = cfg.param_counts()
    n_active = n["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    collective_detail: dict
    op_counts: dict
    memory_per_device: Optional[dict]
    step_time_bound_s: float = 0.0
    roofline_fraction: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(*, arch: str, shape_spec: ShapeSpec, mesh_name: str,
                   chips: int, cfg: ModelConfig, hw: HardwareSpec,
                   cost: dict, hlo_text: str, compute_dtype: str = "bfloat16",
                   memory_stats: Optional[dict] = None) -> RooflineReport:
    # While-aware parse (exec counts x loop trips): XLA's cost_analysis
    # counts scan bodies once, so it undercounts scanned-layer programs by
    # the trip-count product; parse_hlo re-derives per-device dot FLOPs,
    # HBM traffic and collective bytes with execution counts.
    from repro.roofline.hlo_cost import parse_hlo, xla_cost_dict
    cost = xla_cost_dict(cost)
    parsed = parse_hlo(hlo_text)
    flops = float(parsed.dot_flops)
    nbytes = float(parsed.hbm_bytes)
    coll = {"bytes": parsed.collective_bytes,
            "counts": parsed.collective_counts,
            "while_trips": parsed.while_trips,
            "raw_once": parsed.raw_once,
            "xla_cost_analysis_flops_once": float(cost.get("flops", 0.0)),
            "xla_cost_analysis_bytes_once": float(cost.get("bytes accessed", 0.0))}
    cbytes = float(parsed.collective_bytes.get("total", 0.0))

    peak = hw.flops_bf16 if "16" in compute_dtype else hw.flops_f32
    compute_s = flops / peak
    memory_s = nbytes / hw.hbm_bw
    collective_s = cbytes / hw.ici_bw

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape_spec)
    useful = mf / max(1.0, flops * chips)
    # step-time lower bound if the dominant term were perfectly overlapped
    # with the others; roofline fraction = ideal model-compute time / bound.
    bound = max(terms.values())
    ideal = mf / (chips * peak)
    return RooflineReport(
        arch=arch, shape=shape_spec.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=nbytes,
        collective_bytes_per_chip=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf, useful_ratio=useful,
        collective_detail=coll, op_counts=count_ops(hlo_text),
        memory_per_device=memory_stats,
        step_time_bound_s=bound,
        roofline_fraction=(ideal / bound if bound > 0 else 0.0),
    )
