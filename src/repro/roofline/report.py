"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.2f}"


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def one_liner(rec: dict) -> str:
    """The per-cell 'what would move the dominant term down' note."""
    b = rec["bottleneck"]
    shape = rec["shape"]
    if b == "collective":
        if "moe" in rec["arch"] or "arctic" in rec["arch"] or "jamba" in rec["arch"]:
            return ("EP all-to-all + grad reduce dominate; overlap dispatch with "
                    "expert GEMMs / hierarchical reduce would cut it")
        return ("TP activation all-reduces + FSDP gathers dominate; "
                "sequence-sharding activations turns all-reduce into "
                "reduce-scatter (1/2 bytes)")
    if b == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("weight+KV streaming bound (decode is bandwidth-limited by "
                    "nature); KV quantization or wider batch raises intensity")
        return ("activation traffic bound; bigger fusion regions / flash "
                "attention / bf16 residuals reduce HBM bytes")
    return ("MXU-bound — already compute-limited; only layout padding trims "
            "(useful_ratio) remain")


def render(recs: list[dict], mesh: str = "pod") -> str:
    rows = [r for r in recs if r["mesh"] == mesh
            and r.get("policy", "scalable") == "scalable"
            and r.get("propagate", True)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        f"### Roofline table — {mesh} mesh "
        f"({rows[0]['chips'] if rows else '?'} chips, per-chip terms, "
        "v5e constants: 197 TF bf16 / 819 GB/s HBM / 50 GB/s link)",
        "",
        "| arch | shape | compute ms | memory ms | collective ms | bound | "
        "MODEL_FLOPS/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        useful = r["model_flops"] / max(1.0, r["hlo_flops_per_chip"] * r["chips"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(r['compute_s'])} | "
            f"{_fmt_ms(r['memory_s'])} | {_fmt_ms(r['collective_s'])} | "
            f"{r['bottleneck']} | {useful:.2f} | "
            f"{r['roofline_fraction']:.3f} | {one_liner(r)} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    recs = load(args.dir)
    print(render(recs, args.mesh))
    print()
    print(render(recs, "multipod") if args.mesh == "pod" else "")


if __name__ == "__main__":
    main()
