"""Parse collective traffic out of optimized (post-SPMD-partitioning) HLO.

``compiled.as_text()`` is the per-device partitioned module; GSPMD has
already materialized the collectives.  We sum the *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

XLA's text format references operands by name only (``all-gather(%param.1)``),
so parsing is two-pass: (1) map every instruction name to its result shape,
(2) resolve collective operand names against that map (falling back to any
inline-typed operands).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "count_ops"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# instruction definition: "%name = <type> opcode(...)" (type may be a tuple)
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+"
                  r"\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z][\w\-]*)\(")
_TYPED = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPED.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {"bytes": {kind: operand_bytes, "total": ...},
                "counts": {kind: n}}.

    ``-done`` ops are skipped (their operand is the in-flight ``-start``),
    so async collectives are counted once.
    """
    shapes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF.match(line)
        if m:
            shapes[m.group(1)] = _type_bytes(m.group(2))

    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for line in lines:
        m = _DEF.match(line)
        if not m:
            continue
        opcode = m.group(3)
        kind = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
        if kind is None or opcode.endswith("-done"):
            continue
        # operand list: text inside the first parens after the opcode
        start = line.index(opcode + "(") + len(opcode) + 1
        depth, end = 1, start
        while end < len(line) and depth:
            if line[end] == "(":
                depth += 1
            elif line[end] == ")":
                depth -= 1
            end += 1
        operands = line[start:end - 1]
        typed = _type_bytes(operands)
        if typed:
            nbytes = typed
        else:
            nbytes = sum(shapes.get(nm, 0)
                         for nm in _OPERAND_NAME.findall(operands))
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES if k in out)
    return {"bytes": dict(out), "counts": dict(counts)}


def count_ops(hlo_text: str, names=("fusion", "custom-call", "dot", "convolution",
                                    "transpose", "copy", "all-gather",
                                    "all-reduce", "reduce-scatter",
                                    "all-to-all", "collective-permute")) -> dict:
    counts = {}
    for n in names:
        counts[n] = len(re.findall(rf"\b{re.escape(n)}[\w\-]*\(", hlo_text))
    return counts
