"""While-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts a while/scan body ONCE regardless of
trip count (verified empirically — see EXPERIMENTS.md §Dry-run methodology),
which undercounts scanned-layer models by the product of scan trip counts.
This module re-derives per-device costs from the partitioned HLO text with
execution counts:

  - computation graph: ENTRY + while bodies/conditions (trip count parsed
    from the loop-condition constant), conditional branches;
  - exec_count(computation) = product of enclosing trip counts;
  - dot FLOPs from operand shapes x contracting dims x exec_count;
  - HBM traffic model: operand+result bytes of top-level fusion / dot /
    convolution / copy / sort / scatter / gather / reduce instructions
    (XLA fuses elementwise chains, so fusion boundaries approximate actual
    HBM round-trips) x exec_count;
  - collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute x exec_count.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["parse_hlo", "HloCost", "xla_cost_dict"]


def xla_cost_dict(cost) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return a one-element list of per-program dicts, newer ones
    return the dict directly; either may be ``None`` for backends without a
    cost model.  Always returns a plain dict.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_HBM_OPS = ("fusion", "dot", "convolution", "copy", "sort", "scatter",
            "gather", "reduce", "transpose", "reshape", "broadcast",
            "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
            "slice", "select-and-scatter", "iota", "rng", "compare",
            "add", "multiply", "subtract", "divide", "exponential",
            "tanh", "convert", "cholesky", "triangular-solve")

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_SIMPLE_TYPE = re.compile(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?")
_OPCODE = re.compile(r"\s*([a-zA-Z][\w\-]*)\(")


def _parse_instr_line(line: str):
    """Robust instruction parse handling tuple types with /*index=N*/
    comments and nested parens."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":
        depth, j = 1, i + 1
        while j < len(line) and depth:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        type_str = line[i:j]
    else:
        m2 = _SIMPLE_TYPE.match(line, i)
        if not m2:
            return None
        type_str = m2.group(0)
        j = m2.end()
    m3 = _OPCODE.match(line, j)
    if not m3:
        return None
    return m.group(1), type_str, m3.group(1), line[m3.end():]
_TYPED = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_SHAPE_ONLY = re.compile(r"^([a-z0-9]+)\[([0-9,]*)\]")


def _dims(dims_str: str) -> list[int]:
    return [int(d) for d in dims_str.split(",") if d.strip()]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPED.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # text after the opcode's '('


@dataclasses.dataclass
class _Comp:
    name: str
    entry: bool
    instrs: list
    fused: bool = False  # called via fusion `calls=` — no HBM accounting


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: dict
    collective_counts: dict
    while_trips: dict
    raw_once: dict

    def to_dict(self):
        return dataclasses.asdict(self)


def _split_computations(text: str) -> list[_Comp]:
    comps = []
    cur = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line.strip()) if line and not line.startswith(" ") else None
        if m and line.rstrip().endswith("{"):
            cur = _Comp(name=m.group(2), entry=bool(m.group(1)), instrs=[])
            comps.append(cur)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            cur.instrs.append(_Instr(*parsed))
    return comps


def _operands_region(rest: str) -> str:
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return rest[:i - 1]


def parse_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}

    # instruction result shapes (global: names unique per module in practice)
    shapes: dict[str, str] = {}
    for c in comps:
        for ins in c.instrs:
            shapes[ins.name] = ins.type_str

    # mark fusion-called computations (do not re-count their innards)
    for c in comps:
        for ins in c.instrs:
            if ins.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.rest)
                if m and m.group(1) in by_name:
                    by_name[m.group(1)].fused = True

    # execution-count propagation: ENTRY=1; while body/cond x trip count;
    # conditional branches x1; call to_apply x1.
    exec_count: dict[str, float] = defaultdict(float)
    trips: dict[str, int] = {}

    def trip_of(cond_name: str) -> int:
        cond = by_name.get(cond_name)
        if cond is None:
            return 1
        best = 1
        for ins in cond.instrs:
            if ins.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*\)", ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    roots = [c for c in comps if c.entry] or comps[:1]
    stack = [(c.name, 1.0) for c in roots]
    seen_pairs = set()
    while stack:
        name, count = stack.pop()
        exec_count[name] += count
        c = by_name.get(name)
        if c is None:
            continue
        for ins in c.instrs:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                # prefer XLA's own trip-count annotation
                mt = re.search(r"known_trip_count[^0-9]*?(\d+)", ins.rest)
                if mt:
                    t = int(mt.group(1))
                else:
                    t = trip_of(mc.group(1)) if mc else 1
                trips[ins.name] = t
                if mb:
                    key = (name, mb.group(1))
                    if key not in seen_pairs:
                        seen_pairs.add(key)
                        stack.append((mb.group(1), count * t))
            elif ins.opcode == "conditional":
                for m in re.finditer(r"%([\w\.\-]+)", ins.rest):
                    if m.group(1) in by_name and by_name[m.group(1)] is not c:
                        pass  # branches counted once via call below
                mb = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                names = []
                if mb:
                    names = _OPERAND.findall(mb.group(1))
                else:
                    for k in ("true_computation", "false_computation"):
                        mk = re.search(rf"{k}=%?([\w\.\-]+)", ins.rest)
                        if mk:
                            names.append(mk.group(1))
                for n in names:
                    stack.append((n, count))
            elif ins.opcode == "call":
                mk = re.search(r"to_apply=%?([\w\.\-]+)", ins.rest)
                if mk:
                    stack.append((mk.group(1), count))

    dot_flops = 0.0
    hbm = 0.0
    coll_bytes: dict = defaultdict(float)
    coll_counts: dict = defaultdict(float)
    raw_once: dict = defaultdict(float)

    for c in comps:
        if c.fused:
            continue
        count = exec_count.get(c.name, 0.0)
        if count == 0.0:
            continue
        for ins in c.instrs:
            operands_str = _operands_region(ins.rest)
            out_b = _type_bytes(ins.type_str)
            in_b = _type_bytes(operands_str)
            if in_b == 0:
                in_b = sum(_type_bytes(shapes.get(nm, ""))
                           for nm in _OPERAND.findall(operands_str))
            kind = next((k for k in _COLLECTIVES if ins.opcode.startswith(k)), None)
            if kind is not None and not ins.opcode.endswith("-done"):
                coll_bytes[kind] += in_b * count
                coll_counts[kind] += count
                raw_once[kind] += in_b
                hbm += (in_b + out_b) * count
                continue
            if ins.opcode == "dot":
                flops = _dot_flops(ins, shapes)
                dot_flops += flops * count
                hbm += (in_b + out_b) * count
                continue
            base = ins.opcode.split(".")[0]
            if any(base.startswith(h) for h in ("fusion", "convolution", "copy",
                                                "sort", "scatter", "gather",
                                                "reduce", "dynamic-slice",
                                                "dynamic-update-slice",
                                                "concatenate", "pad", "slice",
                                                "transpose", "bitcast-convert",
                                                "convert", "select",
                                                "rng", "cholesky")):
                hbm += (in_b + out_b) * count

    coll_bytes["total"] = sum(coll_bytes[k] for k in _COLLECTIVES if k in coll_bytes)
    return HloCost(dot_flops=dot_flops, hbm_bytes=hbm,
                   collective_bytes=dict(coll_bytes),
                   collective_counts=dict(coll_counts),
                   while_trips=dict(trips), raw_once=dict(raw_once))


def _dot_flops(ins: _Instr, shapes: dict) -> float:
    """2 x prod(result dims) x prod(lhs contracting dim sizes)."""
    m = _SHAPE_ONLY.match(ins.type_str.strip())
    if not m:
        return 0.0
    out_elems = 1
    for d in _dims(m.group(2)):
        out_elems *= d
    ops = _OPERAND.findall(_operands_region(ins.rest))
    if not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0], "")
    ml = _SHAPE_ONLY.match(lhs_shape.strip())
    if not ml:
        return 0.0
    lhs_dims = _dims(ml.group(2))
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    contract = 1
    if mc:
        for i in _dims(mc.group(1)):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract
