from repro.kernels.unpack.ops import *  # noqa: F401,F403
