"""Pallas TPU kernel for the unpack layout transformation (inverse of pack).

A_pack[M_o, K_o, t0, t1] -> A[M, K]: each grid step reads a (TM, TK, t0, t1)
stack of tiles from VMEM, retiles it to a row-major (TM*t0, TK*t1) block and
writes it out; out-of-range writes at the ragged edge are masked by the
BlockSpec machinery (padding is *dropped*, per the paper's unpack semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["unpack_kernel_call"]


def _kernel(ap_ref, out_ref):
    tm, tk, t0, t1 = ap_ref.shape
    blk = ap_ref[...]
    out_ref[...] = blk.transpose(0, 2, 1, 3).reshape(tm * t0, tk * t1)


def unpack_kernel_call(a_pack: jnp.ndarray, m: int, k: int, *, tm: int = 8,
                       tk: int = 8, interpret: bool = False) -> jnp.ndarray:
    """A_pack[M_o, K_o, t0, t1] -> A[m, k] (tile padding sliced away)."""
    m_o, k_o, t0, t1 = a_pack.shape
    tm = min(tm, m_o)
    tk = min(tk, k_o)
    grid = (pl.cdiv(m_o, tm), pl.cdiv(k_o, tk))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tk, t0, t1), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((tm * t0, tk * t1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), a_pack.dtype),
        interpret=interpret,
    )(a_pack)
