"""Pure-jnp oracle for the unpack kernel."""

from __future__ import annotations

import jax.numpy as jnp


def unpack_ref(a_pack: jnp.ndarray, m: int, k: int) -> jnp.ndarray:
    mo, ko, t0, t1 = a_pack.shape
    a = a_pack.transpose(0, 2, 1, 3).reshape(mo * t0, ko * t1)
    return a[:m, :k]
