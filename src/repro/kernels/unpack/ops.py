"""Jitted wrapper for the unpack kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.unpack.kernel import unpack_kernel_call
from repro.kernels.unpack.ref import unpack_ref

__all__ = ["unpack"]


@functools.partial(jax.jit, static_argnames=("m", "k", "interpret"))
def _jit_call(a_pack, *, m, k, interpret):
    return unpack_kernel_call(a_pack, m, k, interpret=interpret)


def unpack(a_pack: jnp.ndarray, m: int, k: int, *,
           interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _jit_call(a_pack, m=m, k=k, interpret=interpret)


def unpack_reference(a_pack, m, k):
    return unpack_ref(a_pack, m, k)
