"""Pallas TPU kernel for packed matmul (mmt4d) — paper Listing 2, TPU-native.

The paper's representative SVE microkernel computes an ``8 x 2VL`` output
tile per K step via outer products on packed operands.  The TPU-native
equivalent feeds the MXU from packed tiles resident in VMEM:

  grid (ceil(M_o/TM), ceil(N_o/TN), K_o), K innermost (sequential);
  per step:   A block (TM,1,m_r,k_r) and B block (TN,1,n_r,k_r) stream
              HBM->VMEM; one dot_general of (TM*m_r, k_r) x (TN*n_r, k_r)^T
              accumulates into an fp32 VMEM scratch tile;
  at k==K_o-1: the accumulator is retiled to packed-C layout, the fused
              epilogue (bias + activation, packed-domain) is applied, and
              the C block is written once.

Because the operands are *packed*, every VMEM block is a stack of native
(sublane, lane) hardware tiles and the in-kernel reshapes are contiguous
no-ops — the memory-layout property the paper's scalable layouts exist to
guarantee.  Tile sizes (m_r, n_r, k_r) arrive from the layout object, i.e.
from the hardware descriptor — never hard-coded here.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mmt4d_kernel_call"]

_ACTIVATIONS = {
    None: lambda x: x,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def _kernel(a_ref, b_ref, bias_ref, c_ref, acc_ref, *, k_steps: int,
            activation: Optional[str], out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tm, _, m_r, k_r = a_ref.shape
    tn, _, n_r, _ = b_ref.shape
    a = a_ref[...].reshape(tm * m_r, k_r)          # contiguous: packed tiles
    b = b_ref[...].reshape(tn * n_r, k_r)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        out = acc.reshape(tm, m_r, tn, n_r).transpose(0, 2, 1, 3)
        if bias_ref is not None:
            out = out + bias_ref[...][None, :, None, :].astype(jnp.float32)
        out = _ACTIVATIONS[activation](out)
        c_ref[...] = out.astype(out_dtype)


def mmt4d_kernel_call(a_pack: jnp.ndarray, b_pack: jnp.ndarray,
                      bias_pack: Optional[jnp.ndarray] = None, *,
                      activation: Optional[str] = None,
                      tm: int = 16, tn: int = 4,
                      interpret: bool = False) -> jnp.ndarray:
    """Run the Pallas mmt4d kernel.

    a_pack: [M_o, K_o, m_r, k_r]; b_pack: [N_o, K_o, n_r, k_r];
    bias_pack: optional [N_o, n_r] (bias already in packed-N layout).
    Returns C_pack [M_o, N_o, m_r, n_r] in ``a_pack.dtype``.
    """
    m_o, k_o, m_r, k_r = a_pack.shape
    n_o, k_o2, n_r, k_r2 = b_pack.shape
    assert (k_o, k_r) == (k_o2, k_r2), (a_pack.shape, b_pack.shape)
    tm = min(tm, m_o)
    tn = min(tn, n_o)
    grid = (pl.cdiv(m_o, tm), pl.cdiv(n_o, tn), k_o)

    in_specs = [
        pl.BlockSpec((tm, 1, m_r, k_r), lambda i, j, k: (i, k, 0, 0)),
        pl.BlockSpec((tn, 1, n_r, k_r), lambda i, j, k: (j, k, 0, 0)),
    ]
    inputs = [a_pack, b_pack]
    if bias_pack is not None:
        in_specs.append(pl.BlockSpec((tn, n_r), lambda i, j, k: (j, 0)))
        inputs.append(bias_pack)
    else:
        in_specs.append(None)
        inputs.append(None)

    kernel = functools.partial(_kernel, k_steps=k_o, activation=activation,
                               out_dtype=a_pack.dtype)

    def body(a, b, bias):
        args = (a, b) if bias is None else (a, b, bias)
        specs = in_specs[:2] if bias is None else in_specs

        def kern(*refs):
            if bias is None:
                a_ref, b_ref, c_ref, acc_ref = refs
                kernel(a_ref, b_ref, None, c_ref, acc_ref)
            else:
                a_ref, b_ref, bias_ref, c_ref, acc_ref = refs
                kernel(a_ref, b_ref, bias_ref, c_ref, acc_ref)

        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=specs,
            out_specs=pl.BlockSpec((tm, tn, m_r, n_r), lambda i, j, k: (i, j, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((m_o, n_o, m_r, n_r), a_pack.dtype),
            scratch_shapes=[pltpu.VMEM((tm * m_r, tn * n_r), jnp.float32)],
            interpret=interpret,
        )(*args)

    return body(a_pack, b_pack, bias_pack)
