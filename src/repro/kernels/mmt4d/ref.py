"""Pure-jnp oracle for the mmt4d Pallas kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    None: lambda x: x,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def mmt4d_ref(a_pack: jnp.ndarray, b_pack: jnp.ndarray,
              bias_pack: Optional[jnp.ndarray] = None, *,
              activation: Optional[str] = None) -> jnp.ndarray:
    """C_pack[m_o,n_o,:,:] = act(sum_k A_pack[m_o,k_o] @ B_pack[n_o,k_o]^T + bias)."""
    out = jnp.einsum("mkab,nkcb->mnac", a_pack, b_pack,
                     preferred_element_type=jnp.float32)
    if bias_pack is not None:
        out = out + bias_pack[None, :, None, :].astype(jnp.float32)
    out = _ACTIVATIONS[activation](out)
    return out.astype(a_pack.dtype)
