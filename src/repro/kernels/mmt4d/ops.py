"""Jitted wrapper for the mmt4d kernel: backend/interpret dispatch + VMEM-aware
block-size selection."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hardware import HardwareSpec, query
from repro.kernels.mmt4d.kernel import mmt4d_kernel_call
from repro.kernels.mmt4d.ref import mmt4d_ref

__all__ = ["mmt4d", "pick_blocks"]


def pick_blocks(m_o: int, n_o: int, m_r: int, n_r: int, k_r: int, itemsize: int,
                hw: Optional[HardwareSpec] = None) -> tuple[int, int]:
    """Choose (TM, TN) so the working set (A blk + B blk + fp32 acc + C blk)
    fits comfortably in VMEM (budget: 1/4 of VMEM to leave room for
    double-buffered pipelining)."""
    hw = hw or query()
    budget = hw.vmem_bytes // 4
    tm, tn = 16, 8
    while tm > 1 or tn > 1:
        a_b = tm * m_r * k_r * itemsize
        b_b = tn * n_r * k_r * itemsize
        acc = tm * m_r * tn * n_r * 4
        c_b = tm * m_r * tn * n_r * itemsize
        if a_b + b_b + acc + c_b <= budget:
            break
        if tn >= tm:
            tn = max(1, tn // 2)
        else:
            tm = max(1, tm // 2)
    return min(tm, m_o), min(tn, n_o)


@functools.partial(jax.jit, static_argnames=("activation", "interpret", "tm", "tn"))
def _jit_call(a_pack, b_pack, bias_pack, *, activation, interpret, tm, tn):
    return mmt4d_kernel_call(a_pack, b_pack, bias_pack, activation=activation,
                             tm=tm, tn=tn, interpret=interpret)


def mmt4d(a_pack: jnp.ndarray, b_pack: jnp.ndarray,
          bias_pack: Optional[jnp.ndarray] = None, *,
          activation: Optional[str] = None,
          interpret: Optional[bool] = None,
          hw: Optional[HardwareSpec] = None) -> jnp.ndarray:
    """Packed matmul on packed operands via the Pallas TPU kernel.

    On non-TPU backends runs in interpret mode (kernel body executed in
    Python) — TPU is the target, CPU validates semantics.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m_o, _, m_r, k_r = a_pack.shape
    n_o, _, n_r, _ = b_pack.shape
    tm, tn = pick_blocks(m_o, n_o, m_r, n_r, k_r, a_pack.dtype.itemsize, hw)
    return _jit_call(a_pack, b_pack, bias_pack, activation=activation,
                     interpret=interpret, tm=tm, tn=tn)


def mmt4d_reference(a_pack, b_pack, bias_pack=None, *, activation=None):
    """Re-export of the oracle for convenience."""
    return mmt4d_ref(a_pack, b_pack, bias_pack, activation=activation)
