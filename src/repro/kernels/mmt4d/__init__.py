from repro.kernels.mmt4d.ops import *  # noqa: F401,F403
