"""Jitted wrapper for the pack kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.pack.kernel import pack_kernel_call
from repro.kernels.pack.ref import pack_ref

__all__ = ["pack"]


@functools.partial(jax.jit, static_argnames=("t0", "t1", "interpret"))
def _jit_call(a, *, t0, t1, interpret):
    return pack_kernel_call(a, t0, t1, interpret=interpret)


def pack(a: jnp.ndarray, t0: int, t1: int, *,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _jit_call(a, t0=t0, t1=t1, interpret=interpret)


def pack_reference(a, t0, t1):
    return pack_ref(a, t0, t1)
