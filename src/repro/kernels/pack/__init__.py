from repro.kernels.pack.ops import *  # noqa: F401,F403
