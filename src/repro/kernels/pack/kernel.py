"""Pallas TPU kernel for the pack layout transformation (paper §4.1/Fig. 1).

Row-major A[M, K] -> A_pack[M_o, K_o, m_r, k_r] with explicit zero padding of
partial tiles (padding semantics, §4.3).  Memory-bound by construction; the
kernel's job is a streaming retile: each grid step reads a (TM*m_r, TK*k_r)
row-major block, masks the out-of-range region, and writes it as a
(TM, TK, m_r, k_r) stack of hardware tiles.

The same kernel packs the RHS (transposed) layout: callers hand it ``B^T``
and tile sizes (n_r, k_r).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pack_kernel_call"]


def _kernel(a_ref, out_ref, *, m: int, k: int, t0: int, t1: int):
    tm, tk, r0, r1 = out_ref.shape
    i = pl.program_id(0)
    j = pl.program_id(1)
    blk = a_ref[...]  # (tm*r0, tk*r1) row-major block (OOB reads unspecified)
    rows = i * (tm * r0) + jax.lax.broadcasted_iota(jnp.int32, blk.shape, 0)
    cols = j * (tk * r1) + jax.lax.broadcasted_iota(jnp.int32, blk.shape, 1)
    mask = (rows < m) & (cols < k)
    blk = jnp.where(mask, blk, jnp.zeros_like(blk))  # explicit tile padding
    out_ref[...] = blk.reshape(tm, r0, tk, r1).transpose(0, 2, 1, 3)


def pack_kernel_call(a: jnp.ndarray, t0: int, t1: int, *, tm: int = 8,
                     tk: int = 8, interpret: bool = False) -> jnp.ndarray:
    """A[M, K] -> A_pack[ceil(M/t0), ceil(K/t1), t0, t1]."""
    m, k = a.shape
    m_o = pl.cdiv(m, t0)
    k_o = pl.cdiv(k, t1)
    tm = min(tm, m_o)
    tk = min(tk, k_o)
    grid = (pl.cdiv(m_o, tm), pl.cdiv(k_o, tk))
    kernel = functools.partial(_kernel, m=m, k=k, t0=t0, t1=t1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm * t0, tk * t1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm, tk, t0, t1), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_o, k_o, t0, t1), a.dtype),
        interpret=interpret,
    )(a)
