"""Pure-jnp oracle for the pack kernel (same math as repro.core.packing)."""

from __future__ import annotations

import jax.numpy as jnp


def pack_ref(a: jnp.ndarray, t0: int, t1: int) -> jnp.ndarray:
    """A[M, K] -> A_pack[ceil(M/t0), ceil(K/t1), t0, t1], zero-padded tiles."""
    m, k = a.shape
    p0 = (-m) % t0
    p1 = (-k) % t1
    a = jnp.pad(a, ((0, p0), (0, p1)))
    mo, ko = a.shape[0] // t0, a.shape[1] // t1
    return a.reshape(mo, t0, ko, t1).transpose(0, 2, 1, 3)
