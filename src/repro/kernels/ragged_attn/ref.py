"""Pure-jnp oracle for the segment-masked ragged paged-attention kernel.

Flat token-level batching (vLLM/Sarathi-style): queries arrive as one
``[W, Hq, dh]`` stream where position ``i`` belongs to engine row
``row_ids[i]`` and sits at absolute sequence position ``q_pos[i]`` of that
row.  Each query gathers its own row's page stream from the pool and
attends causally within its segment (``kv_pos <= q_pos[i]``) — the
segment-aware causal mask that makes one fixed ``[1, W]`` shape serve any
mix of decode / chunked-prefill / speculative-verify rows.

Numerics mirror :func:`repro.models.attention.core_attention` exactly
(fp32 scores and softmax, same contraction order, same ``-1e30`` masking)
so the flat step stays bitwise identical to the dense ``[slots, chunk]``
step on the same tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ragged_attention_ref", "flat_write_destinations"]


def flat_write_destinations(block_tables: np.ndarray, row_ids: np.ndarray,
                            q_pos: np.ndarray, page_tokens: int):
    """Host-side mirror of the flat scatter's addressing rule
    (:func:`repro.models.attention.flat_paged_kv_update`): position ``i``
    of the stream writes page ``block_tables[row_ids[i], q_pos[i] // T]``
    at offset ``q_pos[i] % T``; ``row_ids[i] < 0`` routes to trash page 0.
    Returns ``(pages, offsets, valid)``, each ``[W]``.

    This is the write-side half of the contract the oracle above reads
    back, kept beside it so the two can't drift — the runtime sanitizer
    (``analysis.sanitize``) recomputes every step's destinations through
    this function and asserts each written page is private (``ref == 1``)
    and inside the pool."""
    bt = np.asarray(block_tables)
    row_ids = np.asarray(row_ids)
    q_pos = np.asarray(q_pos)
    valid = row_ids >= 0
    row = np.maximum(row_ids, 0)
    slot = np.minimum(q_pos // page_tokens, bt.shape[1] - 1)
    pages = np.where(valid, bt[row, slot], 0)
    offsets = np.where(valid, q_pos % page_tokens, 0)
    return pages, offsets, valid


def ragged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                         v_pages: jnp.ndarray, *, block_tables: jnp.ndarray,
                         row_ids: jnp.ndarray,
                         q_pos: jnp.ndarray) -> jnp.ndarray:
    """q: [W, Hq, dh]; k_pages/v_pages: [P, T, Hkv, dh] pool (page 0 = trash);
    block_tables: [B, MP]; row_ids: [W] int32 (-1 = padding — clamped to row
    0, output garbage, caller discards); q_pos: [W] absolute positions.
    Returns [W, Hq, dh] in q.dtype."""
    w, hq, dh = q.shape
    hkv = k_pages.shape[2]
    g = hq // hkv
    bt = block_tables[jnp.maximum(row_ids, 0)]                 # [W, MP]
    k_all = k_pages[bt].reshape(w, -1, hkv, dh)                # [W, MP*T, ...]
    v_all = v_pages[bt].reshape(w, -1, hkv, dh)
    qg = q.reshape(w, hkv, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("qhgd,qkhd->qhgk", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) * scale
    kv_pos = jnp.arange(k_all.shape[1])
    neg = jnp.float32(-1e30)
    m = kv_pos[None, :] <= q_pos[:, None]                      # [W, MP*T]
    scores = jnp.where(m[:, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("qhgk,qkhd->qhgd", probs, v_all.astype(jnp.float32))
    return out.reshape(w, hq, dh).astype(q.dtype)
