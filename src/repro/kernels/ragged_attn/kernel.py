"""Pallas TPU kernel for segment-masked ragged paged attention.

One grid step per (flat query position, page): program ``(i, p)`` loads
query ``i``'s row page ``p`` straight from the pool via scalar-prefetched
``block_tables[row_ids[i], p]`` (PrefetchScalarGridSpec — the page id is
known before the body runs, so the K/V block DMA is index-driven, the
paged-attention pattern), applies the segment causal mask
``p*T + t <= q_pos[i]``, and folds the page into an online-softmax
accumulator.  The last page normalises and writes the output row.

The numpy-level oracle is :mod:`repro.kernels.ragged_attn.ref`; this
kernel is flash-style (online softmax) so it matches the oracle to
tolerance, not bitwise — the serving engine dispatches to the oracle off
TPU (see ops.py), where bitwise identity with the dense step is the
contract under test.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only module; absent on CPU-only jaxlib builds
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exercised only off-TPU
    pltpu = None

__all__ = ["ragged_attention_kernel_call"]


def _kernel(row_ids_ref, q_pos_ref, bt_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, t: int, hkv: int, g: int, dh: int):
    i = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)
    qp = q_pos_ref[i]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p * t <= qp)  # pages fully past the query hold nothing visible
    def _fold():
        q = q_ref[0].reshape(hkv, g, dh).astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)                    # [T, Hkv, dh]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("hgd,thd->hgt", q, k,
                       preferred_element_type=jnp.float32) * dh ** -0.5
        kv_pos = p * t + jax.lax.broadcasted_iota(jnp.int32, (1, 1, t), 2)
        s = jnp.where(kv_pos <= qp, s, -jnp.inf)            # [Hkv, g, T]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m_new),
                          jnp.exp(m_prev - m_new), jnp.zeros_like(m_new))
        e = jnp.exp(s - m_new[..., None])
        e = jnp.where(kv_pos <= qp, e, jnp.zeros_like(e))
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * alpha + jnp.sum(e, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jnp.einsum("hgt,thd->hgd", e, v,
                                     preferred_element_type=jnp.float32))

    @pl.when(p == np_ - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], jnp.float32(1e-30))
        out = acc_ref[...] / l[..., None]
        out_ref[...] = out.reshape(1, hkv * g, dh).astype(out_ref.dtype)


def ragged_attention_kernel_call(q: jnp.ndarray, k_pages: jnp.ndarray,
                                 v_pages: jnp.ndarray, *,
                                 block_tables: jnp.ndarray,
                                 row_ids: jnp.ndarray, q_pos: jnp.ndarray,
                                 interpret: bool = False) -> jnp.ndarray:
    """q: [W, Hq, dh]; pages: [P, T, Hkv, dh]; block_tables: [B, MP];
    row_ids/q_pos: [W].  Returns [W, Hq, dh]."""
    w, hq, dh = q.shape
    t, hkv = k_pages.shape[1], k_pages.shape[2]
    g = hq // hkv
    mp = block_tables.shape[1]
    row_ids = jnp.maximum(row_ids.astype(jnp.int32), 0)
    q_pos = q_pos.astype(jnp.int32)

    def page_map(i, p, row_ids_ref, q_pos_ref, bt_ref):
        del q_pos_ref
        return (bt_ref[row_ids_ref[i], p], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(w, mp),
        in_specs=[
            pl.BlockSpec((1, hq, dh), lambda i, p, *_: (i, 0, 0)),
            pl.BlockSpec((1, t, hkv, dh), page_map),
            pl.BlockSpec((1, t, hkv, dh), page_map),
        ],
        out_specs=pl.BlockSpec((1, hq, dh), lambda i, p, *_: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),        # running max
            pltpu.VMEM((hkv, g), jnp.float32),        # running denominator
            pltpu.VMEM((hkv, g, dh), jnp.float32),    # unnormalised context
        ],
    )
    kernel = functools.partial(_kernel, t=t, hkv=hkv, g=g, dh=dh)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w, hq, dh), q.dtype),
        interpret=interpret,
    )(row_ids, q_pos, block_tables, q, k_pages, v_pages)
