from repro.kernels.ragged_attn.ops import *  # noqa: F401,F403
