"""Dispatch wrapper for segment-masked ragged paged attention.

On TPU the Pallas kernel runs; everywhere else the jnp oracle does.  The
oracle is not a fallback of convenience: off-TPU the serving engine's
bitwise flat-vs-dense identity contract is verified against it, so the
dispatch must happen at trace time (``jax.default_backend()``) — the
caller (models/attention.py) is already inside the engine's jit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ragged_attn.ref import ragged_attention_ref

__all__ = ["ragged_attention", "ragged_attention_reference"]


def ragged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                     v_pages: jnp.ndarray, *, block_tables: jnp.ndarray,
                     row_ids: jnp.ndarray, q_pos: jnp.ndarray,
                     use_kernel: Optional[bool] = None,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [W, Hq, dh] flat queries; k_pages/v_pages: [P, T, Hkv, dh] pool;
    block_tables: [B, MP]; row_ids: [W] (-1 = pad); q_pos: [W].
    Returns [W, Hq, dh]."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.ragged_attn.kernel import ragged_attention_kernel_call
        return ragged_attention_kernel_call(
            q, k_pages, v_pages, block_tables=block_tables,
            row_ids=row_ids, q_pos=q_pos, interpret=interpret)
    return ragged_attention_ref(q, k_pages, v_pages,
                                block_tables=block_tables,
                                row_ids=row_ids, q_pos=q_pos)


def ragged_attention_reference(q, k_pages, v_pages, *, block_tables,
                               row_ids, q_pos):
    return ragged_attention_ref(q, k_pages, v_pages,
                                block_tables=block_tables,
                                row_ids=row_ids, q_pos=q_pos)
