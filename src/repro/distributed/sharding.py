"""Sharding rules: DP / TP / EP / FSDP / sequence-sharded KV.

Tile-aligned discipline for packed tensors (DESIGN.md §5): packed weights
``w_pack [N_o, K_o, n_r, k_r]`` are sharded on **outer tile dims only**
(``N_o`` over model, ``K_o`` over data) so no collective ever splits a
hardware tile — the distributed extension of the paper's layout contract.
Unpacked weights shard on the corresponding logical dims; GSPMD padding
handles non-divisible extents (e.g. 28 heads on 16-way TP).

The rule engine maps parameter *paths* to PartitionSpecs:
  - column-parallel (wq/wk/wv/wu/wg, embed, lm_head): out-dim over "model",
    in-dim over "data" when FSDP;
  - row-parallel (wo/wd): in-dim over "model";
  - expert stacks [E, in, out]: E over "model" (expert parallelism), in-dim
    over "data" when FSDP;
  - everything small (norms, biases, scalars): replicated.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import RunConfig
from repro.launch.mesh import dp_axes

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "named", "tree_paths"]


def tree_paths(tree) -> dict:
    """Flatten a pytree into {'a/b/c': leaf}."""
    out = {}

    def rec(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                rec(f"{prefix}/{k}" if prefix else str(k), t[k])
        else:
            out[prefix] = t

    rec("", tree)
    return out


_COL = re.compile(r"(wq|wk|wv|wu|wg|wr|in_proj|x_proj|frontend_proj|"
                  r"vision_proj|lm_head)/w$")
_ROW = re.compile(r"(wo|wd|out_proj|wv)/w$")  # wv matched by _COL first
_EMBED = re.compile(r"embed/e$")


def _spec_for_param(path: str, leaf, run: RunConfig, fsdp_axis) -> P:
    nd = getattr(leaf, "ndim", 0)
    if nd < 2:
        return P()
    # scan-stacked layer groups carry a leading [G] dim: never sharded
    # (it is the lax.scan axis), so rules apply to the remaining dims.
    stacked = "groups/" in path or path.startswith("groups")
    lead: tuple = (None,) if stacked else ()
    nd_eff = nd - len(lead)

    def spec(*rest):
        return P(*lead, *rest)

    if path.endswith("/b") or nd_eff < 2:
        return spec(*(None,) * nd_eff)  # biases / vectors: replicated
    if re.search(r"(wu|wg|wd)/w$", path) and nd_eff == 3:
        # expert stack [E, d_in, d_out]: EP over model + FSDP over data
        return spec("model", fsdp_axis, None)
    if _EMBED.search(path):
        # vocab over data(FSDP) only: GSPMD mis-partitions the token gather
        # against a model-sharded feature dim (SPMD dynamic-slice verifier
        # failure, olmo/chatglm shapes); table is small per-chip, and the
        # tied logits head re-shards compute-side (tp="col").
        return P(fsdp_axis, None)
    if re.search(r"(wo|wd|out_proj)/w$", path):
        return spec("model", fsdp_axis)
    if _COL.search(path):
        return spec(fsdp_axis, "model")
    if re.search(r"router/w$", path):
        return spec(fsdp_axis, None)
    if re.search(r"(pe_enc|pe_dec)$", path):
        return P(None, "model")
    if re.search(r"(w_a|w_b|a_log|conv_w|mu(/.*)?|u|dt_proj/w)$", path):
        return spec(*(None,) * nd_eff)  # small mixer params: replicated
    if nd_eff == 2:
        return spec(fsdp_axis, "model")  # default 2-D weight: col + FSDP
    return spec(*(None,) * nd_eff)


def _sanitize(spec: P, leaf, mesh) -> P:
    """jit argument shardings must divide exactly: drop mesh axes from dims
    they don't divide (e.g. whisper vocab 51865 on a 16-way axis)."""
    shape = getattr(leaf, "shape", ())
    if len(spec) > len(shape):
        return P(*(None,) * len(shape))
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if shape[i] % size == 0 else None)
    out += [None] * (len(shape) - len(spec))
    return P(*out)


def param_specs(params, run: RunConfig, mesh) -> dict:
    """PartitionSpec pytree matching ``params``."""
    fsdp_axis = "data" if run.fsdp and "data" in mesh.axis_names else None
    flat = tree_paths(params)
    specs = {p: _sanitize(_spec_for_param(p, l, run, fsdp_axis), l, mesh)
             for p, l in flat.items()}
    return _unflatten_like(params, specs)


def _unflatten_like(tree, flat_specs: dict, prefix=""):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat_specs,
                                   f"{prefix}/{k}" if prefix else str(k))
                for k, v in tree.items()}
    return flat_specs[prefix]


def batch_specs(batch_like, mesh) -> dict:
    """Inputs: leading batch dim over the DP axes (pod x data)."""
    dp = dp_axes(mesh)

    def spec(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        b = leaf.shape[0]
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if b % dp_size == 0 and b >= dp_size:
            return P(dp, *(None,) * (nd - 1))
        return P(*(None,) * nd)

    return jax.tree.map(spec, batch_like)


def cache_specs(caches, mesh, run: RunConfig, global_batch: int) -> dict:
    """Decode caches: batch over DP when divisible; KV sequence over "model"
    (distributed flash-decode); batch=1 long-context shards the sequence
    over everything available."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_ok = global_batch % dp_size == 0 and global_batch >= dp_size
    bspec = dp if batch_ok else None
    seq_axes = ("model",) if batch_ok else tuple([*dp, "model"])

    flat = tree_paths(caches)

    def spec(path, leaf):
        nd = leaf.ndim
        if path.endswith(("/k_pages", "/v_pages")):
            # paged pool [G, P, T, Hkv, dh]: the page dim is the (chunked)
            # sequence dim — shard it over "model" (distributed flash-decode
            # over page shards); tiles [T, dh] are never split, the
            # distributed extension of the layout contract.
            lead = (None,) * (nd - 4)
            if run.seq_shard_kv:
                return P(*lead, "model", None, None, None)
            return P(*lead, None, None, "model", None)
        if path.endswith("/k") or path.endswith("/v"):
            # [G, B, S, Hkv, dh] (stacked) or [B, S, Hkv, dh]
            lead = (None,) * (nd - 4)
            if run.seq_shard_kv:
                return P(*lead, bspec, seq_axes, None, None)
            return P(*lead, bspec, None, "model", None)
        if path.endswith("ssm"):          # [G, B, di, N]
            return P(*(None,) * (nd - 3), bspec, "model", None)
        if path.endswith("conv"):         # [G, B, W, di]
            return P(*(None,) * (nd - 3), bspec, None, "model")
        if path.endswith("state"):        # [G, B, H, dh, dh]
            return P(*(None,) * (nd - 4), bspec, "model", None, None)
        if path.endswith(("tm_shift", "cm_shift")):  # [G, B, D]
            return P(*(None,) * (nd - 2), bspec, "model")
        return P(*(None,) * nd)

    specs = {p: _sanitize(spec(p, l), l, mesh) for p, l in flat.items()}
    return _unflatten_like(caches, specs)


def state_specs(state_like, run: RunConfig, mesh):
    """TrainState sharding: params & optimizer moments follow param rules;
    8-bit moment *scales* follow their param minus the quantized last axis
    (so the quantized state stays FSDP/TP-sharded exactly like the param)."""
    from repro.training.train_state import TrainState

    p_specs = param_specs(state_like.params, run, mesh)
    is_spec = lambda x: isinstance(x, P)

    def drop_last(spec):
        return P(*tuple(spec)[:-1]) if len(tuple(spec)) else P()

    opt_specs = {}
    for k, tree in state_like.opt_state.items():
        if k in ("m", "v", "err", "m_q", "v_q"):
            opt_specs[k] = p_specs
        elif k in ("m_s", "v_s"):
            opt_specs[k] = jax.tree.map(drop_last, p_specs, is_leaf=is_spec)
        else:
            opt_specs[k] = jax.tree.map(lambda _: P(), tree)
    return TrainState(step=P(), params=p_specs, opt_state=opt_specs)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
