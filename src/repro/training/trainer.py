"""Fault-tolerant training loop.

Posture for 1000+ nodes (exercised here in single-process form, the same
code paths a multi-controller launch would run per host):

  - auto-resume: on start, restore the latest *valid* checkpoint (partial /
    corrupt saves are skipped) and continue bitwise — the data pipeline is a
    pure function of the step counter, so no separate cursor state;
  - atomic checkpoints every ``ckpt_every`` steps + keep-last-k pruning;
  - config fingerprinting: a restored checkpoint must match the model/run
    fingerprint, catching silent config drift across restarts;
  - straggler watchdog: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are logged (on a real cluster this signal
    feeds the coordinator's replace-node decision);
  - elastic restart: meshes are derived from live devices
    (launch.mesh.make_elastic_mesh) and checkpoints are mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.data.pipeline import SyntheticLM
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.optimizer import make_optimizer
from repro.training.step import make_train_step
from repro.training.train_state import TrainState

__all__ = ["Trainer", "fingerprint_of"]


def fingerprint_of(cfg, run: RunConfig) -> str:
    blob = json.dumps({"cfg": dataclasses.asdict(cfg),
                       "run": dataclasses.asdict(run)}, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


class Trainer:
    def __init__(self, model, data: SyntheticLM, run: RunConfig, *,
                 ckpt_dir: Optional[str] = None, total_steps: int = 1000,
                 ckpt_every: int = 50, keep: int = 3,
                 straggler_factor: float = 3.0,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.data = data
        self.run = run
        self.ckpt_dir = ckpt_dir
        self.total_steps = total_steps
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.straggler_factor = straggler_factor
        self.log = log_fn
        self.optimizer = make_optimizer(run, total_steps)
        self.fingerprint = fingerprint_of(model.cfg, run)
        self._step_fn = jax.jit(make_train_step(model, self.optimizer, run),
                                donate_argnums=(0,))
        self.ewma_ms: Optional[float] = None
        self.straggler_events = 0

    # ------------------------------------------------------------------
    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        state = TrainState.create(params, self.optimizer)
        if self.run.grad_compression:
            state.opt_state["err"] = compression.init_error_buffer(params)
        return state

    def restore_or_init(self, key) -> TrainState:
        if self.ckpt_dir is not None and ckpt.latest_step(self.ckpt_dir) is not None:
            tree, extra, step = ckpt.restore(self.ckpt_dir,
                                             fingerprint=self.fingerprint)
            self.log(f"[trainer] resumed from step {step}")
            state = TrainState(step=jnp.asarray(step, jnp.int32),
                               params=tree["params"], opt_state=tree["opt_state"])
            return state
        return self.init_state(key)

    def save(self, state: TrainState) -> None:
        if self.ckpt_dir is None:
            return
        step = int(state.step)
        ckpt.save(self.ckpt_dir, step,
                  {"params": state.params, "opt_state": state.opt_state},
                  extra={"ewma_ms": self.ewma_ms},
                  fingerprint=self.fingerprint)
        ckpt.prune(self.ckpt_dir, keep=self.keep)

    # ------------------------------------------------------------------
    def fit(self, key, steps: Optional[int] = None, fail_at: Optional[int] = None):
        """Run the loop.  ``fail_at`` injects a crash (for restart tests)."""
        state = self.restore_or_init(key)
        start = int(state.step)
        end = steps if steps is not None else self.total_steps
        history = []
        for step in range(start, end):
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = (time.perf_counter() - t0) * 1e3
            if self.ewma_ms is None:
                self.ewma_ms = dt
            else:
                if dt > self.straggler_factor * self.ewma_ms:
                    self.straggler_events += 1
                    self.log(f"[trainer] straggler step {step}: {dt:.0f}ms "
                             f"(ewma {self.ewma_ms:.0f}ms)")
                self.ewma_ms = 0.9 * self.ewma_ms + 0.1 * dt
            history.append(loss)
            if (step + 1) % self.ckpt_every == 0 or step + 1 == end:
                self.save(state)
        return state, history
