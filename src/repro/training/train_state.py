"""Train state pytree."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["TrainState"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: Any
    opt_state: dict

    def tree_flatten(self):
        return (self.step, self.params, self.opt_state), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, params, optimizer) -> "TrainState":
        return cls(step=jnp.zeros((), jnp.int32), params=params,
                   opt_state=optimizer.init(params))
