"""AdamW with warmup+cosine schedule, global-norm clipping, and optional
8-bit (per-row absmax quantized) moment states.

8-bit moments are a distributed-optimization memory trick: m and v stored
int8 with fp32 per-row scales (shape = param.shape[:-1]) cuts optimizer
state from 8 to ~2.03 bytes/param — the difference between arctic-480b
fitting a 256-chip pod or not (EXPERIMENTS.md §Dry-run).  Scales inherit the
param's sharding minus the quantized axis, so the state stays FSDP-sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig

__all__ = ["AdamW", "make_optimizer", "global_norm"]


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (last-axis) absmax int8 quantization.  ndim<2 stays fp32."""
    if x.ndim < 2:
        return x.astype(jnp.float32), jnp.ones(x.shape[:-1] or (), jnp.float32)
    s = jnp.max(jnp.abs(x), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    if q.dtype != jnp.int8:
        return q
    return q.astype(jnp.float32) * s[..., None]


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0
    eightbit: bool = False

    # ------------------------------------------------------------------
    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = self.min_lr_frac + (1 - self.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * cos

    def init(self, params) -> dict:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        if not self.eightbit:
            return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros)}
        mq, ms = _tree_quant(zeros)
        vq, vs = _tree_quant(zeros)
        return {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}

    def update(self, grads, opt_state: dict, params, step: jnp.ndarray):
        """Returns (new_params, new_opt_state, metrics)."""
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9)) \
            if self.grad_clip > 0 else 1.0
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        if self.eightbit:
            m = _tree_dequant(opt_state["m_q"], opt_state["m_s"])
            v = _tree_dequant(opt_state["v_q"], opt_state["v_s"])
        else:
            m, v = opt_state["m"], opt_state["v"]

        def upd(p, g, m_, v_):
            g = g.astype(jnp.float32) * scale
            m_ = b1 * m_ + (1 - b1) * g
            v_ = b2 * v_ + (1 - b2) * g * g
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m_, v_

        out = jax.tree.map(upd, params, grads, m, v)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        if self.eightbit:
            mq, ms = _tree_quant(new_m)
            vq, vs = _tree_quant(new_v)
            new_opt = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        else:
            new_opt = {"m": new_m, "v": new_v}
        return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}


def _tree_quant(tree):
    pairs = jax.tree.map(_quantize, tree)
    q = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return q, s


def _tree_dequant(q, s):
    return jax.tree.map(_dequantize, q, s)


def make_optimizer(run: RunConfig, total_steps: int = 10000) -> AdamW:
    return AdamW(lr=run.lr, warmup_steps=run.warmup_steps,
                 weight_decay=run.weight_decay, grad_clip=run.grad_clip,
                 total_steps=total_steps, eightbit=run.adam_8bit)
