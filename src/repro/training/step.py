"""The jitted train step: loss -> grads (with microbatch accumulation) ->
optional compression -> AdamW update.

Gradient accumulation bounds activation memory at scale (DESIGN.md §5): the
global batch is split into ``run.microbatch`` sequential slices scanned with
fp32 grad accumulation; each slice's backward is remat'd through the layer
scan.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.training import compression
from repro.training.optimizer import AdamW
from repro.training.train_state import TrainState

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(model, optimizer: AdamW, run: RunConfig) -> Callable:
    """Returns train_step(state, batch) -> (state', metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def grads_of(params, batch):
        n = run.microbatch
        if n <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def split(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            g_acc, _ = acc
            (loss, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32) / n,
                                 g_acc, g)
            return (g_acc, metrics), None

        (grads, metrics), _ = jax.lax.scan(
            body, (g0, _zero_metrics(params, batch)), micro)
        return grads, metrics

    def _zero_metrics(params, batch):
        # evaluate metric structure once at zero cost via eval_shape
        shapes = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params, batch)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        grads, metrics = grads_of(state.params, batch)
        opt_state = dict(state.opt_state)
        if run.grad_compression:
            err = opt_state["err"]
            grads, err = compression.compress_with_feedback(grads, err)
            opt_state["err"] = err
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, state.params, state.step)
        if run.grad_compression:
            new_opt["err"] = opt_state["err"]
        metrics = {**metrics, **opt_metrics}
        return TrainState(step=state.step + 1, params=new_params,
                          opt_state=new_opt), metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step
