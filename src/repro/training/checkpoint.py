"""Fault-tolerant checkpointing: atomic, mesh-agnostic, self-validating.

Layout of a checkpoint directory:

    <dir>/step_000123/            (written as .tmp_step_000123, then renamed)
        manifest.json             tree structure, shapes, logical dtypes,
                                  step, config fingerprint, leaf checksums
        arrays.npz                leaves (bf16 stored as uint16 views)

Restore is mesh-agnostic: leaves come back as full np arrays and are
re-sharded by whatever mesh the restarted job derives (elastic restart).
``latest_step`` skips corrupt/partial directories, so a job killed mid-save
resumes from the previous valid checkpoint.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "prune"]

_BF16 = "bfloat16"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
        return out
    out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         fingerprint: str = "") -> str:
    """Atomic save.  ``tree`` is a pytree of arrays (dict-based)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    arrays, meta = {}, {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical == _BF16:
            arr = arr.view(np.uint16)
        key = hashlib.sha1(path.encode()).hexdigest()[:16]
        arrays[key] = arr
        meta[path] = {"key": key, "dtype": logical, "shape": list(arr.shape),
                      "crc": int(np.uint64(arr.view(np.uint8).sum()))}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "leaves": meta, "extra": extra or {},
                "fingerprint": fingerprint, "version": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _valid(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            json.load(f)
        return os.path.exists(os.path.join(path, "arrays.npz"))
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and _valid(os.path.join(ckpt_dir, d)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            fingerprint: str = "") -> Tuple[Any, dict, int]:
    """Returns (tree, extra, step).  Validates checksums and fingerprint."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if fingerprint and manifest["fingerprint"] and \
            manifest["fingerprint"] != fingerprint:
        raise ValueError("checkpoint fingerprint mismatch: "
                         f"{manifest['fingerprint']} != {fingerprint}")
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for leaf_path, m in manifest["leaves"].items():
        arr = npz[m["key"]]
        if int(np.uint64(arr.view(np.uint8).sum())) != m["crc"]:
            raise ValueError(f"checksum mismatch for {leaf_path}")
        if m["dtype"] == _BF16:
            arr = arr.view(jnp.bfloat16)
        flat[leaf_path] = arr
    return _unflatten(flat), manifest["extra"], manifest["step"]


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   for s in [int(d.split("_")[1])])
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
