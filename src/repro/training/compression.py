"""Int8 error-feedback gradient compression.

Synchronous data-parallel gradients under GSPMD are all-reduced by the
compiler; this module implements the *compression transform* with an error
feedback buffer (residual accumulation) so the quantization error is
re-injected next step — the standard trick that keeps convergence intact
(1-bit Adam / EF-SGD lineage).  On real multi-slice hardware this transform
pairs with a shard_map'd int8 all-reduce over the DCN ("pod") axis where
bandwidth is scarcest; the dry-run documents the bytes saved (32->8 bit) in
EXPERIMENTS.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_buffer", "compress_with_feedback"]


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _q8(x):
    s = jnp.max(jnp.abs(x)) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def compress_with_feedback(grads, err):
    """Returns (decompressed_grads, new_err).

    g_hat = Q8(g + err);  new_err = (g + err) - g_hat.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, err)
    g2 = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e2 = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g2, e2
