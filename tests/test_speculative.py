"""Speculative decoding over the fused ragged step.

Contracts covered:
  - spec-on outputs are token-identical to the non-speculative baseline —
    greedy and seeded-sampled — for k in {1, 2, page-straddling}, with the
    n-gram drafter, a draft model, a perfect (oracle) drafter and an
    always-wrong drafter alike (the acceptance rule is lossless, so the
    drafter can only change throughput, never tokens);
  - acceptance stats: an oracle drafter accepts everything, an
    anti-oracle accepts nothing, and the engine's counters say so;
  - KV rollback: rejected draft positions are truncated from the block
    table — whole trailing pages return to the pool, alloc/free stays
    balanced, double-free checks intact (SequencePages.truncate unit);
  - zero new XLA traces after Engine.warmup() with speculation on —
    monolithic and chunked, target and draft model;
  - speculation composes with preemption: a tight pool forces folds and
    the folded prompt only ever contains accepted tokens (a rejected
    draft can never leak into a recompute prompt);
  - constructor validation: hybrids refuse spec (recurrent state cannot
    roll back), a drafter without spec_tokens is rejected, the chunk
    ladder must cover the verify width.
"""

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedKVPool, SequencePages
from repro.serving.scheduler import Request, Scheduler
from repro.serving.speculative import (Drafter, DraftModelDrafter,
                                       NgramDrafter, accept_tokens,
                                       request_context)

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain(eng, reqs, **kw):
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain(**kw)}
    assert sorted(fin) == sorted(rids)
    return [fin[rid] for rid in rids]


REQS = ([5, 11, 8, 3], [16, 12, 20, 14])


@pytest.fixture(scope="module")
def baseline(smollm):
    """Non-speculative reference outputs, greedy and sampled."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    eng = Engine(m, params, max_slots=3)
    greedy = [r.out_tokens for r in _drain(eng, reqs)]
    eng = Engine(m, params, max_slots=3)
    sampled = [r.out_tokens for r in _drain(eng, reqs, greedy=False, seed=7)]
    return reqs, greedy, sampled


class OracleDrafter(Drafter):
    """Proposes the baseline's own continuation: 100% acceptance.  With
    ``offset`` it proposes baseline+offset instead: 0% acceptance.  Either
    way the outputs must not move — the strongest possible statement of
    the lossless-acceptance contract."""

    def __init__(self, outs, offset=0, vocab=512):
        self.outs = outs             # rid -> full baseline out_tokens
        self.offset = offset
        self.vocab = vocab

    def propose(self, req, k):
        done = len(req.out_tokens)
        nxt = self.outs[req.rid][done:done + k]
        return [(t + self.offset) % self.vocab for t in nxt]


# ---------------------------------------------------------------------------
# token identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 5])
def test_spec_greedy_matches_baseline(smollm, baseline, k):
    """k=1: minimal verify width; k=2: partial accepts; k=5: with 8-token
    pages and full oracle acceptance a verify step writes 6 positions, so
    steps straddle page boundaries — growth books multi-page asks and
    rollback crosses pages."""
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    eng = Engine(m, params, max_slots=3, page_tokens=8, spec_tokens=k,
                 drafter=OracleDrafter(dict(enumerate(greedy))))
    got = _drain(eng, reqs)
    assert [r.out_tokens for r in got] == greedy
    st = eng.stats()["speculative"]
    assert st["acceptance_rate"] == 1.0
    assert st["decode_tokens_per_row_step"] > 1.0
    assert eng.pool.num_used == 0


def test_spec_ngram_matches_baseline_greedy_and_sampled(smollm, baseline):
    """The shipped prompt-lookup drafter: partial, input-dependent
    acceptance — tokens still identical, greedy and sampled (the sampled
    acceptance rule recomputes the (seed, rid, position)-keyed picks)."""
    cfg, m, params = smollm
    reqs, greedy, sampled = baseline
    eng = Engine(m, params, max_slots=3, spec_tokens=2)
    assert [r.out_tokens for r in _drain(eng, reqs)] == greedy
    # greedy toy decodes loop, so self-ngram lookup must land some drafts
    assert eng.stats()["speculative"]["accepted"] > 0
    eng = Engine(m, params, max_slots=3, spec_tokens=2)
    assert [r.out_tokens for r in
            _drain(eng, reqs, greedy=False, seed=7)] == sampled


def test_spec_draft_model_matches_baseline(smollm, baseline):
    """A 1-layer draft model sharing the target's vocab: acceptance is
    whatever the small model earns (possibly none — its weights are
    unrelated), outputs must be bit-identical regardless, and the draft
    model's dense cache must survive reconcile/rollback across steps."""
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    dcfg = reduced_config(get_config("smollm2-135m"), layers=1)
    dm = build_model(dcfg, RUN, ShapeSpec("serve", 64, 3, "decode"))
    dparams = dm.init(jax.random.PRNGKey(3))
    eng = Engine(m, params, max_slots=3, spec_tokens=2,
                 drafter=DraftModelDrafter(dm, dparams))
    assert [r.out_tokens for r in _drain(eng, reqs)] == greedy
    st = eng.stats()["speculative"]
    assert st["drafter"]["drafter"] == "draft-model"
    assert st["drafter"]["live_states"] == 0      # forget() on finish
    assert st["drafted"] > 0


def test_spec_chunked_matches_baseline(smollm, baseline):
    """Speculation through the fused chunked step: verify widths ride the
    same shape ladder as prefill chunks."""
    cfg, m, params = smollm
    reqs, greedy, sampled = baseline
    eng = Engine(m, params, max_slots=3, chunk_tokens=8, spec_tokens=2)
    assert [r.out_tokens for r in _drain(eng, reqs)] == greedy
    eng = Engine(m, params, max_slots=3, chunk_tokens=8, spec_tokens=2)
    assert [r.out_tokens for r in
            _drain(eng, reqs, greedy=False, seed=7)] == sampled


# ---------------------------------------------------------------------------
# acceptance accounting + rollback
# ---------------------------------------------------------------------------

def test_rejected_drafts_roll_back_pages(smollm, baseline):
    """An anti-oracle (every draft wrong): every verify step writes k
    rejected positions that must be rolled back.  Outputs unchanged,
    acceptance 0, truncation frees real pages, and the pool balances."""
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    eng = Engine(m, params, max_slots=3, page_tokens=8, spec_tokens=5,
                 drafter=OracleDrafter(dict(enumerate(greedy)), offset=1,
                                       vocab=cfg.vocab))
    got = _drain(eng, reqs)
    assert [r.out_tokens for r in got] == greedy
    st = eng.stats()["speculative"]
    assert st["drafted"] > 0 and st["accepted"] == 0
    assert st["acceptance_rate"] == 0.0
    assert st["decode_tokens_per_row_step"] == 1.0
    assert st["rollback_pages"] > 0, \
        "6-wide verify rows against 8-token pages must straddle a page " \
        "boundary sometimes — rejection should return whole pages"
    assert eng.pool.num_used == 0
    assert eng.pool.total_allocs == eng.pool.total_frees


def test_speculative_grow_sheds_instead_of_preempting():
    """A speculative page ask must never be what forces a displacement:
    when granting an older row's k+1 ask would consume the page a younger
    row's mandatory one-token growth needs this step, the ask is shed
    (counted) and the younger row grows exactly as it would under plain
    decode — zero preemptions."""
    pool = PagedKVPool(1 + 5, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=64)
    a = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=30)
    b = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=30)
    sched.add(a)
    sched.add(b)
    assert len(sched.admit()) == 2           # one prompt page each
    a.len, a.out_tokens = 14, [1] * 7
    b.len, b.out_tokens = 16, [2] * 9
    a.pages.ensure(16)                       # 2 pages each: one page left
    b.pages.ensure(16)
    assert pool.num_free == 1
    # a (older) asks for 3 positions -> len 17 -> a 3rd page; b's mandatory
    # ensure(17) needs that same last page
    displaced = sched.grow(want={a.slot: 3, b.slot: 1})
    assert displaced == [] and sched.num_preemptions == 0
    assert sched.spec_grow_fallbacks == 1
    assert a.pages.capacity == 16            # ask shed: no page taken
    assert b.pages.capacity == 24            # mandatory growth got the page
    # with room for everyone, the same ask is granted
    pool2 = PagedKVPool(1 + 6, 8)
    sched2 = Scheduler(max_slots=2, pool=pool2, max_len=64)
    c = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=30)
    d = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=30)
    sched2.add(c)
    sched2.add(d)
    sched2.admit()
    c.len, c.out_tokens = 14, [1] * 7
    d.len, d.out_tokens = 16, [2] * 9
    c.pages.ensure(16)
    d.pages.ensure(16)
    assert sched2.grow(want={c.slot: 3, d.slot: 1}) == []
    assert c.pages.capacity == 24 and d.pages.capacity == 24
    assert sched2.spec_grow_fallbacks == 0

    # an ask covered by the row's own last-page slack needs no free pages
    # and must not be counted as shed, however tight the pool
    pool3 = PagedKVPool(1 + 5, 8)
    sched3 = Scheduler(max_slots=2, pool=pool3, max_len=64)
    e = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=30)
    f = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=30)
    sched3.add(e)
    sched3.add(f)
    sched3.admit()
    e.len, e.out_tokens = 9, [1] * 2
    f.len, f.out_tokens = 16, [2] * 9
    e.pages.ensure(16)                       # slack covers len 9 + 3
    f.pages.ensure(16)
    assert sched3.grow(want={e.slot: 3, f.slot: 1}) == []
    assert sched3.spec_grow_fallbacks == 0 and sched3.num_preemptions == 0
    assert e.pages.capacity == 16 and f.pages.capacity == 24


def test_sequence_pages_truncate_unit():
    pool = PagedKVPool(1 + 6, 8)
    seq = SequencePages(pool)
    seq.ensure(20)                       # 3 pages
    assert len(seq.pages) == 3 and pool.num_used == 3
    assert seq.truncate(17) == 0         # 17 tokens still need 3 pages
    assert seq.truncate(9) == 1          # drop to 2 pages
    assert len(seq.pages) == 2 and pool.num_used == 2
    assert seq.truncate(0) == 2          # full rollback
    assert pool.num_used == 0
    assert pool.total_allocs == pool.total_frees
    # the freed pages are genuinely reusable (no double-free later)
    seq.ensure(48)
    seq.release()
    assert pool.num_used == 0


def test_accept_tokens_rule_unit():
    """The acceptance rule in isolation: accept while the pick equals the
    draft, emit the pick at the first mismatch, bonus pick after a full
    accept, stop at eos exactly where the baseline would."""
    def pick_argmax(row, req):
        return int(np.argmax(row))

    def logits(*winners, vocab=8):
        out = np.zeros((len(winners), vocab), np.float32)
        for i, w in enumerate(winners):
            out[i, w] = 1.0
        return out

    r = Request(rid=0, prompt=np.zeros(2, np.int32), max_new=10)
    # picks: 3, 5, 6; drafts [3, 5] — full accept + bonus
    appended, accepted = accept_tokens(r, [3, 5], logits(3, 5, 6), 3,
                                       pick_argmax)
    assert (appended, accepted) == (3, 2) and r.out_tokens == [3, 5, 6]
    # picks: 2, 7, ...; drafts [2, 4] — mismatch at j=1: 7 is the correction
    r2 = Request(rid=1, prompt=np.zeros(2, np.int32), max_new=10)
    appended, accepted = accept_tokens(r2, [2, 4], logits(2, 7, 6), 3,
                                       pick_argmax)
    assert (appended, accepted) == (2, 1) and r2.out_tokens == [2, 7]
    # eos mid-accept: stop immediately even though drafts keep matching
    r3 = Request(rid=2, prompt=np.zeros(2, np.int32), max_new=10, eos_id=5)
    appended, accepted = accept_tokens(r3, [3, 5], logits(3, 5, 6), 3,
                                       pick_argmax)
    assert (appended, accepted) == (2, 2) and r3.out_tokens == [3, 5]
    assert r3.finish_reason == "eos"
    # n_eff == 1 degenerates to plain decode
    r4 = Request(rid=3, prompt=np.zeros(2, np.int32), max_new=10)
    assert accept_tokens(r4, [], logits(4), 1, pick_argmax) == (1, 0)
    assert r4.out_tokens == [4]


def test_ngram_drafter_unit():
    d = NgramDrafter(max_ngram=3)
    r = Request(rid=0, prompt=np.asarray([1, 2, 3, 9, 1, 2, 3], np.int32),
                max_new=8)
    r.out_tokens = []
    # trailing [1,2,3] recurs at the start; the continuation there was 9
    assert d.propose(r, 2) == [9, 1]
    # most recent match wins: trailing [7] matches the later 7
    r2 = Request(rid=1, prompt=np.asarray([7, 4, 7, 5, 7], np.int32),
                 max_new=8)
    assert d.propose(r2, 2) == [5, 7]
    # generated tokens are part of the lookup context
    r3 = Request(rid=2, prompt=np.asarray([3, 4], np.int32), max_new=8)
    r3.out_tokens = [5, 3, 4]
    assert d.propose(r3, 3) == [5, 3, 4]
    # no repeat anywhere -> silence, and the stats notice
    r4 = Request(rid=3, prompt=np.asarray([1, 2, 3, 4, 5], np.int32),
                 max_new=8)
    assert d.propose(r4, 2) == []
    assert d.stats()["misses"] == 1 and d.stats()["proposals"] == 3


def test_request_context_is_fold_invariant():
    """Preemption COPIES out_tokens[:folded] into the prompt and keeps
    out_tokens whole (that is what kv_budget and re-folds rely on), so the
    drafters' context helper must skip the folded prefix — concatenating
    the full out_tokens would duplicate it, mis-aiming ngram lookups and
    feeding a draft model a corrupted (and over-long) stream."""
    r = Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32), max_new=10)
    r.out_tokens = [7, 8, 9]
    assert request_context(r).tolist() == [1, 2, 3, 7, 8, 9]
    # after a fold of the first two generated tokens
    r.prompt = np.asarray([1, 2, 3, 7, 8], np.int32)
    r.folded = 2
    assert request_context(r).tolist() == [1, 2, 3, 7, 8, 9]
    # the ngram drafter sees the true stream, not a duplicated seam: on
    # the true [1,2,9,1,2] the trailing [1,2] recurs at 0 followed by 9;
    # the buggy doubled stream [1,2,9,1,2,1,2] would match the phantom
    # copy at 3 instead and propose [1,2]
    d = NgramDrafter(max_ngram=3)
    rf = Request(rid=1, prompt=np.asarray([1, 2, 9, 1, 2], np.int32),
                 max_new=10)
    rf.out_tokens = [1, 2]
    rf.folded = 2                 # prompt tail [1, 2] is the fold copy
    assert d.propose(rf, 2) == [9, 1]


# ---------------------------------------------------------------------------
# warmup / no-recompile, preemption, validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [None, 8])
def test_no_compiles_after_warmup_with_spec(smollm, chunk):
    """Zero-recompile contract with speculation on: warmup covers the
    verify shapes (and the drafter's), then a trace with admissions,
    drafted/undrafted steps, growth and displacement compiles nothing."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6,
                 chunk_tokens=chunk, spec_tokens=2)
    eng.warmup()
    assert eng.pool.num_used == 0 and eng.pool.total_allocs == 0
    before = dict(m.trace_counts)
    reqs = list(zip(_prompts(cfg, [4, 25, 6, 30], seed=3), [16, 10, 16, 8]))
    fin = _drain(eng, reqs)
    assert eng.num_preemptions + eng.num_pauses >= 1
    assert sum(len(r.out_tokens) for r in fin) == 16 + 10 + 16 + 8
    assert dict(m.trace_counts) == before, \
        "speculative Engine.step compiled a new shape after warmup()"


def test_spec_preemption_never_folds_rejected_tokens(smollm):
    """Speculation under page pressure: outputs identical to the ample
    non-spec baseline through preemptions, and every folded prompt is
    original prompt + an accepted-output prefix — a rejected draft can
    never reach a recompute prompt because out_tokens never holds one."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6, 5])
    news = [12, 12]
    ample = Engine(m, params, max_slots=2, page_tokens=8)
    rids = [ample.add_request(p, n) for p, n in zip(prompts, news)]
    want = {r.rid: r.out_tokens for r in ample.drain()}

    for greedy in (True, False):
        w = want
        if not greedy:
            b = Engine(m, params, max_slots=2, page_tokens=8)
            for p, n in zip(prompts, news):
                b.add_request(p, n)
            w = {r.rid: r.out_tokens for r in b.drain(greedy=False, seed=5)}
        tight = Engine(m, params, max_slots=2, page_tokens=8,
                       num_pages=1 + 4, spec_tokens=2)
        for p, n in zip(prompts, news):
            tight.add_request(p, n)
        fin = {r.rid: r for r in tight.drain(greedy=greedy, seed=5)}
        assert {rid: r.out_tokens for rid, r in fin.items()} == w
        assert tight.num_preemptions >= 1
        assert tight.pool.num_used == 0
        assert tight.pool.total_allocs == tight.pool.total_frees
        for rid, r in fin.items():
            orig = prompts[rid].tolist()
            folded = r.prompt.tolist()
            assert folded[:len(orig)] == orig
            assert folded[len(orig):] == w[rid][:len(folded) - len(orig)]


def test_spec_constructor_validation(smollm):
    cfg, m, params = smollm
    with pytest.raises(AssertionError, match="at least one draft"):
        Engine(m, params, spec_tokens=0)
    with pytest.raises(AssertionError, match="drafter needs spec_tokens"):
        Engine(m, params, drafter=NgramDrafter())
    with pytest.raises(AssertionError, match="shape ladder"):
        Engine(m, params, chunk_tokens=8, spec_tokens=8)
    with pytest.raises(AssertionError, match="vocab"):
        import dataclasses
        odd = dataclasses.replace(cfg, vocab=cfg.vocab * 2, name="odd-vocab")
        om = build_model(odd, RUN, ShapeSpec("serve", 64, 2, "decode"))
        Engine(m, params, spec_tokens=2,
               drafter=DraftModelDrafter(om, om.init(jax.random.PRNGKey(0))))


def test_draft_model_reconcile_when_speculation_covered_context(smollm):
    """Shed-draft regression: the engine may trim away a proposal (page
    pressure / same-step preemption) and then commit the very token the
    drafter speculated.  The drafter's cache then already covers the whole
    context at the next propose — it must re-derive the last position's
    logits (identical KV overwrite) instead of crashing with nothing to
    draft from, and keep proposing the same chain it would have fresh."""
    cfg, m, params = smollm
    dcfg = reduced_config(get_config("smollm2-135m"), layers=1)
    dm = build_model(dcfg, RUN, ShapeSpec("serve", 64, 3, "decode"))
    d = DraftModelDrafter(dm, dm.init(jax.random.PRNGKey(3)))
    r = Request(rid=0, prompt=np.asarray([5, 9, 2, 7], np.int32), max_new=10)
    r.out_tokens = [3]
    first = d.propose(r, 2)
    assert len(first) == 2
    # the engine sheds the draft but its own pick matches the speculation:
    # context grows by exactly the token the drafter already wrote KV for
    r.out_tokens.append(first[0])
    second = d.propose(r, 2)
    fresh = DraftModelDrafter(dm, dm.init(jax.random.PRNGKey(3)))
    assert second == fresh.propose(r, 2), \
        "reconciled propose must equal a from-scratch propose"


def test_hybrid_families_refuse_spec():
    cfg = reduced_config(get_config("rwkv6-1.6b"))
    m = build_model(cfg, RUN, ShapeSpec("serve", 64, 2, "decode"))
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="speculative decode"):
        Engine(m, params, spec_tokens=2)
    with pytest.raises(AssertionError, match="pure-attention draft"):
        DraftModelDrafter(m, params)
