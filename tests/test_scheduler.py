"""Continuous-batching scheduler + paged KV pool invariants.

Covers the serving subsystem's contracts:
  - page size is always a multiple of the active layout's ``m_r``;
  - page allocation/free is balanced after eviction (no leaks);
  - ragged arrivals produce identical per-request tokens as serving each
    request alone;
  - greedy decode is deterministic under reordered admission;
  - admission waits (FCFS) when slots or pages are exhausted and resumes
    after eviction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core.hardware import presets
from repro.core.layout import make_layout
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import OutOfPages, PagedKVPool, SequencePages
from repro.serving.scheduler import Request, Scheduler

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


@pytest.fixture(scope="module")
def singles(smollm):
    """Reference outputs: each request served entirely alone."""
    cfg, m, params = smollm
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    prompts = _prompts(cfg, lens)
    eng = Engine(m, params, max_slots=3)
    outs = []
    for p, n in zip(prompts, news):
        eng.add_request(p, n)
        outs.append(eng.drain()[0].out_tokens)
    return prompts, news, outs


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------

def test_page_size_is_layout_tile_multiple():
    """The layout contract: pages hold whole microkernel M-tiles, for every
    policy / hardware VL / dtype."""
    for policy in ("scalable", "fixed", "unpacked"):
        for hw in ("tpu_v5e", "tpu_vl256", "tpu_vl512"):
            for dt in (jnp.float32, jnp.bfloat16, jnp.int8):
                lay = make_layout(policy, presets[hw], dt)
                for req in (1, 7, 16, 33):
                    pool = PagedKVPool(4, req, lay)
                    assert pool.page_tokens % lay.m_r == 0
                    assert pool.page_tokens >= req


def test_pool_alloc_free_balance():
    pool = PagedKVPool(9, 8)         # 8 usable pages (page 0 = trash)
    assert pool.num_free == 8 and pool.num_used == 0
    seqs = [SequencePages(pool) for _ in range(3)]
    for s, tokens in zip(seqs, (5, 17, 24)):
        s.ensure(tokens)
    assert [len(s.pages) for s in seqs] == [1, 3, 3]
    assert pool.num_used == 7
    assert 0 not in {p for s in seqs for p in s.pages}  # trash page never given
    seqs[1].release()
    assert pool.num_used == 4 and pool.num_free == 4
    with pytest.raises(OutOfPages):
        SequencePages(pool).ensure(8 * 8)               # 8 pages > 4 free
    for s in seqs:
        s.release()
    assert pool.num_used == 0 and pool.num_free == 8


def test_engine_page_size_multiple_of_m_r(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, page_tokens=3)   # deliberately unaligned request
    lay = m.ctx.layout(m.compute_dtype)
    assert eng.pool.page_tokens % lay.m_r == 0


# ---------------------------------------------------------------------------
# scheduler admission / eviction
# ---------------------------------------------------------------------------

def test_admission_waits_for_slots_and_pages():
    pool = PagedKVPool(1 + 6, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=48)

    def req(rid, plen, max_new):
        return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                       max_new=max_new)

    for r in (req(0, 8, 9), req(1, 8, 9), req(2, 8, 9)):
        sched.add(r)
    first = sched.admit()
    assert [r.rid for r in first] == [0, 1]      # slots exhausted; FCFS
    assert sched.admit() == []
    assert pool.num_used == 4                    # 2 pages reserved per request
    sched.finish(first[0])
    assert pool.num_used == 2
    nxt = sched.admit()
    assert [r.rid for r in nxt] == [2]           # eviction frees the slot

    # pool-bound: a huge request blocks even though a slot is free
    sched.add(req(3, 8, 41))                     # needs 6 pages, 2 free
    assert sched.admit() == []
    sched.finish(first[1])
    sched.finish(nxt[0])
    assert [r.rid for r in sched.admit()] == [3]
    assert sched.num_free_slots == 1


def test_request_budget_checked_against_max_len():
    pool = PagedKVPool(8, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=16)
    with pytest.raises(AssertionError):
        sched.add(Request(rid=0, prompt=np.zeros(10, np.int32), max_new=10))


# ---------------------------------------------------------------------------
# end-to-end: ragged arrivals, determinism, balance after eviction
# ---------------------------------------------------------------------------

def test_ragged_arrivals_match_single_request(smollm, singles):
    cfg, m, params = smollm
    prompts, news, want = singles

    eng2 = Engine(m, params, max_slots=3)    # 4 requests contend for 3 slots
    rids = [eng2.add_request(p, n) for p, n in zip(prompts, news)]
    fin = {r.rid: r.out_tokens for r in eng2.drain()}
    for rid, w in zip(rids, want):
        assert fin[rid] == w
    # balanced after eviction: every page and slot returned
    assert eng2.pool.num_used == 0
    assert eng2.scheduler.num_free_slots == 3


def test_greedy_deterministic_under_reordered_admission(smollm, singles):
    cfg, m, params = smollm
    prompts, news, want = singles

    eng = Engine(m, params, max_slots=2)     # different slot count, too
    order = [3, 1, 0, 2]
    rids = {i: eng.add_request(prompts[i], news[i]) for i in order}
    fin = {r.rid: r.out_tokens for r in eng.drain()}
    for i in order:
        assert fin[rids[i]] == want[i]  # batch composition is irrelevant


def test_step_interleaves_admission_and_decode(smollm):
    """A slot freed by eviction is re-used at the very next admission phase
    (continuous, not batch-synchronous), and arrival times gate admission."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [4, 4, 4])
    eng = Engine(m, params, max_slots=1)
    eng.add_request(prompts[0], 2, arrival=0.0)
    eng.add_request(prompts[1], 2, arrival=0.0)
    eng.add_request(prompts[2], 2, arrival=99.0)

    fin = eng.step(now=0.0)          # r0 prefill (tok 1) + decode (tok 2)
    assert [r.rid for r in fin] == [0]
    fin = eng.step(now=1.0)          # r1 takes r0's slot immediately
    assert [r.rid for r in fin] == [1]
    assert eng.step(now=50.0) == [] # r2 hasn't arrived yet
    assert not eng.scheduler.running
    fin = eng.step(now=99.0)
    assert [r.rid for r in fin] == [2]
    assert not eng.scheduler.has_work


def test_eos_finishes_early(smollm):
    cfg, m, params = smollm
    [p] = _prompts(cfg, [6])
    eng = Engine(m, params, max_slots=2)
    eng.add_request(p, 8)
    want = eng.drain()[0].out_tokens
    eos = want[2]
    eng.add_request(p, 8, eos_id=eos)
    got = eng.drain()[0]
    assert got.out_tokens == want[:3]
    assert got.finish_reason == "eos"
    assert eng.pool.num_used == 0
