"""Continuous-batching scheduler + paged KV pool invariants.

Covers the serving subsystem's contracts:
  - page size is always a multiple of the active layout's ``m_r``;
  - page allocation/free is balanced after eviction (no leaks), and
    double-frees / frees of never-allocated pages fail loudly;
  - ragged arrivals produce identical per-request tokens as serving each
    request alone;
  - greedy decode is deterministic under reordered admission;
  - admission waits (FCFS) when slots or pages are exhausted and resumes
    after eviction; out-of-order adds are inserted in arrival order;
  - lazy admission reserves prompt-only pages; growth preempts the
    youngest on exhaustion, and the preempted-and-recomputed output equals
    the uninterrupted one token for token;
  - a drain under sustained OutOfPages pressure terminates with every
    request complete and the pool balanced.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core.hardware import presets
from repro.core.layout import make_layout
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import (OutOfPages, PagedKVPool, PoolError,
                                    SequencePages)
from repro.serving.scheduler import AdmissionError, Request, Scheduler

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


@pytest.fixture(scope="module")
def singles(smollm):
    """Reference outputs: each request served entirely alone."""
    cfg, m, params = smollm
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    prompts = _prompts(cfg, lens)
    eng = Engine(m, params, max_slots=3)
    outs = []
    for p, n in zip(prompts, news):
        eng.add_request(p, n)
        outs.append(eng.drain()[0].out_tokens)
    return prompts, news, outs


# ---------------------------------------------------------------------------
# pool
# ---------------------------------------------------------------------------

def test_page_size_is_layout_tile_multiple():
    """The layout contract: pages hold whole microkernel M-tiles, for every
    policy / hardware VL / dtype."""
    for policy in ("scalable", "fixed", "unpacked"):
        for hw in ("tpu_v5e", "tpu_vl256", "tpu_vl512"):
            for dt in (jnp.float32, jnp.bfloat16, jnp.int8):
                lay = make_layout(policy, presets[hw], dt)
                for req in (1, 7, 16, 33):
                    pool = PagedKVPool(4, req, lay)
                    assert pool.page_tokens % lay.m_r == 0
                    assert pool.page_tokens >= req


def test_pool_alloc_free_balance():
    pool = PagedKVPool(9, 8)         # 8 usable pages (page 0 = trash)
    assert pool.num_free == 8 and pool.num_used == 0
    seqs = [SequencePages(pool) for _ in range(3)]
    for s, tokens in zip(seqs, (5, 17, 24)):
        s.ensure(tokens)
    assert [len(s.pages) for s in seqs] == [1, 3, 3]
    assert pool.num_used == 7
    assert 0 not in {p for s in seqs for p in s.pages}  # trash page never given
    seqs[1].release()
    assert pool.num_used == 4 and pool.num_free == 4
    with pytest.raises(OutOfPages):
        SequencePages(pool).ensure(8 * 8)               # 8 pages > 4 free
    for s in seqs:
        s.release()
    assert pool.num_used == 0 and pool.num_free == 8


def test_engine_page_size_multiple_of_m_r(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, page_tokens=3)   # deliberately unaligned request
    lay = m.ctx.layout(m.compute_dtype)
    assert eng.pool.page_tokens % lay.m_r == 0


def test_double_free_and_foreign_free_detected():
    """A page freed twice would be handed to two requests and silently
    cross their KV streams — the allocator must refuse at the free."""
    pool = PagedKVPool(4, 8)
    p = pool.alloc()
    pool.free([p])
    with pytest.raises(PoolError):
        pool.free([p])                       # double-free
    with pytest.raises(PoolError):
        pool.free([3])                       # never allocated
    with pytest.raises(PoolError):
        pool.free([0])                       # the trash page is never owned
    # a request's rollback path (ensure failure) must not double-free either
    seq = SequencePages(pool)
    seq.ensure(3 * 8)
    with pytest.raises(OutOfPages):
        SequencePages(pool).ensure(8)
    seq.release()
    assert pool.num_free == 3 and pool.total_allocs == pool.total_frees


# ---------------------------------------------------------------------------
# scheduler admission / eviction
# ---------------------------------------------------------------------------

def _req(rid, plen, max_new, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new=max_new, arrival=arrival)


def test_admission_waits_for_slots_and_pages():
    """Eager (PR-1 baseline) policy: full-lifetime reservation at admit."""
    pool = PagedKVPool(1 + 6, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=48, eager=True)
    req = _req

    for r in (req(0, 8, 9), req(1, 8, 9), req(2, 8, 9)):
        sched.add(r)
    first = sched.admit()
    assert [r.rid for r in first] == [0, 1]      # slots exhausted; FCFS
    assert sched.admit() == []
    assert pool.num_used == 4                    # 2 pages reserved per request
    sched.finish(first[0])
    assert pool.num_used == 2
    nxt = sched.admit()
    assert [r.rid for r in nxt] == [2]           # eviction frees the slot

    # pool-bound: a huge request blocks even though a slot is free
    sched.add(req(3, 8, 41))                     # needs 6 pages, 2 free
    assert sched.admit() == []
    sched.finish(first[1])
    sched.finish(nxt[0])
    assert [r.rid for r in sched.admit()] == [3]
    assert sched.num_free_slots == 1


def test_request_budget_checked_against_max_len():
    pool = PagedKVPool(8, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=16)
    with pytest.raises(AdmissionError):
        sched.add(Request(rid=0, prompt=np.zeros(10, np.int32), max_new=10))


def test_request_budget_checked_against_pool_capacity():
    """A request whose lifetime can never fit the pool even alone would
    deadlock the preemption loop — add() must reject it."""
    pool = PagedKVPool(1 + 2, 8)                 # 2 usable pages = 16 tokens
    sched = Scheduler(max_slots=2, pool=pool, max_len=48)
    with pytest.raises(AdmissionError):
        sched.add(_req(0, 8, 17))                # budget 24 > 16
    sched.add(_req(1, 8, 9))                     # budget 16 fits exactly


def test_add_inserts_in_arrival_order():
    """Out-of-order adds must not stall trace replay behind a
    not-yet-arrived head; preempted requests stay at the front."""
    pool = PagedKVPool(1 + 8, 8)
    sched = Scheduler(max_slots=1, pool=pool, max_len=48)
    sched.add(_req(0, 4, 4, arrival=10.0))
    sched.add(_req(1, 4, 4, arrival=1.0))        # added late, arrives early
    sched.add(_req(2, 4, 4, arrival=5.0))
    assert [r.rid for r in sched.waiting] == [1, 2, 0]
    assert [r.rid for r in sched.admit(now=1.0)] == [1]  # head not rid 0
    # a preempted request outranks every arrival, however early
    sched.waiting[0].preempted = True            # rid 2 pretends preempted
    sched.add(_req(3, 4, 4, arrival=0.0))
    assert [r.rid for r in sched.waiting] == [2, 3, 0]


def test_lazy_admission_reserves_prompt_only():
    """Lazy admission books pages for the prompt, not the lifetime: two
    long-budget requests coexist where eager reservation admits one."""
    pool = PagedKVPool(1 + 4, 8)                 # 4 usable pages
    sched = Scheduler(max_slots=2, pool=pool, max_len=48)
    for r in (_req(0, 8, 17), _req(1, 8, 17)):   # eager: 3 pages each
        sched.add(r)
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]
    assert pool.num_used == 2                    # one prompt page each

    eager_pool = PagedKVPool(1 + 4, 8)
    eager = Scheduler(max_slots=2, pool=eager_pool, max_len=48, eager=True)
    for r in (_req(0, 8, 17), _req(1, 8, 17)):
        eager.add(r)
    assert [r.rid for r in eager.admit()] == [0]  # 3 + 3 pages don't fit


def test_growth_preempts_youngest_and_recomputation_state():
    pool = PagedKVPool(1 + 4, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=48)
    r0, r1 = _req(0, 8, 17), _req(1, 8, 17)
    sched.add(r0)
    sched.add(r1)
    assert len(sched.admit()) == 2

    # simulate the engine: prefill done, decode steps grow one token each
    for r in (r0, r1):
        r.len = r.prompt_len
        r.out_tokens.append(100 + r.rid)
    assert sched.grow() == []                    # len 9 fits page 2
    assert pool.num_used == 4
    for r in (r0, r1):
        r.len = 16
        r.out_tokens.extend([200 + r.rid, 300 + r.rid])
    preempted = sched.grow()                     # r0 needs page 3; pool dry
    assert preempted == [r1]                     # youngest admit_seq evicted
    assert sched.num_preemptions == 1 and r1.num_preemptions == 1
    assert r1.status == "waiting" and r1.preempted and r1.slot == -1
    assert r1.len == 0 and r1.pages is None
    # generated tokens folded into the prompt → recomputation replays them
    assert r1.prompt.tolist() == [0] * 8 + [101, 201, 301]
    assert r1.kv_budget == 8 + 17 - 1            # invariant under preemption
    assert sched.waiting[0] is r1                # front of the queue
    assert pool.num_used == 3                    # r0 grew into freed pages

    # r1 cannot re-admit while r0 holds the pool under the watermark...
    assert sched.admit() == []
    # ...but once r0 finishes, r1 resumes first
    sched.finish(r0)
    assert [r.rid for r in sched.admit()] == [1]
    assert not r1.preempted and r1.admit_seq == 2
    sched.finish(r1)
    assert pool.num_used == 0 and sched.num_free_slots == 2


def test_second_preemption_folds_only_fresh_tokens():
    """A twice-preempted request must fold only the tokens generated since
    its last admission — re-folding the whole out_tokens would duplicate
    the first fold's prefix and corrupt the recompute context."""
    pool = PagedKVPool(1 + 8, 8)
    sched = Scheduler(max_slots=1, pool=pool, max_len=48)
    r = _req(0, 4, 10)
    sched.add(r)
    [r_] = sched.admit()
    assert r_ is r
    r.len, r.out_tokens = 4, [11, 12, 13]
    sched._preempt(r)
    assert r.prompt.tolist() == [0, 0, 0, 0, 11, 12, 13] and r.folded == 3
    [r_] = sched.admit()                      # recompute: prefill + decodes
    r.len, r.out_tokens = 7, [11, 12, 13, 14, 15]
    sched._preempt(r)
    assert r.prompt.tolist() == [0, 0, 0, 0, 11, 12, 13, 14, 15]
    assert r.folded == 5
    assert r.kv_budget == 4 + 10 - 1          # invariant across both folds


# ---------------------------------------------------------------------------
# end-to-end: ragged arrivals, determinism, balance after eviction
# ---------------------------------------------------------------------------

def test_ragged_arrivals_match_single_request(smollm, singles):
    cfg, m, params = smollm
    prompts, news, want = singles

    eng2 = Engine(m, params, max_slots=3)    # 4 requests contend for 3 slots
    rids = [eng2.add_request(p, n) for p, n in zip(prompts, news)]
    fin = {r.rid: r.out_tokens for r in eng2.drain()}
    for rid, w in zip(rids, want):
        assert fin[rid] == w
    # balanced after eviction: every page and slot returned
    assert eng2.pool.num_used == 0
    assert eng2.scheduler.num_free_slots == 3


def test_greedy_deterministic_under_reordered_admission(smollm, singles):
    cfg, m, params = smollm
    prompts, news, want = singles

    eng = Engine(m, params, max_slots=2)     # different slot count, too
    order = [3, 1, 0, 2]
    rids = {i: eng.add_request(prompts[i], news[i]) for i in order}
    fin = {r.rid: r.out_tokens for r in eng.drain()}
    for i in order:
        assert fin[rids[i]] == want[i]  # batch composition is irrelevant


def test_step_interleaves_admission_and_decode(smollm):
    """A slot freed by eviction is re-used at the very next admission phase
    (continuous, not batch-synchronous), and arrival times gate admission."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [4, 4, 4])
    eng = Engine(m, params, max_slots=1)
    eng.add_request(prompts[0], 2, arrival=0.0)
    eng.add_request(prompts[1], 2, arrival=0.0)
    eng.add_request(prompts[2], 2, arrival=99.0)

    fin = eng.step(now=0.0)          # r0 prefill (tok 1) + decode (tok 2)
    assert [r.rid for r in fin] == [0]
    fin = eng.step(now=1.0)          # r1 takes r0's slot immediately
    assert [r.rid for r in fin] == [1]
    assert eng.step(now=50.0) == [] # r2 hasn't arrived yet
    assert not eng.scheduler.running
    fin = eng.step(now=99.0)
    assert [r.rid for r in fin] == [2]
    assert not eng.scheduler.has_work


def test_eos_finishes_early(smollm):
    cfg, m, params = smollm
    [p] = _prompts(cfg, [6])
    eng = Engine(m, params, max_slots=2)
    eng.add_request(p, 8)
    want = eng.drain()[0].out_tokens
    eos = want[2]
    eng.add_request(p, 8, eos_id=eos)
    got = eng.drain()[0]
    assert got.out_tokens == want[:3]
    assert got.finish_reason == "eos"
    assert eng.pool.num_used == 0


# ---------------------------------------------------------------------------
# lazy allocation + preemption through the engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_preemption_recomputation_is_deterministic(smollm):
    """The tentpole contract: a pool too small for both lifetimes forces a
    preemption mid-decode, and the preempted-and-recomputed greedy output
    equals the uninterrupted (ample-pool) output token for token."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6, 5])
    news = [12, 12]

    ample = Engine(m, params, max_slots=2, page_tokens=8)
    rids = [ample.add_request(p, n) for p, n in zip(prompts, news)]
    want = {r.rid: r.out_tokens for r in ample.drain()}
    assert ample.num_preemptions == 0

    # 4 usable pages of 8 tokens; each request's lifetime needs 3 pages
    tight = Engine(m, params, max_slots=2, page_tokens=8, num_pages=1 + 4)
    tight.warmup()          # pre-compiles every bucket; must not touch pages
    assert tight.pool.num_used == 0 and tight.pool.total_allocs == 0
    rids2 = [tight.add_request(p, n) for p, n in zip(prompts, news)]
    fin = {r.rid: r for r in tight.drain()}
    assert tight.num_preemptions >= 1
    for rid, rid2 in zip(rids, rids2):
        assert fin[rid2].out_tokens == want[rid]
        assert fin[rid2].finish_reason == "length"
    assert tight.pool.num_used == 0
    assert tight.pool.total_allocs == tight.pool.total_frees
    assert tight.scheduler.num_free_slots == 2


def test_sampled_preemption_recomputation_is_deterministic(smollm):
    """The sampled twin of the greedy contract above (PR 2 verified it
    manually; this automates it): sampling keys are (seed, rid, position)-
    derived, never batch- or step-derived, so a preempted-and-recomputed
    sampled continuation equals the uninterrupted one token for token."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6, 5])
    news = [12, 12]
    ample = Engine(m, params, max_slots=2, page_tokens=8)
    rids = [ample.add_request(p, n) for p, n in zip(prompts, news)]
    want = {r.rid: r.out_tokens for r in ample.drain(greedy=False, seed=11)}
    assert ample.num_preemptions == 0

    tight = Engine(m, params, max_slots=2, page_tokens=8, num_pages=1 + 4)
    rids2 = [tight.add_request(p, n) for p, n in zip(prompts, news)]
    fin = {r.rid: r for r in tight.drain(greedy=False, seed=11)}
    assert tight.num_preemptions >= 1
    for rid, rid2 in zip(rids, rids2):
        assert fin[rid2].out_tokens == want[rid]
    assert tight.pool.num_used == 0
    assert tight.pool.total_allocs == tight.pool.total_frees


def test_per_request_sampling_params(smollm):
    """temperature/seed ride the Request (multi-tenant prerequisite; the
    speculative acceptance rule replays exactly these per-request keys):
    a request's own seed makes the drain seed irrelevant, temperature=0
    forces greedy inside a sampled drain, and temperature != 1 actually
    reshapes the picks."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6, 5])

    def serve(drain_seed, temps=(0.0, 0.7), seeds=(None, 11)):
        eng = Engine(m, params, max_slots=3)
        rids = [eng.add_request(p, 8, temperature=t, seed=s)
                for p, t, s in zip(prompts, temps, seeds)]
        fin = {r.rid: r.out_tokens for r in eng.drain(greedy=False,
                                                      seed=drain_seed)}
        return [fin[rid] for rid in rids]

    a1, b1 = serve(drain_seed=5)
    a2, b2 = serve(drain_seed=999)
    assert b1 == b2, "a per-request seed must shadow the drain seed"
    assert a1 == a2, "temperature=0 rows must not depend on any seed"

    solo = Engine(m, params, max_slots=1)
    solo.add_request(prompts[0], 8)
    assert a1 == solo.drain()[0].out_tokens   # t=0 == greedy, same rid

    # same rid + same seed, cold vs hot: temperature genuinely moved picks
    c1 = serve(drain_seed=5, temps=(0.2,), seeds=(11,))[0]
    h1 = serve(drain_seed=5, temps=(5.0,), seeds=(11,))[0]
    assert c1 != h1


@pytest.mark.slow
def test_out_of_pages_drain_terminates(smollm):
    """Sustained OutOfPages pressure: 8 requests whose lifetimes need 4
    pages each contend for 6 pages across 3 slots.  The drain must
    terminate (oldest-first growth guarantees progress), complete every
    request at full budget with outputs identical to an uninterrupted run
    — including requests preempted more than once (the double-fold
    regression) — and balance the pool."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [4, 5, 6, 7, 4, 5, 6, 7], seed=3)
    ample = Engine(m, params, max_slots=3, page_tokens=8)
    rids_a = [ample.add_request(p, 24) for p in prompts]
    want = {r.rid: r.out_tokens for r in ample.drain()}

    eng = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6)
    rids = [eng.add_request(p, 24) for p in prompts]
    fin = {r.rid: r for r in eng.drain()}
    assert sorted(fin) == sorted(rids)
    for rid, rid_a in zip(rids, rids_a):
        assert len(fin[rid].out_tokens) == 24
        assert fin[rid].out_tokens == want[rid_a]
        assert fin[rid].finish_reason == "length"
    assert eng.num_preemptions >= 1
    # at least one request must survive two preemptions, or this test
    # cannot catch re-fold corruption
    assert max(r.num_preemptions for r in fin.values()) >= 2
    assert eng.pool.num_used == 0
    assert eng.pool.total_allocs == eng.pool.total_frees
    assert eng.pool.peak_used <= 6
