"""Serving telemetry (repro.obs).

Contracts covered:
  - telemetry is an observer, never a participant: a drain with
    ``telemetry=True`` is token-identical to the same drain with it off
    — chunked and flat, greedy and seeded-sampled — and a post-warmup
    drain with tracing enabled triggers zero new XLA traces;
  - streaming histograms report percentiles within the geometric-bucket
    error bound (factor 2**0.25 → ≤ ~19% relative) without retaining
    samples, and exact count/mean/min/max;
  - registry reset semantics: ``reset("drain")`` zeroes drain-scoped
    series only — lifetime counters and momentary gauges survive;
  - the exported Chrome trace is schema-valid: metadata first, ts
    monotone per track, X spans with non-negative dur, b/e async pairs
    balanced per (cat, id), and a tight-pool prefix-cache drain shows
    queue/prefill/decode spans per request plus at least one ``preempt``
    and one ``prefix_hit`` instant;
  - a chaos drain (seeded FaultPlan + bounded queue) lands
    ``fault:nan`` / ``quarantine`` / ``shed`` events in the trace and
    the matching counters in the registry;
  - ``Engine.telemetry()`` exposes TTFT/ITL percentiles and honours the
    explicit per-drain reset.
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.obs import (NULL, Histogram, MetricsRegistry, NullTelemetry,
                       Telemetry, TraceRecorder)
from repro.serving.engine import Engine
from repro.serving.faults import FaultEvent, FaultPlan

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain(eng, reqs, **kw):
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain(**kw)}
    assert sorted(fin) == sorted(rids)
    return [fin[rid] for rid in rids]


REQS = ([13, 21, 3, 16], [8, 6, 10, 7])


# ---------------------------------------------------------------------------
# streaming histograms and registry scopes (no engine, no jax tracing)
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    """Geometric buckets at factor 2**0.25 bound relative error by ~19%;
    on a lognormal latency-like distribution the estimate lands far
    inside it.  count/mean/min/max are exact (not bucketed)."""
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-4.0, sigma=1.0, size=5000)
    h = Histogram("lat")
    for x in xs:
        h.observe(float(x))
    snap = h.snapshot()
    assert snap["count"] == len(xs)
    assert snap["mean"] == pytest.approx(xs.mean())
    assert snap["min"] == pytest.approx(xs.min())
    assert snap["max"] == pytest.approx(xs.max())
    for q, key in [(0.50, "p50"), (0.95, "p95"), (0.99, "p99")]:
        want = float(np.quantile(xs, q))
        got = snap[key]
        assert abs(got - want) / want < 0.19, (key, got, want)
    # the median of a heavy sample should be much tighter than the bound
    assert abs(snap["p50"] / float(np.quantile(xs, 0.5)) - 1) < 0.05
    assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]


def test_histogram_edge_cases():
    h = Histogram("x")
    assert h.snapshot()["count"] == 0          # empty: no crash
    h.observe(-1.0)                            # clamped, not dropped
    h.observe(0.0)
    h.observe(1e9)                             # beyond hi: overflow bucket
    s = h.snapshot()
    assert s["count"] == 3 and s["min"] == 0.0 and s["max"] == 1e9
    assert s["p99"] <= s["max"]                # clamped to observed range


def test_registry_reset_scopes():
    r = MetricsRegistry()
    per_drain = r.counter("tokens_out")                  # default scope
    forever = r.counter("requests_total", scope="lifetime")
    g = r.gauge("queue_depth")
    h = r.histogram("ttft_s")
    per_drain.inc(7)
    forever.inc(3)
    g.set(5)
    h.observe(0.25)
    r.reset("drain")
    snap = r.snapshot()
    assert snap["tokens_out"] == 0                       # drain: zeroed
    assert snap["requests_total"] == 3                   # lifetime: kept
    assert snap["queue_depth"] == 5                      # gauge: momentary
    assert snap["ttft_s"]["count"] == 0                  # drain histogram
    assert snap["_scope"]["tokens_out"] == "drain"
    assert snap["_scope"]["requests_total"] == "lifetime"
    # asking for an existing series under a different kind/scope is a bug
    with pytest.raises(AssertionError):
        r.counter("tokens_out", scope="lifetime")
    with pytest.raises(AssertionError):
        r.gauge("tokens_out")


def test_null_telemetry_is_inert():
    """The default recorder never touches a clock or allocates — every
    event hook is a no-op and ``clock()`` is a constant."""
    assert not NULL.enabled
    assert NULL.registry is None and NULL.tracer is None
    assert NULL.clock() == 0.0
    NULL.step_begin()
    NULL.step_end(None, None, [])              # no attribute access at all
    assert isinstance(Telemetry(), NullTelemetry)   # engines accept both


def test_trace_recorder_schema_and_bounds(tmp_path):
    clk = iter(x * 1e-3 for x in range(100))
    rec = TraceRecorder(clock=lambda: next(clk), max_events=6)
    rec.complete("slot 0", "prefill", 0.001, 0.003, {"tokens": 16})
    rec.async_begin("scheduler", "queue", 7)
    rec.async_end("scheduler", "queue", 7)
    rec.instant("pool", "cow")
    rec.counter("pool", "pages", {"used": 3, "free": 5})
    assert rec.dropped >= 1                    # 3 M-records + 5 events > 6
    doc = rec.to_json()
    evs = doc["traceEvents"]
    phs = [e["ph"] for e in evs]
    assert phs == sorted(phs, key=lambda p: p != "M")   # metadata first
    ts = [e["ts"] for e in evs if "ts" in e]
    assert ts == sorted(ts)
    rec.export(tmp_path / "t.json")
    assert json.loads((tmp_path / "t.json").read_text()) == doc


# ---------------------------------------------------------------------------
# the observer effect: telemetry on == telemetry off, zero retraces
# ---------------------------------------------------------------------------

def test_telemetry_token_identity_chunked_and_flat(smollm):
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    for kw in [dict(chunk_tokens=16, flat=False),              # dense chunked
               dict(chunk_tokens=16, token_budget=24)]:        # flat [1, W]
        for greedy, seed in [(True, 0), (False, 7)]:
            plain = Engine(m, params, max_slots=3, page_tokens=8, **kw)
            want = [r.out_tokens
                    for r in _drain(plain, reqs, greedy=greedy, seed=seed)]
            obs = Engine(m, params, max_slots=3, page_tokens=8,
                         telemetry=True, **kw)
            got = [r.out_tokens
                   for r in _drain(obs, reqs, greedy=greedy, seed=seed)]
            assert got == want, (kw, greedy)
            assert obs.obs.enabled and obs.obs.tracer.events()


def test_telemetry_zero_retrace_after_warmup(smollm):
    """Tracing is pure host-side bookkeeping: with telemetry enabled, a
    warmed flat engine drains without a single new XLA trace."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, prefix_cache=True, telemetry=True)
    eng.warmup()
    before = dict(m.trace_counts)
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    _drain(eng, reqs)
    assert dict(m.trace_counts) == before, \
        f"telemetry retraced: {before} -> {dict(m.trace_counts)}"
    assert eng.obs.registry.snapshot()["steps"] > 0


# ---------------------------------------------------------------------------
# exported trace: schema + lifecycle coverage under pressure
# ---------------------------------------------------------------------------

def _validate_trace(doc):
    """Chrome trace_event JSON-flavour schema checks; returns the event
    list for content assertions."""
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    by_track = {}
    open_async = {}
    for e in evs:
        assert e["pid"] == 1 and isinstance(e["tid"], int)
        if e["ph"] == "M":
            assert e["name"] == "thread_name" and e["args"]["name"]
            continue
        assert isinstance(e["ts"], int) and e["ts"] >= 0
        by_track.setdefault(e["tid"], []).append(e["ts"])
        if e["ph"] == "X":
            assert e["dur"] >= 0
        elif e["ph"] in ("b", "e"):
            key = (e["cat"], e["id"])
            if e["ph"] == "b":
                open_async[key] = open_async.get(key, 0) + 1
            else:
                assert open_async.get(key, 0) > 0, f"orphan end {key}"
                open_async[key] -= 1
        elif e["ph"] == "i":
            assert e["s"] == "t"
        elif e["ph"] == "C":
            assert all(isinstance(v, (int, float))
                       for v in e["args"].values())
        else:
            raise AssertionError(f"unexpected phase {e['ph']!r}")
    for tid, ts in by_track.items():
        assert ts == sorted(ts), f"track {tid} not monotone"
    assert all(v == 0 for v in open_async.values()), \
        f"unclosed async spans: {open_async}"
    return evs


def test_trace_export_covers_lifecycle_under_pressure(smollm, tmp_path):
    """The acceptance drain: a pool at ~half the working set plus a
    prefix cache and a duplicated prompt — the exported trace must be
    schema-valid and contain queue/prefill/decode spans per request,
    ≥ 1 ``preempt`` instant, and ≥ 1 ``prefix_hit`` instant."""
    cfg, m, params = smollm
    lens = [4, 25, 6, 30, 4, 5]
    prompts = _prompts(cfg, lens, seed=3)
    prompts.append(prompts[1])                 # duplicate → prefix hit
    reqs = list(zip(prompts, [16, 10, 16, 8, 16, 16, 10]))
    eng = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 8,
                 chunk_tokens=8, prefix_cache=True, telemetry=True)
    fin = _drain(eng, reqs)
    assert eng.num_preemptions + eng.num_pauses >= 1, \
        "config failed to create pressure — tighten the pool"
    assert eng.stats()["prefix_cache"]["hits"] >= 1

    path = tmp_path / "drain.trace.json"
    eng.obs.export_trace(path)
    doc = json.loads(path.read_text())
    evs = _validate_trace(doc)

    names = {e["name"] for e in evs}
    tracks = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert {"engine", "scheduler", "pool"} <= tracks
    assert any(t.startswith("slot ") for t in tracks)
    # lifecycle spans: every request waits in queue (async), prefills and
    # decodes (X spans on its slot track)
    queues = [e for e in evs if e["ph"] == "b" and e["name"] == "queue"]
    assert {e["id"] for e in queues} >= {r.rid for r in fin}
    for span in ("prefill", "decode", "step", "device"):
        assert span in names, f"missing {span} spans"
    instants = {e["name"] for e in evs if e["ph"] == "i"}
    assert "preempt" in instants or "pause" in instants
    assert "preempt" in instants, "acceptance requires a preemption"
    assert "prefix_hit" in instants, "acceptance requires a cache hit"
    # counters sampled: pool pages + scheduler load
    assert {e["name"] for e in evs if e["ph"] == "C"} >= {"pages", "load"}
    # the request-lifecycle journal mirrors the trace
    marks = [ev[0] for ev in fin[0].obs_events]
    assert marks[0] == "queued" and marks[-1] == "finished"
    assert "prefill_chunk" in marks and "prefill_done" in marks


def test_chaos_drain_lands_fault_events_in_trace(smollm):
    """A seeded NaN fault plus a bounded queue: the quarantine and the
    sheds are visible both as registry counters and as trace instants,
    and survivors still finish."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [4] * 6, seed=5)
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, queue_limit=2,
                 telemetry=True)
    rids = [eng.add_request(p, 3) for p in prompts]
    plan = FaultPlan([FaultEvent(1, "nan")])
    with plan.on(eng):
        fin = {r.rid: r for r in eng.drain()}
    assert sorted(fin) == sorted(rids)
    assert plan.fired["nan"] == 1
    reasons = [fin[r].finish_reason for r in rids]
    assert reasons.count("rejected") == 4
    assert reasons.count("error") == 1

    snap = eng.obs.registry.snapshot()
    assert snap["quarantines"] == 1 and snap["sheds"] == 4
    assert snap["faults_injected"] == 1
    evs = _validate_trace(eng.obs.tracer.to_json())
    instants = {e["name"] for e in evs if e["ph"] == "i"}
    assert {"fault:nan", "quarantine", "shed"} <= instants


# ---------------------------------------------------------------------------
# Engine.telemetry(): percentiles and the explicit per-drain reset
# ---------------------------------------------------------------------------

def test_engine_telemetry_percentiles_and_reset(smollm):
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    _drain(eng, reqs)

    tel = eng.telemetry(reset=True)
    assert tel["enabled"]
    lat = tel["latency"]
    assert lat["ttft_s"]["count"] == len(reqs)
    assert lat["e2e_s"]["count"] == len(reqs)
    assert lat["itl_s"]["count"] > 0
    for series in ("ttft_s", "itl_s", "queue_wait_s", "e2e_s"):
        s = lat[series]
        assert 0 <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    met = tel["metrics"]
    assert met["requests_finished"] == len(reqs)
    assert met["tokens_out"] == sum(n for _, n in reqs)
    assert met["step_wall_s"]["count"] == met["steps"] > 0
    # device time is a subset of wall time, measured per step
    assert met["step_device_s"]["count"] == met["steps"]

    # the reset zeroed the drain scope; a second drain starts clean
    after = eng.telemetry()
    assert after["metrics"]["tokens_out"] == 0
    assert after["latency"]["ttft_s"]["count"] == 0
    _drain(eng, reqs)
    again = eng.telemetry()
    assert again["metrics"]["requests_finished"] == len(reqs), \
        "second drain must not double-count the first"


def test_telemetry_disabled_reports_so(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=2, chunk_tokens=8)
    tel = eng.telemetry()
    assert not tel["enabled"]
    assert tel["metrics"] == {} and tel["latency"] == {}
    assert tel["components"]["finished"] == 0   # stats still reported
