"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 device;
multi-device dry-run coverage goes through subprocesses (test_dryrun.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import RunConfig, ShapeSpec


@pytest.fixture(scope="session")
def run_f32():
    return RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)


@pytest.fixture(scope="session")
def smoke_shape():
    return ShapeSpec("smoke", 32, 2, "train")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
