"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 device;
multi-device dry-run coverage goes through subprocesses (test_dryrun.py).

If ``hypothesis`` is not installed, a seeded-random property-check fallback
(tests/_propcheck.py) is registered under that name BEFORE test modules
import — property modules always collect and the properties still run."""

import sys

try:
    import hypothesis  # noqa: F401  (prefer the real library when present)
except ImportError:
    import _propcheck
    sys.modules["hypothesis"] = _propcheck

import jax
import jax.numpy as jnp
import pytest

# NOTE: do NOT enable jax_compilation_cache_dir here — the persistent cache
# in jaxlib 0.4.37 corrupts the heap on the CPU backend under this suite
# (reproducible "corrupted double-linked list" abort in the trainer
# checkpoint-resume test once executables round-trip through the cache).

from repro.configs import RunConfig, ShapeSpec


@pytest.fixture(scope="session")
def run_f32():
    return RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)


@pytest.fixture(scope="session")
def smoke_shape():
    return ShapeSpec("smoke", 32, 2, "train")


# the `slow` marker is registered in pytest.ini (with `-m "not slow"` as the
# default tier-1 selection)
