"""End-to-end behaviour tests for the paper's system.

The paper's claim chain, reproduced at CPU scale:
  1. one model implementation runs under scalable / fixed / unpacked
     code-generation policies with identical results;
  2. the scalable packed layout adapts to the hardware descriptor (VL),
     fixed does not;
  3. training + checkpoint/restart + serving all operate on the packed
     representation end-to-end.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core import make_layout, presets
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.training.trainer import Trainer

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False,
                warmup_steps=2)


def test_vla_portability_end_to_end():
    """The paper's headline property: ONE set of weights + ONE model
    definition executes correctly across hardware with different vector
    lengths, because layouts are derived from the hardware descriptor."""
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("t", 32, 2, "train")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab)}
    outs = []
    params = None
    for hw in ("tpu_vl128", "tpu_vl256", "tpu_vl512"):
        m = build_model(cfg, RUN, shape, hw=presets[hw])
        if params is None:
            params = m.init(jax.random.PRNGKey(0))
        logits, _ = m.forward(params, batch)
        outs.append(np.asarray(logits))
        lay = make_layout("scalable", presets[hw], jnp.float32)
        assert lay.n_r == presets[hw].lanes  # layout followed the hardware
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-3, atol=2e-3)


def test_train_then_serve_pipeline():
    """Train a few steps, checkpoint, restore, serve — all packed."""
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("t", 64, 4, "train")
    model = build_model(cfg, RUN, shape)
    data = SyntheticLM(cfg, shape, seed=0)
    tr = Trainer(model, data, RUN, total_steps=5, log_fn=lambda *_: None)
    state, hist = tr.fit(jax.random.PRNGKey(0))
    assert all(np.isfinite(hist))

    serve_shape = ShapeSpec("s", 64, 2, "decode")
    m2 = build_model(cfg, RUN, serve_shape)
    eng = Engine(m2, state.params)
    out = eng.generate({"tokens": jnp.asarray([[1, 2, 3], [4, 5, 6]])}, 5)
    assert out.shape == (2, 5)


def test_packing_overhead_is_amortizable():
    """Paper §4.1: packing is a standalone op over full operands, so its
    cost is O(MK + KN) against O(MNK) compute — check the op counts."""
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    m = k = n = 512
    pack_elems = m * k + k * n
    matmul_flops = 2 * m * n * k
    assert matmul_flops / pack_elems >= min(m, n, k) * 0.9


def test_continuous_serving_smoke():
    """Boot the continuous-batching engine end-to-end on smollm2-135m with 3
    ragged requests (different prompt lengths AND budgets): all complete,
    token counts honor per-request budgets, KV pages balance after drain."""
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("s", 64, 2, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, max_slots=2)   # 3 requests contend for 2 slots

    key = jax.random.PRNGKey(1)
    reqs = [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (l,), 0, cfg.vocab)), n)
            for i, (l, n) in enumerate([(3, 7), (12, 4), (7, 10)])]
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain()}
    assert sorted(fin) == sorted(rids)
    for rid, (_, n) in zip(rids, reqs):
        out = fin[rid].out_tokens
        assert len(out) == n
        assert all(0 <= t < cfg.vocab for t in out)
    assert eng.pool.num_used == 0 and eng.scheduler.num_free_slots == 2


# policy agreement is also covered at forward/op level (test_models,
# test_packing); the loss-level sweep rides the slow tier
@pytest.mark.slow
def test_three_policies_one_model():
    cfg = reduced_config(get_config("qwen3-8b"), layers=2)
    shape = ShapeSpec("t", 16, 2, "train")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab)}
    params = None
    losses = []
    for pol in ("scalable", "fixed", "unpacked"):
        m = build_model(cfg, dataclasses.replace(RUN, layout_policy=pol), shape)
        if params is None:
            params = m.init(jax.random.PRNGKey(0))
        loss, _ = m.loss(params, batch)
        losses.append(float(loss))
    assert max(losses) - min(losses) < 2e-3
