"""Packed-domain propagation ops (paper §4.3) and their padding-neutrality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (MatmulContext, linear_init, linear_apply, make_layout,
                        pack_activation, presets, prepack_params)

LAY = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
CTX = MatmulContext()

dims = st.integers(1, 200)


@pytest.mark.slow
@given(m=dims, k=dims, seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_rms_norm_padding_neutral(m, k, seed):
    """Norms over the padded feature dim must divide by the TRUE size."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, m, k))
    g = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k,))
    got = pack_activation(x, LAY).rms_norm(g).unpack()
    ms = jnp.mean(x * x, -1, keepdims=True)
    want = x * jax.lax.rsqrt(ms + 1e-6) * g
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@given(m=dims, k=dims)
@settings(max_examples=25, deadline=None)
def test_layer_norm_padding_neutral(m, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))[None]
    g = jnp.ones((k,))
    b = jnp.zeros((k,))
    got = pack_activation(x, LAY).layer_norm(g, b).unpack()
    mean = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    want = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_padding_invariant_maintained_through_chain():
    """After packed-domain ops, the feature padding is still exactly zero
    (the layout contract consumers rely on)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 10, 200))
    px = pack_activation(x, LAY)
    g = jnp.ones((200,))
    y = px.rms_norm(g).elementwise(jax.nn.gelu)
    y = y + y
    data = np.asarray(y.data)  # [B, M_o, K_o, m_r, k_r]
    # feature padding: cols beyond 200 - 128 = 72 of the last K tile
    assert np.all(data[..., -1, :, 72:] == 0)
    # token padding: rows beyond 10 - 8 = 2 of the last M tile
    assert np.all(data[:, -1, :, 2:, :] == 0)


@pytest.mark.slow
def test_residual_chain_matches_unpacked():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 17, 100))
    p1 = linear_init(jax.random.PRNGKey(1), 100, 300)
    p2 = linear_init(jax.random.PRNGKey(2), 300, 100)
    px = pack_activation(x, LAY)
    h = linear_apply(p1, px.rms_norm(jnp.ones(100)), CTX,
                     activation=jax.nn.silu, keep_packed=True)
    out = (px + linear_apply(p2, h, CTX, keep_packed=True)).unpack()

    ms = jnp.mean(x * x, -1, keepdims=True)
    xr = x * jax.lax.rsqrt(ms + 1e-6)
    want = x + jax.nn.silu(xr @ p1["w"]) @ p2["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_prepacked_weights_equivalent():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 9, 130))
    params = {"lin": linear_init(jax.random.PRNGKey(1), 130, 60, bias=True)}
    a = linear_apply(params["lin"], x, CTX)
    pp = prepack_params(params, CTX)
    assert "w_pack" in pp["lin"] and "w" not in pp["lin"]
    b = linear_apply(pp["lin"], x, CTX)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_fixed_layout_forces_roundtrip():
    """The fixed (NEON-analogue) layout is not chain-compatible: keep_packed
    must round-trip through unpacked — and still be correct."""
    lay_fixed = make_layout("fixed", presets["tpu_v5e"], jnp.float32)
    assert lay_fixed.chain_compatible  # 8x128x128 happens to chain
    # fixed layout under a wider hardware: tiles stay 8/128/128 while the
    # scalable layout moves — correctness must hold for both
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 33, 100))
    ctxf = MatmulContext(policy="fixed", hw=presets["tpu_vl512"])
    ctxs = MatmulContext(policy="scalable", hw=presets["tpu_vl512"])
    p1 = linear_init(jax.random.PRNGKey(1), 100, 50)
    want = x @ p1["w"]
    for ctx in (ctxf, ctxs):
        px = pack_activation(x, ctx.layout(x.dtype))
        got = linear_apply(p1, px, ctx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
