"""Property tests: pack/unpack roundtrip, padding semantics, mmt4d == dot."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

import types

from repro.core import (Epilogue, matmul, packed_matmul, packing,
                        make_layout, presets)

mm = types.SimpleNamespace(Epilogue=Epilogue, matmul=matmul,
                           packed_matmul=packed_matmul)
from repro.core.layout import LayoutPolicy

LAY = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
LAY_FIXED = make_layout("fixed", presets["tpu_v5e"], jnp.float32)

dims = st.integers(1, 300)


@given(m=dims, k=dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(m, k, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    ap = packing.pack_lhs(a, LAY)
    assert ap.shape == LAY.packed_lhs_shape(m, k)
    np.testing.assert_array_equal(np.asarray(packing.unpack_lhs(ap, m, k)),
                                  np.asarray(a))


@given(m=dims, k=dims)
@settings(max_examples=20, deadline=None)
def test_padding_is_explicit_zero(m, k):
    """Paper §4.3: out-of-bounds elements are explicit zeros in packed data."""
    a = jnp.ones((m, k))
    ap = packing.pack_lhs(a, LAY)
    total = float(jnp.sum(ap))
    assert total == m * k  # all padding contributed exactly zero


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_packed_matmul_equals_dot(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k))
    b = jax.random.normal(k2, (k, n))
    ref = a @ b
    for lay in (LAY, LAY_FIXED):
        out = mm.packed_matmul(a, b, lay)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)


@given(m=dims, k=dims, n=dims)
@settings(max_examples=10, deadline=None)
def test_policy_dispatch_agree(m, k, n):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    outs = [mm.matmul(a, b, make_layout(p, presets["tpu_v5e"], jnp.float32))
            for p in ("scalable", "fixed", "unpacked")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-3)


def test_vl_scaling_layouts_all_correct():
    """One code path, three 'hardware vector lengths' (Fig 3 premise)."""
    a = jax.random.normal(jax.random.PRNGKey(0), (100, 300))
    b = jax.random.normal(jax.random.PRNGKey(1), (300, 200))
    ref = a @ b
    for hwname in ("tpu_vl128", "tpu_vl256", "tpu_vl512"):
        lay = make_layout("scalable", presets[hwname], jnp.float32)
        np.testing.assert_allclose(np.asarray(mm.packed_matmul(a, b, lay)),
                                   np.asarray(ref), rtol=1e-4, atol=1e-3)


def test_epilogue_fusion_packed_domain():
    a = jax.random.normal(jax.random.PRNGKey(0), (37, 130))
    b = jax.random.normal(jax.random.PRNGKey(1), (130, 70))
    bias = jax.random.normal(jax.random.PRNGKey(2), (70,))
    epi = mm.Epilogue(activation=jax.nn.gelu, has_bias=True)
    out = mm.packed_matmul(a, b, LAY, epilogue=epi, bias=bias)
    ref = jax.nn.gelu(a @ b + bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@given(lead=st.integers(1, 4), m=st.integers(1, 60), k=st.integers(1, 60),
       n=st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_batched_packed_matmul(lead, m, k, n):
    a = jax.random.normal(jax.random.PRNGKey(0), (lead, m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    out = mm.packed_matmul(a, b, LAY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-3)
