"""Flat token-level serving step (the [1, budget] packed layout).

Contracts covered:
  - the flat step is token-identical to both the dense chunked step and
    the monolithic baseline — greedy and seeded-sampled — and stays so
    under speculation (n-gram and draft-model), a prefix cache, and a
    pool tight enough to force preemptions and mid-prefill pauses;
  - after Engine.warmup() a flat drain with speculation and prefix-cache
    hits triggers zero new XLA traces on the target AND the draft model;
  - budget exactness: no flat step ever carries more real tokens than
    the token budget (decode tokens excepted — they are unconditional),
    and every decoding row appears in every step (decode never stalls
    behind prefill);
  - the width ladder is m_r-aligned, descending, and _flat_shape picks
    the smallest width that holds the step;
  - the Pallas ragged-attention kernel (interpret mode) matches the jnp
    reference oracle on mixed decode/prefill segments with padding rows;
  - eos classification is one shared rule (scheduler.finish_reason_for)
    across the continuous and static paths: eos strictly before the last
    position is "eos", eos AS the last position is "length";
  - mid-draft eos regression: a draft that runs past eos is truncated —
    the block table ends at the eos position and no post-eos draft KV
    can reach the prefix cache (a second identical request must hit the
    cache and still reproduce the baseline).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.kernels.ragged_attn.kernel import ragged_attention_kernel_call
from repro.kernels.ragged_attn.ref import \
    ragged_attention_ref as ragged_attention_reference
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, finish_reason_for
from repro.serving.speculative import (Drafter, DraftModelDrafter,
                                       NgramDrafter)

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.fixture(scope="module")
def draft(smollm):
    cfg, _, _ = smollm
    dcfg = reduced_config(cfg, layers=1)
    dm = build_model(dcfg, RUN, ShapeSpec("serve", 64, 3, "decode"))
    return dm, dm.init(jax.random.PRNGKey(3))


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain(eng, reqs, **kw):
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain(**kw)}
    assert sorted(fin) == sorted(rids)
    return [fin[rid] for rid in rids]


REQS = ([13, 21, 3, 16], [8, 6, 10, 7])


@pytest.fixture(scope="module")
def baseline(smollm):
    """Monolithic-prefill reference outputs, greedy and sampled."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    eng = Engine(m, params, max_slots=3)
    greedy = [r.out_tokens for r in _drain(eng, reqs)]
    eng = Engine(m, params, max_slots=3)
    sampled = [r.out_tokens for r in _drain(eng, reqs, greedy=False, seed=7)]
    return reqs, greedy, sampled


# ---------------------------------------------------------------------------
# token identity: flat == dense chunked == monolithic
# ---------------------------------------------------------------------------

def test_flat_matches_chunked_and_monolithic(smollm, baseline):
    """The tentpole identity: same prompts, three engines (flat, dense
    chunked, monolithic), one token stream.  The budget (24) is a
    non-divisor of most prompts so segments split mid-chunk."""
    cfg, m, params = smollm
    reqs, greedy, sampled = baseline
    flat = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                  token_budget=24)
    assert flat.flat            # flat defaults on whenever chunking is on
    got = _drain(flat, reqs)
    assert [r.out_tokens for r in got] == greedy
    assert flat.pool.num_used == 0
    st = flat.stats()["flat"]
    assert st["steps"] > 0 and st["token_budget"] == 24

    dense = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                   token_budget=24, flat=False)
    assert not dense.flat
    assert [r.out_tokens for r in _drain(dense, reqs)] == greedy


def test_flat_matches_baseline_sampled(smollm, baseline):
    """Sampling keys are (seed, rid, position)-derived: the flat layout
    must be invisible to sampled continuations too."""
    cfg, m, params = smollm
    reqs, _, sampled = baseline
    eng = Engine(m, params, max_slots=3, chunk_tokens=16, token_budget=24)
    assert [r.out_tokens for r in
            _drain(eng, reqs, greedy=False, seed=7)] == sampled


def test_flat_requires_chunking(smollm):
    cfg, m, params = smollm
    with pytest.raises(AssertionError):
        Engine(m, params, max_slots=3, flat=True)


def test_flat_preemption_token_identical(smollm):
    """A pool at ~half the working set forces folds and mid-prefill
    pauses; the flat engine must still reproduce the ample-pool
    monolithic outputs exactly and balance the pool."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, [4, 25, 6, 30, 4, 5], seed=3),
                    [16, 10, 16, 8, 16, 16]))
    ample = Engine(m, params, max_slots=3, page_tokens=8)
    want = [r.out_tokens for r in _drain(ample, reqs)]

    tight = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6,
                   chunk_tokens=8)
    got = _drain(tight, reqs)
    assert [r.out_tokens for r in got] == want
    assert tight.num_preemptions >= 1
    assert tight.pool.num_used == 0
    assert tight.pool.total_allocs == tight.pool.total_frees


# ---------------------------------------------------------------------------
# speculation and prefix cache over the flat step
# ---------------------------------------------------------------------------

def test_flat_spec_ngram_matches_baseline(smollm, baseline):
    cfg, m, params = smollm
    reqs, greedy, sampled = baseline
    for gr, seed, want in [(True, 0, greedy), (False, 7, sampled)]:
        eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                     token_budget=24, spec_tokens=2, drafter=NgramDrafter())
        assert eng.flat
        got = _drain(eng, reqs, greedy=gr, seed=seed)
        assert [r.out_tokens for r in got] == want
        assert eng.pool.num_used == 0


def test_flat_spec_draft_model_matches_baseline(smollm, draft, baseline):
    """Draft-model speculation over the flat step — exercises the batched
    propose_all path (one [slots, 1] draft call per position, not one
    [1, 1] call per row per position)."""
    cfg, m, params = smollm
    dm, dparams = draft
    reqs, greedy, _ = baseline
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, spec_tokens=3,
                 drafter=DraftModelDrafter(dm, dparams))
    got = _drain(eng, reqs)
    assert [r.out_tokens for r in got] == greedy
    sp = eng.stats()["speculative"]
    st = sp["drafter"]
    assert st["drafter"] == "draft-model"
    assert st["live_states"] == 0            # forget() ran for every rid
    assert sp["drafted"] > 0
    # batching: the drafter launches O(positions) batched steps per engine
    # step, never O(rows * positions) single-row steps — with 3 slots and
    # k=3 a per-row drafter needs ~3x the launches of a batched one
    assert st["draft_steps"] <= eng.stats()["steps"] * (eng.spec_tokens + 1)


def test_flat_prefix_cache_hits_and_identity(smollm, baseline):
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, prefix_cache=True)
    assert [r.out_tokens for r in _drain(eng, reqs)] == greedy
    # identical prompts again: served from cached pages, same tokens
    assert [r.out_tokens for r in _drain(eng, reqs)] == greedy
    st = eng.stats()["prefix_cache"]
    assert st["hits"] >= 1
    eng.prefix_cache.clear()
    assert eng.pool.num_used == 0


# ---------------------------------------------------------------------------
# zero recompiles after warmup
# ---------------------------------------------------------------------------

def test_flat_zero_recompile_after_warmup(smollm, draft):
    """warmup() compiles the whole flat width ladder (x verify widths) and
    the draft model's batch widths; a subsequent drain with speculation,
    prefix-cache hits and chunked prefill must trace nothing new on the
    target or the draft model."""
    cfg, m, params = smollm
    dm, dparams = draft
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, spec_tokens=2, prefix_cache=True,
                 drafter=DraftModelDrafter(dm, dparams))
    eng.warmup()
    before_t = dict(m.trace_counts)
    before_d = dict(dm.trace_counts)
    reqs = list(zip(_prompts(cfg, [13, 21, 3, 16, 13]), [8, 6, 10, 7, 8]))
    _drain(eng, reqs)
    assert dict(m.trace_counts) == before_t, \
        f"target retraced: {before_t} -> {dict(m.trace_counts)}"
    assert dict(dm.trace_counts) == before_d, \
        f"draft retraced: {before_d} -> {dict(dm.trace_counts)}"


# ---------------------------------------------------------------------------
# budget exactness and the width ladder
# ---------------------------------------------------------------------------

def test_flat_budget_exactness(smollm):
    """Spy on the flat launch: (a) real (non-pad) tokens never exceed the
    budget, (b) every slot that is decoding when the step launches has at
    least one position in the step — decode never stalls on prefill
    backlog, (c) the width is the smallest ladder rung holding the real
    count."""
    cfg, m, params = smollm
    budget = 16
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=8,
                 token_budget=budget)
    seen = []
    orig = eng._run_flat

    def spy(token, bt, row_ids, q_pos, idx):
        decoding = {s for s, r in eng.scheduler.running.items()
                    if r.status == "running"}
        real = row_ids[row_ids >= 0]
        seen.append((int(real.size), set(int(x) for x in np.unique(real)),
                     decoding, row_ids.size))
        return orig(token, bt, row_ids, q_pos, idx)

    eng._run_flat = spy
    reqs = list(zip(_prompts(cfg, [13, 21, 3, 16]), [8, 6, 10, 7]))
    _drain(eng, reqs)
    assert seen
    for real, rows, decoding, width in seen:
        assert 0 < real <= budget
        assert decoding <= rows, f"decoding slots {decoding} stalled ({rows})"
        assert width == eng._flat_shape(real)
    # at least one step must actually mix prefill and decode segments
    assert any(len(rows) > 1 for _, rows, _, _ in seen)


def test_flat_width_ladder(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=8,
                 token_budget=24)
    ladder = eng._flat_shapes()
    mr = eng._bucket
    assert ladder == sorted(ladder, reverse=True)
    assert all(w % mr == 0 for w in ladder)
    assert ladder[0] >= 24 and ladder[-1] == mr
    # the chosen width is the smallest rung that fits
    for n in range(1, ladder[0] + 1):
        w = eng._flat_shape(n)
        assert w >= n and all(r < n for r in ladder if r < w)
    # speculation raises the cap so a full verify burst always fits
    eng2 = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=8,
                  token_budget=8, spec_tokens=5,
                  drafter=NgramDrafter())
    assert eng2._flat_shapes()[0] >= 3 * 6


# ---------------------------------------------------------------------------
# Pallas kernel vs reference oracle
# ---------------------------------------------------------------------------

def test_ragged_kernel_matches_reference():
    """Interpret-mode Pallas kernel vs the jnp oracle on mixed segments:
    a decode row, a mid-prefill chunk, a fresh prefill and -1 padding."""
    key = jax.random.PRNGKey(0)
    hq, hkv, dh, t, pages, mp, w = 4, 2, 8, 8, 9, 3, 16
    ks = jax.random.split(key, 3)
    q = np.asarray(jax.random.normal(ks[0], (w, hq, dh)), np.float32)
    k_pages = np.asarray(jax.random.normal(ks[1], (pages, t, hkv, dh)),
                         np.float32)
    v_pages = np.asarray(jax.random.normal(ks[2], (pages, t, hkv, dh)),
                         np.float32)
    bt = np.asarray(jax.random.permutation(jax.random.PRNGKey(5),
                                           pages)[: 3 * mp],
                    np.int32).reshape(3, mp)
    # row 0: one decode token at pos 17; row 1: 5-token chunk at 8..12;
    # row 2: fresh 4-token prefill; rest: padding
    row_ids = np.full(w, -1, np.int32)
    q_pos = np.zeros(w, np.int32)
    row_ids[0], q_pos[0] = 0, 17
    row_ids[1:6], q_pos[1:6] = 1, np.arange(8, 13)
    row_ids[6:10], q_pos[6:10] = 2, np.arange(4)
    args = dict(block_tables=jnp.asarray(bt), row_ids=jnp.asarray(row_ids),
                q_pos=jnp.asarray(q_pos))
    ref = ragged_attention_reference(q, jnp.asarray(k_pages),
                                     jnp.asarray(v_pages), **args)
    out = ragged_attention_kernel_call(q, jnp.asarray(k_pages),
                                       jnp.asarray(v_pages), interpret=True,
                                       **args)
    np.testing.assert_allclose(np.asarray(out)[row_ids >= 0],
                               np.asarray(ref)[row_ids >= 0],
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# eos: one classification rule across continuous and static paths
# ---------------------------------------------------------------------------

def test_finish_reason_rule():
    """eos strictly before the final position is "eos"; eos AS the final
    position is "length" (the row used its whole allowance)."""
    assert finish_reason_for([1, 9, 2, 3], 4, 9) == (2, "eos")
    assert finish_reason_for([1, 2, 3, 9], 4, 9) == (4, "length")
    assert finish_reason_for([1, 2, 3, 4], 4, 9) == (4, "length")
    assert finish_reason_for([9, 1, 2], 4, 9) == (1, "eos")
    assert finish_reason_for([1, 2], 4, None) == (2, "length")
    assert finish_reason_for([9], 1, 9) == (1, "length")   # eos at the cap


def test_request_done_uses_shared_rule():
    r = Request(rid=0, prompt=np.zeros(3, np.int32), max_new=4, eos_id=9,
                arrival=0.0)
    r.out_tokens = [1, 2, 3, 9]
    assert r.done() and r.finish_reason == "length"
    r2 = Request(rid=1, prompt=np.zeros(3, np.int32), max_new=4, eos_id=9,
                 arrival=0.0)
    r2.out_tokens = [1, 9]
    assert r2.done() and r2.finish_reason == "eos"


def test_continuous_and_static_eos_agree(smollm, baseline):
    """Both generate() paths must classify identically: run the continuous
    path with an eos drawn from the baseline stream and check every row's
    reason against finish_reason_for applied to its no-eos stream."""
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    eos = greedy[0][2]          # row 0 finishes early; others data-dependent
    max_new = 8
    eng = Engine(m, params, max_slots=3)
    out, reasons = eng.generate(
        {"tokens": np.stack([np.resize(r[0], 13) for r in reqs[:2]])},
        max_new, eos_id=eos, return_reasons=True)
    for i in range(out.shape[0]):
        row = list(out[i])
        kept, want = finish_reason_for(row[:max_new], max_new, eos)
        assert reasons[i] == want
        if want == "eos":
            assert all(t == eos for t in row[kept - 1:])


# ---------------------------------------------------------------------------
# mid-draft eos regression
# ---------------------------------------------------------------------------

class TruthDrafter(Drafter):
    """Proposes the request's true greedy continuation, INCLUDING tokens
    past eos — every draft position verifies as accepted, so a draft burst
    deliberately writes KV beyond end-of-sequence.  The engine must roll
    that KV back when it cuts the stream at eos."""

    def __init__(self, outs_by_prompt):
        self.outs = outs_by_prompt      # prompt bytes -> full greedy stream

    def propose(self, req, k):
        done = len(req.out_tokens)
        nxt = self.outs[np.asarray(req.prompt).tobytes()][done:done + k]
        return [int(t) for t in nxt]


@pytest.mark.parametrize("use_cache", [False, True])
def test_mid_draft_eos_truncates_kv(smollm, baseline, use_cache):
    """eos arrives mid-draft (the oracle keeps proposing past it, and the
    target accepts everything): outputs must stop exactly at eos, the
    block table must shrink to the kept length (the in-step assert in
    _verify_decode_row guards this), the pool must balance, and with a
    prefix cache a rerun of the same prompt must hit the cache and still
    match — proof no post-eos draft KV was inserted."""
    cfg, m, params = smollm
    reqs, greedy, _ = baseline
    # eos = the 4th baseline token of row 0: eos lands mid-stream, and with
    # k=4 the oracle drafts through and past it in one burst
    eos = greedy[0][3]
    outs = {np.asarray(p).tobytes(): toks
            for (p, _), toks in zip(reqs, greedy)}
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, spec_tokens=4, prefix_cache=use_cache,
                 drafter=TruthDrafter(outs))
    rids = [eng.add_request(p, n, eos_id=eos) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain()}
    for i, rid in enumerate(rids):
        req = fin[rid]
        kept, reason = finish_reason_for(greedy[i], reqs[i][1], eos)
        assert req.out_tokens == greedy[i][:kept]
        assert req.finish_reason == reason
    assert eng.pool.total_allocs == eng.pool.total_frees
    if use_cache:
        # rerun: the cached pages must reproduce the same truncated stream
        rids = [eng.add_request(p, n, eos_id=eos) for p, n in reqs]
        fin = {r.rid: r for r in eng.drain()}
        for i, rid in enumerate(rids):
            kept, _ = finish_reason_for(greedy[i], reqs[i][1], eos)
            assert fin[rid].out_tokens == greedy[i][:kept]
        assert eng.stats()["prefix_cache"]["hits"] >= 1
        eng.prefix_cache.clear()
    assert eng.pool.num_used == 0
