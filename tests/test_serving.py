"""Serving engine: generation determinism, prepacking, cache reuse."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


def _setup(arch, max_len=64):
    cfg = reduced_config(get_config(arch))
    shape = ShapeSpec("serve", max_len, 2, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.fixture(scope="module")
def smollm_serve():
    """One smollm2 model shared by the smollm2 serving tests — engines over
    the same model share step compilations (model.jit_step)."""
    return _setup("smollm2-135m")


@pytest.mark.parametrize("arch", ["smollm2-135m", "rwkv6-1.6b",
                                  pytest.param("whisper-small",
                                               marks=pytest.mark.slow)])
def test_generate_shapes_and_determinism(arch, smollm_serve):
    cfg, m, params = smollm_serve if arch == "smollm2-135m" else _setup(arch)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (2, 64 // cfg.audio_downsample,
                                             cfg.d_model))
    eng = Engine(m, params)
    out1 = eng.generate(batch, 6)
    out2 = Engine(m, params).generate(batch, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)  # greedy => deterministic
    assert out1.min() >= 0 and out1.max() < cfg.vocab


def test_generate_matches_unpacked_policy(smollm_serve):
    """Packed serving == unpacked serving, token for token."""
    import dataclasses
    cfg, m1, params = smollm_serve
    shape = ShapeSpec("serve", 64, 2, "decode")
    m2 = build_model(cfg, dataclasses.replace(RUN, layout_policy="unpacked"),
                     shape)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab)}
    o1 = Engine(m1, params).generate(batch, 8)
    o2 = Engine(m2, params, prepack=False).generate(batch, 8)
    np.testing.assert_array_equal(o1, o2)


def test_continuous_matches_static_batching(smollm_serve):
    """The compatibility contract: the continuous engine's generate() equals
    the static-batch loop token for token (same prompts, same budget)."""
    cfg, m, params = smollm_serve
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab)}
    eng = Engine(m, params)
    np.testing.assert_array_equal(eng.generate_static(batch, 6),
                                  Engine(m, params).generate(batch, 6))


def test_generate_pads_eos_rows_and_reports_reasons(smollm_serve):
    """The ragged-stack bug: a row finishing early (eos) used to crash
    np.stack.  generate(eos_id=...) must pad eos rows to max_new with the
    eos token and report per-row finish reasons."""
    cfg, m, params = smollm_serve
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab)}
    base = Engine(m, params).generate(batch, 8)
    eos = int(base[0, 2])       # force row 0 to finish at its 3rd token
    out, reasons = Engine(m, params).generate(batch, 8, eos_id=eos,
                                              return_reasons=True)
    assert out.shape == (2, 8) and out.dtype == np.int32
    for i in range(2):
        hits = np.flatnonzero(base[i] == eos)
        want = np.array(base[i])
        if hits.size:
            want[hits[0]:] = eos
            assert reasons[i] == "eos"
        else:
            assert reasons[i] == "length"
        np.testing.assert_array_equal(out[i], want)
    assert reasons[0] == "eos"
    # without return_reasons the wrapper keeps its array-only signature
    out2 = Engine(m, params).generate(batch, 8, eos_id=eos)
    np.testing.assert_array_equal(out2, out)


def test_vlm_generate_with_patch_prefix():
    cfg, m, params = _setup("internvl2-26b")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                          cfg.vocab),
             "patches": jax.random.normal(jax.random.PRNGKey(2),
                                          (2, cfg.vision_tokens, cfg.d_model))}
    out = Engine(m, params).generate(batch, 4)
    assert out.shape == (2, 4)
