"""Chunked prefill under a per-step token budget (the fused ragged step).

Contracts covered:
  - chunked prefill is token-identical to monolithic prefill for chunk
    sizes of one page, a non-divisor of the prompt length, and larger than
    the whole prompt — greedy and seeded-sampled;
  - identity holds through preemption: a pool too small for the working
    set forces folds/pauses and the recomputed outputs still match;
  - a paused mid-prefill request resumes from its cursor with the pages it
    still holds — already-written chunks are never recomputed;
  - chunked admission books pages for the next chunk only (not the whole
    prompt), and chunk sizes round up to the layout's m_r;
  - the token budget caps concurrent prefill tokens per step, never decode
    progress;
  - after Engine.warmup() a trace with admissions, chunked prefills,
    growth and preemption triggers zero new XLA traces (the
    compile-counting hook in ReproModel.jit_step);
  - recurrent-mixer families refuse chunk_tokens (padded chunk rows are
    not inert for a scan).
"""

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core.layout import ceil_div
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedKVPool
from repro.serving.scheduler import Request, Scheduler

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain(eng, reqs, **kw):
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain(**kw)}
    assert sorted(fin) == sorted(rids)
    return [fin[rid] for rid in rids]


# ---------------------------------------------------------------------------
# chunk-boundary correctness: chunked == monolithic, token for token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mono_outputs(smollm):
    """Monolithic-prefill reference over prompts chosen so every chunk size
    below hits a boundary case (13 and 21 are non-divisors of 8 and 16; 3
    is smaller than any chunk)."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, [13, 21, 3, 16]), [8, 6, 10, 7]))
    eng = Engine(m, params, max_slots=3)
    greedy = [r.out_tokens for r in _drain(eng, reqs)]
    eng = Engine(m, params, max_slots=3)
    sampled = [r.out_tokens for r in _drain(eng, reqs, greedy=False, seed=7)]
    return reqs, greedy, sampled


@pytest.mark.parametrize("chunk", [8, 16, 40])
def test_chunked_matches_monolithic(smollm, mono_outputs, chunk):
    """chunk=8: exactly one page; chunk=16: non-divisor of the 13/21-token
    prompts (final partial chunk); chunk=40: larger than every prompt
    (prefill completes in one fused step)."""
    cfg, m, params = smollm
    reqs, greedy, sampled = mono_outputs
    # an unthrottling budget keeps chunks whole, so every prompt takes
    # exactly ceil(len / chunk) fused steps — no chunk is ever re-run (a
    # tighter budget splits chunks across steps, changing pacing, never
    # tokens: test_chunked_budget_through_engine)
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=chunk,
                 token_budget=1000)
    got = _drain(eng, reqs)
    assert [r.out_tokens for r in got] == greedy
    assert eng.pool.num_used == 0
    for r, (p, _) in zip(got, reqs):
        assert r.chunk_steps == ceil_div(p.shape[0], eng.chunk_tokens)


def test_chunked_matches_monolithic_sampled(smollm, mono_outputs):
    """Sampling keys are (seed, rid, position)-derived, so chunking must be
    invisible to sampled continuations too."""
    cfg, m, params = smollm
    reqs, _, sampled = mono_outputs
    eng = Engine(m, params, max_slots=3, chunk_tokens=16)
    assert [r.out_tokens for r in
            _drain(eng, reqs, greedy=False, seed=7)] == sampled


@pytest.mark.slow
def test_chunked_preemption_token_identical(smollm):
    """A pool at ~half the working set forces preemptions (folds) and
    pauses mid-prefill; the chunked engine must still reproduce the
    ample-pool monolithic outputs exactly, and balance the pool."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, [4, 25, 6, 30, 4, 5], seed=3),
                    [16, 10, 16, 8, 16, 16]))
    ample = Engine(m, params, max_slots=3, page_tokens=8)
    want = [r.out_tokens for r in _drain(ample, reqs)]

    tight = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6,
                   chunk_tokens=8)
    got = _drain(tight, reqs)
    assert [r.out_tokens for r in got] == want
    assert tight.num_preemptions >= 1
    assert tight.pool.num_used == 0
    assert tight.pool.total_allocs == tight.pool.total_frees
    assert tight.scheduler.num_free_slots == 3


# ---------------------------------------------------------------------------
# pause/resume: a displaced mid-prefill request keeps its pages + cursor
# ---------------------------------------------------------------------------

def _req(rid, plen, max_new, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new=max_new, arrival=arrival)


def test_pause_keeps_pages_and_cursor():
    """grow() displacing a mid-prefill victim must pause it — slot
    returned, pages and cursor intact — and walk on to a decoding victim
    for the actual pages (pausing frees none); re-admission then resumes
    from the cursor with the very same pages."""
    pool = PagedKVPool(1 + 8, 8)
    sched = Scheduler(max_slots=3, pool=pool, max_len=64, chunk_tokens=8)
    a, c, b = _req(0, 8, 40), _req(1, 8, 9), _req(2, 40, 4)
    for r in (a, c, b):
        sched.add(r)
    assert len(sched.admit()) == 3
    assert {r.status for r in (a, c, b)} == {"prefilling"}
    assert pool.num_used == 0            # chunked admission books nothing yet

    # a and c finish their one-chunk prompts and decode; b is mid-prefill
    # with two chunks written (2 pages, cursor 16)
    assert sched.plan_chunks(100) == {a.slot: 8, c.slot: 8, b.slot: 8}
    for r in (a, c):
        r.prefill_cursor = r.len = 8
        r.status = "running"
        r.out_tokens.append(7)
    b.prefill_cursor = b.len = 8         # the engine advances cursors
    assert sched.plan_chunks(100) == {b.slot: 8}
    b.prefill_cursor = b.len = 16
    held = list(b.pages.pages)
    assert pool.num_used == 4 and len(held) == 2

    # a grows to 41 tokens: 5 new pages against 4 free.  The youngest
    # victim is b, mid-prefill: paused (pages kept) — then c, decoding:
    # preempted (pages released) — and a's growth succeeds.
    a.len, a.out_tokens = 40, [7] * 33
    displaced = sched.grow()
    assert displaced == [b, c]
    assert b.status == "waiting" and b.preempted and b.slot == -1
    assert b.num_pauses == 1 and sched.num_pauses == 1
    assert b.num_preemptions == 0        # b's pages were NOT released
    assert b.prefill_cursor == 16 and b.pages.pages == held
    assert c.status == "waiting" and c.pages is None     # true preemption
    assert sched.num_preemptions == 1
    assert a.status == "running" and len(a.pages.pages) == 6
    assert sched.waiting[0] is c and sched.waiting[1] is b

    # once a finishes, both resume; b picks up from its cursor with the
    # same pages and books only the next chunk
    sched.finish(a)
    assert sched.admit() == [c, b]
    assert b.status == "prefilling" and b.prefill_cursor == 16
    assert b.pages.pages == held
    plan = sched.plan_chunks(100)
    assert plan[b.slot] == 8
    assert b.pages.pages[:2] == held and len(b.pages.pages) == 3


def test_reclaim_releases_paused_pages_when_solo():
    """Termination fallback: when the sole running request cannot grow and
    the remaining pages belong to a paused waiter, the waiter's pages are
    reclaimed (cursor reset — a true preemption) rather than deadlocking or
    self-preempting."""
    pool = PagedKVPool(1 + 4, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=64, chunk_tokens=8)
    a, b = _req(0, 4, 29), _req(1, 24, 4)
    sched.add(a)
    sched.add(b)
    assert len(sched.admit()) == 2
    assert sched.plan_chunks(100) == {a.slot: 4, b.slot: 8}
    a.prefill_cursor = a.len = 4
    a.status = "running"
    a.out_tokens.append(7)
    b.prefill_cursor = b.len = 8         # the engine advances cursors

    # a needs 3 new pages against 2 free: b (youngest, mid-prefill) is
    # paused — which frees nothing — leaving a as its own youngest victim,
    # so the fallback reclaims the paused b's page (cursor reset, a true
    # preemption) instead of self-preempting the oldest request
    a.len, a.out_tokens = 24, [7] * 21
    assert sched.grow() == [b]
    assert b.status == "waiting" and b.num_pauses == 1
    assert a.status == "running" and len(a.pages.pages) == 4
    assert b.pages.pages == [] and b.prefill_cursor == 0 and b.len == 0
    assert b.num_preemptions == 1 and sched.num_preemptions == 1


def test_admission_reclaims_paused_pages_when_idle():
    """Liveness hole regression: with nothing running and every page held
    by paused waiters, admit() must reclaim behind the queue head (never
    the head itself — its held pages reduce its need) instead of hanging a
    drain forever.  Needs a chunk spanning >1 page so the head's next
    chunk can outsize the free list."""
    pool = PagedKVPool(1 + 4, 16)                # 4 usable pages = 64 tokens
    sched = Scheduler(max_slots=2, pool=pool, max_len=64, chunk_tokens=32)
    a, b = _req(0, 48, 4), _req(1, 33, 4)
    sched.add(a)
    sched.add(b)
    assert len(sched.admit()) == 2
    assert sched.plan_chunks(100) == {a.slot: 32, b.slot: 32}
    a.prefill_cursor = a.len = 32                # 2 pages each: pool full
    b.prefill_cursor = b.len = 32
    sched._pause(b)
    sched._pause(a)
    assert not sched.running and pool.num_free == 0
    assert [r.rid for r in sched.waiting] == [0, 1]

    # head a needs 1 more page for its final chunk; only paused b holds
    # pages — admission must reclaim b (cursor reset), keep a's pages, and
    # resume a from its cursor
    held = list(a.pages.pages)
    assert sched.admit() == [a]
    assert a.prefill_cursor == 32 and a.pages.pages == held
    assert b.pages.pages == [] and b.prefill_cursor == 0
    assert b.num_preemptions == 1
    assert sched.plan_chunks(100) == {a.slot: 16}    # final 48-32 remainder


def test_pause_resume_through_engine_no_rework(smollm):
    """End to end: a long prompt whose chunked prefill stalls behind a
    decode-heavy neighbour must finish in exactly ceil(len/chunk) fused
    steps — stall-and-resume keeps the cursor and never re-runs a written
    chunk — with outputs identical to the ample-pool monolithic run."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, [6, 40], seed=5), [10, 4]))
    ample = Engine(m, params, max_slots=2, page_tokens=8)
    want = [r.out_tokens for r in _drain(ample, reqs)]

    eng = Engine(m, params, max_slots=2, page_tokens=8, num_pages=1 + 6,
                 chunk_tokens=8, token_budget=100)   # page-driven stalls only
    got = _drain(eng, reqs)
    assert [r.out_tokens for r in got] == want
    long = got[1]
    assert long.num_preemptions == 0, \
        "sizing drifted: the long prompt should stall/pause, not recompute"
    assert long.chunk_steps == ceil_div(40, 8)
    assert eng.scheduler.prefill_stall_steps >= 1 or long.num_pauses >= 1


# ---------------------------------------------------------------------------
# admission, alignment, budget
# ---------------------------------------------------------------------------

def test_chunk_tokens_rounds_to_m_r(smollm):
    cfg, m, params = smollm
    lay = m.ctx.layout(m.compute_dtype)
    eng = Engine(m, params, chunk_tokens=3)     # deliberately unaligned
    assert eng.chunk_tokens % lay.m_r == 0 and eng.chunk_tokens >= 3
    assert eng.scheduler.chunk_tokens == eng.chunk_tokens
    with pytest.raises(AssertionError, match="at least one token"):
        Engine(m, params, chunk_tokens=0)       # would wedge every prefill


def test_chunked_admission_books_first_chunk_only():
    """Chunked admission must not require (or take) pages for the whole
    prompt: a long prompt admits into a pool that could never hold it all
    at once, and pages arrive chunk by chunk."""
    sched = Scheduler(max_slots=1, pool=PagedKVPool(1 + 6, 8), max_len=64,
                      chunk_tokens=8)
    r = _req(0, 40, 4)                           # prompt alone needs 5 pages
    sched.add(r)
    assert [q.rid for q in sched.admit()] == [0]
    assert sched.pool.num_used == 0              # nothing booked up front
    assert sched.plan_chunks(100) == {r.slot: 8}
    assert sched.pool.num_used == 1              # first chunk's page only
    # monolithic lazy admission books the whole prompt at once
    mono = Scheduler(max_slots=1, pool=PagedKVPool(1 + 6, 8), max_len=64)
    mono.add(_req(0, 40, 4))
    mono.admit()
    assert mono.pool.num_used == 5


def test_token_budget_caps_concurrent_prefill():
    """Two prefilling slots under a budget of one chunk: the older gets the
    full chunk, the younger stalls (0 tokens) — and decodes are never
    budget-stalled (they are subtracted before the plan)."""
    pool = PagedKVPool(1 + 8, 8)
    sched = Scheduler(max_slots=2, pool=pool, max_len=64, chunk_tokens=8)
    a, b = _req(0, 24, 4), _req(1, 24, 4)
    sched.add(a)
    sched.add(b)
    sched.admit()
    assert sched.plan_chunks(8) == {a.slot: 8, b.slot: 0}
    assert sched.prefill_stall_steps == 1
    a.prefill_cursor = a.len = 8
    b.prefill_cursor = b.len = 0
    # a bigger budget feeds both, clipped to the remaining prompt
    assert sched.plan_chunks(12) == {a.slot: 8, b.slot: 4}

    # with a tile alignment (the engine passes the layout m_r), a
    # budget-clamped chunk rounds DOWN so the cursor stays on a tile
    # boundary — a remainder too small for a whole tile stalls instead
    pool2 = PagedKVPool(1 + 8, 8)
    tiled = Scheduler(max_slots=2, pool=pool2, max_len=64,
                      chunk_tokens=16, chunk_align=8)
    c, d = _req(0, 32, 4), _req(1, 32, 4)
    tiled.add(c)
    tiled.add(d)
    tiled.admit()
    assert tiled.plan_chunks(20) == {c.slot: 16, d.slot: 0}   # not 4


def test_chunked_budget_through_engine(smollm):
    """The budget knob must not change tokens, only pacing: serving with a
    budget of one chunk per step equals the unbounded-budget outputs."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, [13, 21, 9], seed=9), [6, 5, 7]))
    wide = Engine(m, params, max_slots=3, chunk_tokens=8)
    want = [r.out_tokens for r in _drain(wide, reqs)]
    narrow = Engine(m, params, max_slots=3, chunk_tokens=8,
                    token_budget=8 + 3)
    assert [r.out_tokens for r in _drain(narrow, reqs)] == want


def test_hybrid_families_refuse_chunking(smollm):
    cfg = reduced_config(get_config("rwkv6-1.6b"))
    shape = ShapeSpec("serve", 64, 2, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="chunked prefill"):
        Engine(m, params, chunk_tokens=8)


# ---------------------------------------------------------------------------
# warmup: the no-recompile contract
# ---------------------------------------------------------------------------

def test_no_compiles_after_warmup_chunked(smollm):
    """The fused engine's step shapes are the geometric ladder
    ([slots, chunk] .. [slots, m_r], plus [slots, 1]); after warmup, a
    trace with admissions, chunked prefills, stalls, growth and preemption
    must trigger zero new XLA traces."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6,
                 chunk_tokens=8)
    eng.warmup()
    assert eng.pool.num_used == 0 and eng.pool.total_allocs == 0
    before = dict(m.trace_counts)
    reqs = list(zip(_prompts(cfg, [4, 25, 6, 30], seed=3), [16, 10, 16, 8]))
    fin = _drain(eng, reqs)
    assert eng.num_preemptions + eng.num_pauses >= 1
    assert sum(len(r.out_tokens) for r in fin) == 16 + 10 + 16 + 8
    assert dict(m.trace_counts) == before, \
        "Engine.step compiled a new shape after warmup()"
    assert eng.stats()["compiles"] == before


def test_no_compiles_after_warmup_monolithic(smollm):
    """The baseline policy keeps its contract too: geometric buckets plus
    the decode step cover every monolithic trace, including recompute
    prefills of fold-extended prompts."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, num_pages=1 + 6)
    eng.warmup()
    before = dict(m.trace_counts)
    reqs = list(zip(_prompts(cfg, [4, 25, 6, 30], seed=3), [16, 10, 16, 8]))
    _drain(eng, reqs)
    assert eng.num_preemptions >= 1
    assert dict(m.trace_counts) == before
