"""Layout-aware prefix cache: refcounted sharing, copy-on-write,
cache-backed preemption (PR 5).

Covers the sharing subsystem's contracts:
  - pool refcounts: ``share`` adds references, ``free`` drops them, pages
    return to the free list only at refcount zero, and double-free checks
    extend to shared pages (over-freeing fails loudly);
  - copy-on-write: ``cow`` splits a shared page (device copy via the
    installed ``page_copier``), ``truncate`` never truncates *into* a
    shared page (it CoW-splits the kept tail first);
  - the hash-chain cache: longest-prefix lookup, the ``prompt_len - 1``
    hit cap, layout-keyed roots (no cross-layout aliasing), LRU eviction
    of cache-only pages under pool pressure, in-use pages pinned;
  - allocator-under-sharing property: any interleaving of
    admit/share/grow/truncate/preempt/evict keeps refcounts >= 0, keeps
    alloc+share/free balanced, and never writes a shared page in place;
  - engine integration: cache-on outputs are token-identical to cache-off
    (greedy + sampled, monolithic + chunked, spec-on) at <= 0.5x the
    prefill tokens on a shared-prefix trace; a preempt-resume recomputes
    only the uncached suffix; zero new XLA traces after ``warmup()``;
  - the stats satellite: ``pages_per_request`` and the reserved-page-
    excluding ``free_pages``/``usable_pages`` denominators.
"""

import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import (OutOfPages, PagedKVPool, PoolError,
                                    SequencePages)
from repro.serving.prefix_cache import PrefixCache

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 96, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _tok(n, seed=0):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,),
                                         0, 64), np.int32)


# ---------------------------------------------------------------------------
# pool refcounting + CoW
# ---------------------------------------------------------------------------

def test_refcount_share_free_balance():
    pool = PagedKVPool(1 + 4, 8)
    p = pool.alloc()
    assert pool.ref(p) == 1 and not pool.is_shared(p)
    pool.share([p, p])
    assert pool.ref(p) == 3 and pool.is_shared(p)
    pool.free([p])
    assert pool.ref(p) == 2 and pool.num_used == 1   # still allocated
    pool.free([p, p])
    assert pool.ref(p) == 0 and pool.num_used == 0   # now actually free
    with pytest.raises(PoolError):
        pool.free([p])                               # over-free fails loudly
    with pytest.raises(PoolError):
        pool.share([p])                              # sharing a dead page too
    assert pool.total_allocs + pool.total_shares == pool.total_frees == 3


def test_cow_splits_shared_page_only():
    pool = PagedKVPool(1 + 4, 8)
    copies = []
    pool.page_copier = lambda src, dst: copies.append((src, dst))
    seq = SequencePages(pool)
    seq.ensure(8)
    [p] = seq.pages
    assert pool.cow(seq, 0) == p and not copies      # unshared: no-op
    pool.share([p])                                  # someone else holds it
    q = pool.cow(seq, 0)
    assert q != p and seq.pages == [q]
    assert copies == [(p, q)]                        # device contents copied
    assert pool.ref(p) == 1 and pool.ref(q) == 1     # split: one ref each
    assert pool.cow_copies == 1
    pool.free([p])
    seq.release()
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees


def test_truncate_never_truncates_into_shared_page():
    pool = PagedKVPool(1 + 6, 8)
    pool.page_copier = lambda src, dst: None
    seq = SequencePages(pool)
    seq.ensure(3 * 8)
    tail = seq.pages[-1]
    other = list(seq.pages)
    pool.share(other)                                # all three shared
    # aligned truncation only drops trailing refs — kept pages untouched
    before = list(seq.pages)
    assert seq.truncate(16) == 1
    assert seq.pages == before[:2] and pool.ref(tail) == 1
    # unaligned truncation lands mid-page on a shared page: CoW-split
    kept = seq.pages[1]
    assert seq.truncate(12) == 0                     # no whole page dropped
    assert seq.pages[0] == before[0] and seq.pages[1] != kept
    assert pool.ref(kept) == 1                       # other holder keeps it
    assert pool.cow_copies == 1
    seq.release()
    pool.free(other)
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees


def test_pool_stats_satellites():
    """``pages_per_request`` and the reserved-page-excluding denominators
    (the trash page must never inflate capacity ratios)."""
    pool = PagedKVPool(1 + 8, 8)
    st_ = pool.stats()
    assert st_["usable_pages"] == 8 and st_["reserved_pages"] == 1
    assert st_["free_pages"] == 8 == st_["num_free"]
    assert st_["pages_per_request"] == 0.0 and st_["live_requests"] == 0
    a, b = SequencePages(pool), SequencePages(pool)
    a.ensure(24)                                     # 3 pages
    b.ensure(8)                                      # 1 page
    st_ = pool.stats()
    assert st_["live_requests"] == 2
    assert st_["pages_per_request"] == pytest.approx(2.0)
    assert st_["free_pages"] == 4                    # 8 usable - 4 held
    pool.share([a.pages[0]])
    assert pool.stats()["shared_pages"] == 1
    pool.free([a.pages[0]])
    a.release()
    b.release()
    st_ = pool.stats()
    assert st_["free_pages"] == st_["usable_pages"] == 8


# ---------------------------------------------------------------------------
# the hash-chain cache
# ---------------------------------------------------------------------------

def test_lookup_walks_longest_prefix_and_caps_at_last_token():
    pool = PagedKVPool(1 + 8, 8)
    cache = PrefixCache(pool, layout_key=(4,))
    prompt = _tok(24)                                # 3 exact pages
    seq = SequencePages(pool)
    seq.ensure(24)
    cache.insert(prompt, seq.pages, 24)
    assert cache.stats()["entries"] == 3

    # a diverging prompt matches only the shared blocks
    div = prompt.copy()
    div[20] += 1
    pages, hit = cache.lookup(div)
    assert hit == 16 and pages == seq.pages[:2]
    pool.free(pages)                                 # give the refs back

    # the exact prompt is capped at L-1: all pages shared, cursor mid-page
    pages, hit = cache.lookup(prompt)
    assert hit == 23 and pages == seq.pages
    pool.free(pages)

    # a longer prompt with the cached prefix hits all 3 full pages
    longer = np.concatenate([prompt, _tok(5, seed=9)])
    pages, hit = cache.lookup(longer)
    assert hit == 24 and pages == seq.pages
    pool.free(pages)
    seq.release()
    cache.clear()
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees


def test_layout_key_roots_the_chain():
    """The same token content under a different layout key must miss — a
    layout change can never alias stale KV."""
    pool = PagedKVPool(1 + 8, 8)
    a = PrefixCache(pool, layout_key=(4,))
    b = PrefixCache(pool, layout_key=(8,))
    prompt = _tok(16)
    seq = SequencePages(pool)
    seq.ensure(16)
    a.insert(prompt, seq.pages, 16)
    assert b.lookup(prompt) == ([], 0)
    pages, hit = a.lookup(prompt)
    assert hit == 15 and len(pages) == 2
    pool.free(pages)
    seq.release()
    a.clear()
    assert pool.num_used == 0


def test_eviction_lru_under_pool_pressure_pins_in_use_pages():
    pool = PagedKVPool(1 + 4, 8)
    cache = PrefixCache(pool, layout_key=(4,))
    old, new = _tok(8, seed=1), _tok(8, seed=2)
    s1, s2 = SequencePages(pool), SequencePages(pool)
    s1.ensure(8)
    cache.insert(old, s1.pages, 8)
    pinned = s1.pages[0]                             # s1 still holds it
    s2.ensure(8)
    cache.insert(new, s2.pages, 8)
    s2.release()                                     # cache-only: evictable
    assert cache.evictable() == 1 and pool.num_available == 3
    # pool pressure: allocating all remaining pages auto-evicts `new`
    s3 = SequencePages(pool)
    s3.ensure(3 * 8)
    assert cache.evictions == 1
    assert cache.lookup(new) == ([], 0)              # LRU victim gone
    assert pool.ref(pinned) == 2                     # in-use page survived
    pages, hit = cache.lookup(old)
    assert hit == 7 and pages == [pinned]
    pool.free(pages)
    # with everything pinned or handed out, exhaustion still fails loudly
    with pytest.raises(OutOfPages):
        s3.ensure(4 * 8)
    s1.release()
    s3.release()
    cache.clear()
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees


# ---------------------------------------------------------------------------
# allocator-under-sharing property (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), usable=st.integers(4, 12))
def test_property_sharing_interleaving_keeps_invariants(seed, usable):
    """Any interleaving of admit / share(lookup+insert) / grow / truncate /
    preempt-into-cache / evict keeps refcounts >= 0 (over-free asserts
    inside the pool), keeps allocs+shares balanced against frees once
    everything is released, and never writes a shared page in place (every
    simulated KV write asserts its target page has refcount 1)."""
    rng = random.Random(seed)
    t = 8
    pool = PagedKVPool(1 + usable, t)
    pool.page_copier = lambda src, dst: None
    cache = PrefixCache(pool, layout_key=(4,))

    def write(seq, pos):
        # the invariant under test: the page a position is written into is
        # never shared (prefill/decode writes follow CoW or fresh pages)
        page = seq.pages[pos // t]
        assert pool.ref(page) == 1, \
            f"write at {pos} would hit shared page {page}"

    live = []      # [prompt, seq, len]  (len = tokens with simulated KV)

    def admit():
        plen = rng.randrange(2, 3 * t)
        if rng.random() < 0.6 and live:              # shared-prefix arrival
            donor = rng.choice(live)[0]
            cut = rng.randrange(1, len(donor) + 1)
            prompt = np.concatenate([donor[:cut], _tok(plen, seed=rng.
                                                       randrange(999))])[:plen]
        else:
            prompt = _tok(plen, seed=rng.randrange(999))
        seq = SequencePages(pool)
        pages, hit = cache.lookup(prompt)
        seq.pages = pages
        if hit % t:
            try:
                pool.cow(seq, len(pages) - 1)
            except OutOfPages:
                pool.free([seq.pages.pop()])
                hit = len(seq.pages) * t
        try:
            seq.ensure(plen)
        except OutOfPages:                           # admission blocked
            seq.release()
            return
        for pos in range(hit, plen):                 # prefill the suffix
            write(seq, pos)
        cache.insert(prompt, seq.pages, plen)
        live.append([prompt, seq, plen])

    def grow():
        if not live:
            return
        r = rng.choice(live)
        try:
            r[1].ensure(r[2] + 1)
        except OutOfPages:
            return
        if r[2] < len(r[0]):                         # keep prompt keys honest
            r[0] = np.concatenate([r[0], _tok(1, seed=rng.randrange(999))])
        write(r[1], r[2])
        r[2] += 1

    def truncate():
        if not live:
            return
        r = rng.choice(live)
        if r[2] <= 1:
            return
        new_len = rng.randrange(1, r[2])
        try:
            r[1].truncate(new_len)
        except OutOfPages:                           # CoW split had no page
            return
        r[2] = new_len

    def preempt():
        if not live:
            return
        r = live.pop(rng.randrange(len(live)))
        cache.insert(r[0], r[1].pages, min(r[2], len(r[0])))
        r[1].release()

    def evict():
        cache.evict(rng.randrange(1, 3))

    ops = [admit, grow, truncate, preempt, evict]
    for _ in range(60):
        rng.choice(ops)()
        assert all(v >= 1 for v in pool._ref.values())
        assert pool.num_used + pool.num_free == pool.usable_pages

    for _, seq, _ in live:
        seq.release()
    cache.clear()
    assert cache.evictable() == 0
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _shared_prefix_trace(cfg, n=6, sys_tokens=40):
    key = jax.random.PRNGKey(3)
    sysp = np.asarray(jax.random.randint(key, (sys_tokens,), 0, cfg.vocab))
    reqs = []
    for i in range(n):
        sfx = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                            (3 + i % 3,), 0, cfg.vocab))
        reqs.append((np.concatenate([sysp, sfx]), 5 + i % 3))
    return reqs


def _drain_staggered(eng, reqs, *, greedy=True, seed=0):
    for i, (p, n) in enumerate(reqs):
        eng.add_request(p, n, arrival=float(2 * i))
    clock, fin = 0.0, {}
    while eng.scheduler.has_work:
        fin.update((r.rid, r.out_tokens)
                   for r in eng.step(now=clock, greedy=greedy, seed=seed))
        clock += 1.0
    return [fin[i] for i in sorted(fin)]


@pytest.mark.parametrize("greedy", [True, False], ids=["greedy", "sampled"])
@pytest.mark.parametrize("kw", [dict(), dict(chunk_tokens=16),
                                dict(spec_tokens=2)],
                         ids=["monolithic", "chunked", "spec"])
def test_cache_on_token_identical_and_halves_prefill(smollm, greedy, kw):
    """The tentpole contract: cache-on outputs are bit-identical to
    cache-off — both prefill policies, speculation on, greedy and sampled —
    at <= 0.5x the prefill tokens on a shared-system-prompt trace, with
    the pool balanced once the cache is cleared."""
    cfg, m, params = smollm
    reqs = _shared_prefix_trace(cfg)
    base = Engine(m, params, max_slots=3, page_tokens=16)
    want = _drain_staggered(base, reqs, greedy=greedy, seed=7)
    off_tokens = base.stats()["prefill_tokens"]
    assert off_tokens == sum(p.shape[0] for p, _ in reqs)

    eng = Engine(m, params, max_slots=3, page_tokens=16, prefix_cache=True,
                 **kw)
    got = _drain_staggered(eng, reqs, greedy=greedy, seed=7)
    assert got == want, "prefix cache changed tokens"
    st_ = eng.stats()
    assert st_["prefill_tokens"] <= 0.5 * off_tokens, \
        (st_["prefill_tokens"], off_tokens)
    assert st_["prefix_cache"]["hits"] >= len(reqs) - 1
    eng.prefix_cache.clear()
    assert eng.pool.num_used == 0
    assert eng.pool.total_allocs + eng.pool.total_shares \
        == eng.pool.total_frees


@pytest.mark.slow
def test_fully_cached_prompt_cow_splits_last_page(smollm):
    """A page-aligned, fully-cached prompt admits at cursor L-1 (the last
    position's logits feed the first pick) — the one in-place write into a
    shared page, so it must CoW-split, and tokens must not change."""
    cfg, m, params = smollm
    p32 = _tok(32, seed=5) % cfg.vocab               # 2 exact 16-token pages
    base = Engine(m, params, max_slots=2, page_tokens=16)
    base.add_request(p32, 5)
    base.add_request(p32, 5)
    want = [r.out_tokens for r in sorted(base.drain(), key=lambda r: r.rid)]

    eng = Engine(m, params, max_slots=2, page_tokens=16, prefix_cache=True)
    eng.add_request(p32, 5)
    eng.step()                                       # r0 prefills + inserts
    eng.add_request(p32, 5)
    fin = {r.rid: r.out_tokens for r in eng.drain()}
    assert [fin[0], fin[1]] == want
    pc = eng.stats()["prefix_cache"]
    assert pc["cow_copies"] == 1 and pc["hit_tokens"] == 31
    eng.prefix_cache.clear()
    assert eng.pool.num_used == 0


@pytest.mark.slow
def test_preempt_resume_recomputes_only_uncached_suffix(smollm):
    """Preemption releases pages into the cache, so a resume's prefill
    covers at most the tokens generated since its last admission plus one
    partial page — not the whole folded prompt (the PR-2 fold path is now
    a cache hit).  Outputs stay identical to an uninterrupted run."""
    cfg, m, params = smollm
    key = jax.random.PRNGKey(11)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                             (l,), 0, cfg.vocab))
               for i, l in enumerate([6, 5])]
    ample = Engine(m, params, max_slots=2, page_tokens=8)
    rids = [ample.add_request(p, 12) for p in prompts]
    want = {r.rid: r.out_tokens for r in ample.drain()}

    tight = Engine(m, params, max_slots=2, page_tokens=8, num_pages=1 + 4,
                   prefix_cache=True)
    rids2 = [tight.add_request(p, 12) for p in prompts]
    fin = {r.rid: r for r in tight.drain()}
    assert tight.num_preemptions >= 1
    for rid, rid2 in zip(rids, rids2):
        assert fin[rid2].out_tokens == want[rid]
    events = tight.scheduler.resume_events
    assert events, "preemption under a prefix cache must record resumes"
    for e in events:
        # a reclaim or a pool-pressure eviction legitimately loses the
        # cached prefix (identity still holds); otherwise the bound applies
        assert e["reclaimed"] or e["evicted"] or \
            e["recompute"] <= e["generated_since"] + tight.pool.page_tokens, e
    assert any(not e["reclaimed"] and not e["evicted"] for e in events), \
        "at least one resume should have found its pages cached"
    tight.prefix_cache.clear()
    assert tight.pool.num_used == 0
    assert tight.pool.total_allocs + tight.pool.total_shares \
        == tight.pool.total_frees


@pytest.mark.parametrize("kw", [dict(), dict(chunk_tokens=16)],
                         ids=["monolithic", "chunked"])
def test_zero_recompile_after_warmup_with_cache(smollm, kw):
    """The no-recompile contract survives the cache: hits, CoW splits and
    evictions introduce no new step shapes (the CoW copy program is primed
    by warmup)."""
    cfg, m, params = smollm
    reqs = _shared_prefix_trace(cfg, n=4)
    eng = Engine(m, params, max_slots=3, page_tokens=16, prefix_cache=True,
                 **kw)
    eng.warmup()
    compiles = dict(m.trace_counts)
    _drain_staggered(eng, reqs)
    assert dict(m.trace_counts) == compiles, \
        "prefix-cache serving compiled a new XLA program after warmup()"


def test_prefix_cache_rejected_configs(smollm):
    cfg, m, params = smollm
    with pytest.raises(AssertionError):
        Engine(m, params, eager=True, prefix_cache=True)
