"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_layout, packing, presets
from repro.kernels.mmt4d.ops import mmt4d as mmt4d_op
from repro.kernels.mmt4d.ref import mmt4d_ref
from repro.kernels.pack.ops import pack as pack_op
from repro.kernels.pack.ref import pack_ref
from repro.kernels.unpack.ops import unpack as unpack_op
from repro.kernels.unpack.ref import unpack_ref

SHAPES = [(64, 256, 384), (37, 200, 130), (8, 128, 128), (130, 520, 260),
          (1, 128, 640), (257, 129, 65)]
DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)]


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype,rtol", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("mkn", SHAPES, ids=[str(s) for s in SHAPES])
def test_mmt4d_kernel_matches_ref(mkn, dtype, rtol):
    m, k, n = mkn
    lay = make_layout("scalable", presets["tpu_v5e"], dtype)
    ap = packing.pack_lhs(_rand(0, (m, k), dtype), lay)
    bp = packing.pack_rhs(_rand(1, (k, n), dtype), lay)
    got = mmt4d_op(ap, bp)
    want = mmt4d_ref(ap, bp)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol * 50)


@pytest.mark.parametrize("act", [None, "gelu", "silu", "relu"])
def test_mmt4d_fused_epilogue(act):
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    ap = packing.pack_lhs(_rand(0, (40, 200), jnp.float32), lay)
    bp = packing.pack_rhs(_rand(1, (200, 72), jnp.float32), lay)
    bias = packing.pad_to_tiles(_rand(2, (1, 72), jnp.float32), 1,
                                lay.n_r).reshape(-1, lay.n_r)
    got = mmt4d_op(ap, bp, bias, activation=act)
    want = mmt4d_ref(ap, bp, bias, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype,_", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("mk", [(64, 256), (37, 200), (8, 128), (130, 520),
                                (1, 1), (1000, 3)])
def test_pack_kernel_matches_ref(mk, dtype, _):
    m, k = mk
    lay = make_layout("scalable", presets["tpu_v5e"], dtype)
    a = _rand(0, (m, k), dtype)
    got = pack_op(a, lay.m_r, lay.k_r)
    want = pack_ref(a, lay.m_r, lay.k_r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mk", [(64, 256), (37, 200), (130, 520), (1, 1)])
def test_unpack_kernel_matches_ref(mk):
    m, k = mk
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    ap = packing.pack_lhs(_rand(0, (m, k), jnp.float32), lay)
    got = unpack_op(ap, m, k)
    want = unpack_ref(ap, m, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_rand(0, (m, k), jnp.float32)))


def test_kernel_roundtrip_pipeline():
    """pack -> mmt4d -> unpack (all Pallas) == jnp.dot."""
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    a = _rand(0, (100, 300), jnp.float32)
    b = _rand(1, (300, 200), jnp.float32)
    ap = pack_op(a, lay.m_r, lay.k_r)
    bp = pack_op(jnp.swapaxes(b, 0, 1), lay.n_r, lay.k_r)
    cp = mmt4d_op(ap, bp)
    c = unpack_op(cp.reshape(cp.shape[0], cp.shape[1], lay.m_r, lay.n_r),
                  100, 200)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b),
                               rtol=1e-5, atol=1e-4)


def test_vl_scaling_kernels():
    """Same kernel code at VL in {128,256,512} (Fig 3 premise)."""
    a = _rand(0, (64, 512), jnp.float32)
    b = _rand(1, (512, 256), jnp.float32)
    ref = a @ b
    for hw in ("tpu_vl128", "tpu_vl256", "tpu_vl512"):
        lay = make_layout("scalable", presets[hw], jnp.float32)
        ap = packing.pack_lhs(a, lay)
        bp = packing.pack_rhs(b, lay)
        cp = mmt4d_op(ap, bp, hw=presets[hw])
        got = packing.unpack_out(cp, 64, 256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)
