"""Overload and fault-tolerance contracts (PR 8).

Covers the resilience layer end to end:
  - identity under chaos: for seeded fault plans (OutOfPages spikes,
    drafter failures mid-spec, NaN-logit injection, page-copier
    failures), every surviving request's tokens are bit-identical to the
    fault-free run — chunked and flat steps, greedy and sampled picks,
    prefix cache on and off — and the allocator is balanced afterwards;
  - cancellation from every lifecycle state releases every page
    (property test interleaving admit/chunk/spec/preempt/cancel over the
    real Scheduler, extending the PR-5 allocator property);
  - deadlines and admission control: ``deadline_s``/``max_queue_s``
    produce ``timeout`` rows, a bounded queue produces fast ``rejected``
    rows, and ``drain``/``generate`` pad both exactly like eos rows;
  - the degradation ladder: repeated drafter failure auto-disables
    speculation for the drain, a NaN row is quarantined without
    poisoning the prefix cache, and a stuck drain raises a diagnosable
    ``StallError`` naming the non-advancing rids;
  - ``FaultPlan`` replayability: same seed, same events.
"""

import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.aliasing import check_pool_consistency
from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.faults import (FaultEvent, FaultPlan, InjectedFault,
                                  StallError)
from repro.serving.kv_cache import OutOfPages, PagedKVPool, PoolError
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import AdmissionError, Request, Scheduler

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain_outputs(engine, prompts, news, *, greedy=True, plan=None, seed=0):
    for p, n in zip(prompts, news):
        engine.add_request(p, n)
    if plan is not None:
        with plan.on(engine):
            fin = engine.drain(greedy=greedy, seed=seed)
    else:
        fin = engine.drain(greedy=greedy, seed=seed)
    return {r.rid: (list(r.out_tokens), r.finish_reason) for r in fin}


# ---------------------------------------------------------------------------
# identity under chaos (tentpole headline invariant)
# ---------------------------------------------------------------------------

_PLANS = {
    "oom-spike": lambda: FaultPlan([FaultEvent(1, "oom"), FaultEvent(2, "oom"),
                                    FaultEvent(4, "oom")]),
    "drafter-mid-spec": lambda: FaultPlan(
        [FaultEvent(s, "drafter") for s in (1, 2, 3, 5, 7)]),
    "nan-quarantine": lambda: FaultPlan([FaultEvent(3, "nan")]),
    "copier-failure": lambda: FaultPlan([FaultEvent(1, "copier"),
                                         FaultEvent(3, "copier")]),
}

_CONFIGS = {
    "chunked-greedy-cache": (dict(chunk_tokens=8, flat=False,
                                  prefix_cache=True), True),
    "flat-sampled": (dict(chunk_tokens=8), False),
    "flat-spec-greedy-cache": (dict(chunk_tokens=8, spec_tokens=3,
                                    prefix_cache=True), True),
}


@pytest.mark.parametrize("config", sorted(_CONFIGS))
def test_chaos_identity(smollm, config):
    """Every surviving request of a faulted drain is token-identical to
    the fault-free drain, and the allocator audits clean afterwards."""
    cfg, m, params = smollm
    kwargs, greedy = _CONFIGS[config]
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    prompts = _prompts(cfg, lens)

    clean = _drain_outputs(Engine(m, params, max_slots=3, **kwargs),
                           prompts, news, greedy=greedy)
    assert all(reason in ("length", "eos") for _, reason in clean.values())

    for name, make_plan in sorted(_PLANS.items()):
        eng = Engine(m, params, max_slots=3, **kwargs)
        plan = make_plan()
        out = _drain_outputs(eng, prompts, news, greedy=greedy, plan=plan)
        assert set(out) == set(clean), f"{name}: lost requests"
        for rid, (toks, reason) in out.items():
            if reason == "error":
                continue                       # quarantined casualty
            assert (toks, reason) == clean[rid], \
                f"{name}: surviving rid {rid} diverged from the clean run"
        # allocator balanced: no leaked pages, ledger consistent, no
        # retired rid holding pages
        assert not check_pool_consistency(eng, f"chaos:{name}")
        live = sum(len(s.pages) for s in eng.pool.sequences())
        cached = (len(set(eng.prefix_cache.pages()))
                  if eng.prefix_cache is not None else 0)
        assert eng.pool.num_used == live + cached == cached


def test_chaos_zero_retrace_after_warmup(smollm):
    """Fault handling must ride the warmed shapes: quarantine, rollback
    and preemption change host bookkeeping, never the compiled step."""
    cfg, m, params = smollm
    lens, news = [5, 11, 8, 3], [6, 4, 9, 7]
    prompts = _prompts(cfg, lens)
    eng = Engine(m, params, max_slots=3, chunk_tokens=8, spec_tokens=3,
                 prefix_cache=True)
    eng.warmup()
    before = sum(m.trace_counts.values())
    plan = FaultPlan([FaultEvent(1, "oom"), FaultEvent(2, "drafter"),
                      FaultEvent(3, "nan"), FaultEvent(4, "copier"),
                      FaultEvent(5, "drafter")])
    _drain_outputs(eng, prompts, news, plan=plan)
    assert sum(m.trace_counts.values()) == before, \
        "a faulted drain recompiled after warmup"


def test_nan_quarantine_frees_pages_and_skips_cache(smollm):
    """The quarantined row finishes with ``error``, its pages are freed,
    and the prefix cache gains nothing from it."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [9, 6])
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, prefix_cache=True)
    plan = FaultPlan([FaultEvent(1, "nan")])
    out = _drain_outputs(eng, prompts, [5, 5], plan=plan)
    dead = [rid for rid, (_, reason) in out.items() if reason == "error"]
    assert len(dead) == 1 and plan.fired["nan"] == 1
    toks, _ = out[dead[0]]
    assert eng.stats()["resilience"]["quarantines"] == 1
    assert not check_pool_consistency(eng, "nan-quarantine")
    # no sequence of the dead rid holds pages
    assert not any(s.pages for s in eng.pool.sequences()
                   if s.owner == dead[0])


def test_drafter_auto_disable_counts_and_resets(smollm):
    """Three consecutive drafter failures disable speculation for the
    rest of the drain; the next drain gets the drafter back."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [5, 8])
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, spec_tokens=3)
    plan = FaultPlan([FaultEvent(s, "drafter") for s in range(1, 12)])
    out = _drain_outputs(eng, prompts, [8, 8], plan=plan)
    res = eng.stats()["resilience"]
    assert res["spec_auto_disables"] == 1
    assert res["drafter_errors"] == eng._drafter_fail_limit, \
        "auto-disable must stop calling the broken drafter"
    assert not res["spec_disabled"], "the disable is per-drain"
    assert all(reason == "length" for _, reason in out.values())
    # a fresh drain actually speculates again
    clean = _drain_outputs(eng, _prompts(cfg, [7]), [6])
    assert eng._drafted > 0


# ---------------------------------------------------------------------------
# deadlines / admission control / padding
# ---------------------------------------------------------------------------

def test_deadline_and_max_queue_timeouts(smollm):
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6, 6, 6])
    eng = Engine(m, params, max_slots=2, chunk_tokens=8)
    eng.add_request(prompts[0], 4)
    eng.add_request(prompts[1], 4, deadline_s=0.5, arrival=0.0)
    eng.add_request(prompts[2], 4, max_queue_s=0.25, arrival=0.0)
    fin = {}
    # t=0: all live, third may admit or queue; t=1.0: both bounds elapsed
    for now in (0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        for r in eng.step(now=now):
            fin[r.rid] = r.finish_reason
    while eng.scheduler.has_work:
        for r in eng.step(now=9.0):
            fin[r.rid] = r.finish_reason
    assert fin[0] == "length"
    assert fin[1] in ("timeout", "length")       # raced its own decode
    res = eng.stats()["resilience"]
    assert res["timeouts"] >= 1
    assert not check_pool_consistency(eng, "timeouts")


def test_bounded_queue_sheds_with_typed_rejections(smollm):
    cfg, m, params = smollm
    prompts = _prompts(cfg, [4] * 6)
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, queue_limit=2)
    out = _drain_outputs(eng, prompts, [3] * 6)
    reasons = [reason for _, reason in out.values()]
    assert reasons.count("rejected") == 4, \
        "adds beyond queue_limit=2 must shed (none were admitted yet)"
    assert eng.stats()["resilience"]["sheds"] == 4
    # rejected rows never touched the pool
    assert not check_pool_consistency(eng, "shed")

    # the page-demand signal sheds on predicted demand, typed kind
    sched = Scheduler(2, PagedKVPool(1 + 4, 8), 48, queue_pages=2)
    sched.add(Request(rid=0, prompt=np.zeros(16, np.int32), max_new=4))
    with pytest.raises(AdmissionError) as e:
        sched.add(Request(rid=1, prompt=np.zeros(16, np.int32), max_new=4))
    assert e.value.kind == "page-demand" and e.value.rid == 1
    # an impossible request still raises out of Engine.add_request
    eng2 = Engine(m, params, max_slots=2, chunk_tokens=8, queue_limit=2)
    with pytest.raises(AdmissionError) as e2:
        eng2.add_request(np.zeros(80, np.int32), 60)
    assert e2.value.kind == "impossible"


def test_generate_pads_timeout_and_rejected_rows(smollm):
    """The PR-2 ragged ``np.stack`` fix extended: timeout/rejected/error
    rows pad to full width exactly like eos rows, and the undisturbed
    continuous result agrees with ``generate_static``."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, [6] * 4)
    batch = {"tokens": np.stack(prompts)}

    eng = Engine(m, params, max_slots=2, chunk_tokens=8, queue_limit=1)
    out, reasons = eng.generate(batch, 5, eos_id=7, return_reasons=True)
    assert out.shape == (4, 5), "shed rows must not produce ragged output"
    for i, reason in enumerate(reasons):
        if reason == "rejected":
            assert (out[i] == 7).all(), "shed rows pad with eos_id"
    # all four are added before any step runs, so one queues and the
    # rest shed at the bounded queue
    assert reasons.count("rejected") == 3

    # a deadline that can never fire leaves generate() == the static path
    eng2 = Engine(m, params, max_slots=4, chunk_tokens=8)
    timed = eng2.generate(batch, 5, deadline_s=3600.0)
    static = np.asarray(eng2.generate_static(batch, 5))
    np.testing.assert_array_equal(timed, static)

    # an already-elapsed deadline times every row out, still full width
    eng3 = Engine(m, params, max_slots=4, chunk_tokens=8)
    out3, reasons3 = eng3.generate(batch, 5, eos_id=7, deadline_s=0.0,
                                   return_reasons=True)
    assert out3.shape == (4, 5) and set(reasons3) == {"timeout"}
    assert (out3 == 7).all()
    assert not check_pool_consistency(eng3, "all-timeout")


def test_cancel_from_queued_prefilling_and_decoding(smollm):
    cfg, m, params = smollm
    prompts = _prompts(cfg, [20, 6, 5])
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, token_budget=8)
    rids = [eng.add_request(p, n) for p, n in zip(prompts, (6, 6, 6))]
    assert eng.cancel(rids[2])                   # queued (never admitted)
    fin0 = eng.step()                            # delivers the cancel
    assert eng.scheduler.running, "admission should have happened"
    statuses = {r.rid: r.status for r in eng.scheduler.running.values()}
    assert statuses.get(rids[0]) == "prefilling", \
        "the 20-token prompt must still be mid-chunk at an 8-token budget"
    assert eng.cancel(rids[0])                   # prefilling, pages held
    fin = {r.rid: r.finish_reason for r in fin0 + eng.drain()}
    assert fin[rids[0]] == "cancelled" and fin[rids[2]] == "cancelled"
    assert fin[rids[1]] == "length"              # decodes to completion
    assert not eng.cancel(rids[1]), "finished rids are not cancellable"
    res = eng.stats()["resilience"]
    assert res["cancels"] == 2 and eng.pool.num_used == 0
    assert not check_pool_consistency(eng, "cancel-states")


def test_watchdog_turns_stuck_drain_into_stall_error(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=2, chunk_tokens=8, watchdog_steps=5)
    orig_alloc = eng.pool.alloc

    def dead_alloc(*a, **k):
        raise OutOfPages("wedged pool (test)")
    eng.pool.alloc = dead_alloc
    rid = eng.add_request(_prompts(cfg, [6])[0], 4)
    with pytest.raises(StallError) as e:
        eng.drain()
    assert f"rid {rid}" in str(e.value)
    assert eng.stats()["resilience"]["watchdog_trips"] == 1
    eng.pool.alloc = orig_alloc
    fin = eng.drain()                            # recovers once unwedged
    assert [r.finish_reason for r in fin] == ["length"]


def test_fault_plan_is_replayable():
    a, b = FaultPlan.random(11, steps=20), FaultPlan.random(11, steps=20)
    assert a.events == b.events
    assert FaultPlan.random(12, steps=20).events != a.events
    with pytest.raises(ValueError):
        FaultPlan([FaultEvent(1, "segfault")])


# ---------------------------------------------------------------------------
# cancellation property (extends the PR-5 allocator property)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), usable=st.integers(5, 12))
def test_property_cancel_interleaving_keeps_invariants(seed, usable):
    """Any interleaving of admit / chunked prefill / decode growth /
    spec-rollback / preempt / cancel over the real Scheduler keeps
    allocs+shares balanced against frees, refcounts >= 1 for live pages,
    and no cancelled request's pages live — from *any* lifecycle state."""
    rng = random.Random(seed)
    t = 8
    pool = PagedKVPool(1 + usable, t)
    pool.page_copier = lambda src, dst: None
    cache = PrefixCache(pool, layout_key=(4,))
    sched = Scheduler(3, pool, 6 * t, chunk_tokens=t, chunk_align=4,
                      prefix_cache=cache, queue_limit=6)
    next_rid = [0]
    retired = set()

    def tok(n):
        g = np.random.Generator(np.random.Philox(rng.randrange(999)))
        return g.integers(1, 50, size=n).astype(np.int32)

    def add():
        plen = rng.randrange(2, 3 * t)
        req = Request(rid=next_rid[0], prompt=tok(plen),
                      max_new=rng.randrange(1, 2 * t))
        try:
            sched.add(req)
            next_rid[0] += 1
        except AdmissionError:
            pass

    def admit():
        sched.admit()

    def chunk():
        for slot, n in list(sched.plan_chunks(t).items()):
            req = sched.running.get(slot)
            if req is None or n == 0 or req.status != "prefilling":
                continue
            req.prefill_cursor += n
            req.len = req.prefill_cursor
            cache.insert(req.prompt, req.pages.pages, req.prefill_cursor)
            if req.prefill_cursor >= req.prompt_len:
                req.status = "running"
                req.out_tokens.append(1)
                if req.done():
                    sched.finish(req)
                    retired.add(req.rid)

    def decode():
        sched.grow()
        for slot, req in list(sched.running.items()):
            if req.status != "running" or req.pages.capacity <= req.len:
                continue
            req.len += 1
            req.out_tokens.append(1)
            if req.done():
                sched.finish(req)
                retired.add(req.rid)

    def spec():
        rows = [(s, r) for s, r in sched.running.items()
                if r.status == "running"]
        if not rows:
            return
        slot, req = rng.choice(rows)
        sched.grow(want={slot: 3})               # speculative 1 + 2 ask
        if sched.running.get(slot) is not req:
            return                               # displaced by its own ask
        if req.pages.capacity > req.len:
            req.len += 1
            req.out_tokens.append(1)
        try:
            req.pages.truncate(req.len)          # rejected-draft rollback
        except PoolError:
            sched.cancel(req.rid, "error", cache_pages=False)
            retired.add(req.rid)
            return
        if req.done():
            sched.finish(req)
            retired.add(req.rid)

    def cancel():
        live = ([r.rid for r in sched.waiting]
                + [r.rid for r in sched.running.values()])
        if not live:
            return
        rid = rng.choice(live)
        reason = rng.choice(["cancelled", "timeout", "error"])
        sched.cancel(rid, reason, cache_pages=reason != "error")
        retired.add(rid)

    ops = [add, add, admit, chunk, decode, spec, cancel]
    for _ in range(80):
        rng.choice(ops)()
        assert all(v >= 1 for v in pool._ref.values())
        assert pool.num_used + pool.num_free == pool.usable_pages
        live_refs = sum(pool._ref.values())
        assert (pool.total_allocs + pool.total_shares
                == pool.total_frees + live_refs)
        for s in pool.sequences():
            assert not (s.owner in retired and s.pages), \
                f"retired rid {s.owner} still holds {s.pages}"

    for r in list(sched.waiting) + list(sched.running.values()):
        sched.cancel(r.rid)
    cache.clear()
    assert pool.num_used == 0
    assert pool.total_allocs + pool.total_shares == pool.total_frees
