"""Performance attribution layer (repro.obs.attrib / monitors / export).

Contracts covered:
  - attribution completeness: per step record the four wall components
    (``sched + device + draft + host``) sum back to the measured wall
    within float tolerance — chunked and flat, speculation on and off —
    and the drain totals inherit the identity;
  - warmup-only cost model: ``Engine.warmup()`` with telemetry on builds
    a :class:`StepCostModel` whose family labels are exactly the engine's
    compiled ladder, attribution stays observer-grade (token identity vs
    a telemetry-off drain, zero post-warmup XLA traces), and without
    telemetry no model is built;
  - the Prometheus text exposition passes the pure-python lint and its
    counters are monotone across consecutive scrapes;
  - the single-file HTML report carries the waterfall, the per-family
    table and the alert log; ``write_report`` drops the ``.prom`` twin;
  - anomaly monitors: a vanishing ITL SLO target forces a ``slo-burn``
    alert exactly once per excursion, and the alert rides the telemetry
    dict + the counter;
  - the new obs modules stay clean under the repo's AST invariant lint
    (monotonic clocks, no unseeded randomness).
"""

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.obs.attrib import (StepCostModel, fresh_totals, summarize,
                              update_aggregates)
from repro.obs.export import html_report, lint_prometheus, prometheus_text, \
    write_report
from repro.obs.monitors import Monitors
from repro.serving.engine import Engine

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("serve", 64, 3, "decode")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(cfg, lens, seed=1):
    key = jax.random.PRNGKey(seed)
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (l,),
                                          0, cfg.vocab))
            for i, l in enumerate(lens)]


def _drain(eng, reqs, **kw):
    rids = [eng.add_request(p, n) for p, n in reqs]
    fin = {r.rid: r for r in eng.drain(**kw)}
    assert sorted(fin) == sorted(rids)
    return [fin[rid] for rid in rids]


REQS = ([13, 21, 3, 16], [8, 6, 10, 7])

# engine grids under test: dense chunked, flat token-level, and flat
# with an n-gram drafter (speculation exercises the draft span)
GRIDS = [dict(chunk_tokens=16, flat=False),
         dict(chunk_tokens=16, token_budget=24),
         dict(chunk_tokens=16, token_budget=24, spec_tokens=2)]


# ---------------------------------------------------------------------------
# attribution completeness: components sum to wall
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", GRIDS,
                         ids=["chunked", "flat", "flat-spec"])
def test_attribution_components_sum_to_wall(smollm, kw):
    """The headline property: every per-step attribution record's four
    components reconstruct the measured wall.  The split is exact by
    construction (host is the remainder), so tolerance only covers float
    rounding — parts-per-million of the wall, not a loose bound."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, telemetry=True,
                 **kw)
    eng.warmup()
    _drain(eng, list(zip(_prompts(cfg, REQS[0]), REQS[1])))
    recs = list(eng.obs.step_records)
    assert recs, "drain produced no attribution records"
    for rec in recs:
        parts = rec["sched"] + rec["device"] + rec["draft"] + rec["host"]
        assert abs(parts - rec["wall"]) <= 1e-9 + 1e-6 * rec["wall"], rec
        assert rec["sched"] >= 0 and rec["host"] >= 0
        assert rec["families"], "every moving step is family-tagged"
    # the drain totals inherit the identity
    tot = eng.obs.attribution_summary()["totals"]
    comp = tot["sched_s"] + tot["device_s"] + tot["draft_s"] + tot["host_s"]
    assert comp == pytest.approx(tot["wall_s"], rel=1e-6)
    assert tot["steps"] == len(recs)
    if "spec_tokens" in kw:
        assert tot["draft_s"] > 0, "speculative drain must record drafting"


def test_summarize_matches_incremental_aggregation(smollm):
    """The one-shot ``summarize`` over the record window equals the
    telemetry's incremental aggregates (same fold, different order)."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()
    _drain(eng, list(zip(_prompts(cfg, REQS[0]), REQS[1])))
    live = eng.obs.attribution_summary()
    redo = summarize(list(eng.obs.step_records), eng.cost_model,
                     goodput_tokens=live["goodput_tokens"],
                     tokens_out=live["tokens_out"])
    assert redo["totals"] == pytest.approx(live["totals"])
    assert set(redo["families"]) == set(live["families"])
    for label, f in redo["families"].items():
        assert f == pytest.approx(live["families"][label])
    for key in ("mfu", "mbu", "padding_waste_ratio", "goodput_ratio"):
        assert redo[key] == pytest.approx(live[key])
    # utilizations are physical: strictly positive, nowhere near 1 on CPU
    assert 0 < live["mfu"] < 1 and 0 < live["mbu"]
    assert 0 <= live["padding_waste_ratio"]
    assert live["goodput_ratio"] == 1.0        # no deadlines -> all good


def test_update_aggregates_survives_window_eviction():
    """The running aggregates are independent of the bounded record
    window: folding records one at a time (then discarding them) yields
    the same totals as keeping all of them."""
    recs = [{"wall": 0.5 + i * 0.01, "sched": 0.1, "device": 0.3,
             "draft": 0.0, "host": 0.1 + i * 0.01,
             "families": (("decode[3,1]", 2 + (i % 2), 3, 0.3),)}
            for i in range(10)]
    tot, fams = fresh_totals(), {}
    for rec in recs:
        update_aggregates(tot, fams, rec, None)    # no cost model needed
    assert tot["steps"] == 10
    assert tot["wall_s"] == pytest.approx(sum(r["wall"] for r in recs))
    assert tot["real_tokens"] == sum(r["families"][0][1] for r in recs)
    assert fams["decode[3,1]"]["padded_tokens"] == 30
    assert fams["decode[3,1]"]["predicted_s"] == 0.0   # model-less fold


# ---------------------------------------------------------------------------
# warmup-only cost model + the observer effect
# ---------------------------------------------------------------------------

def test_cost_model_built_at_warmup_only(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    assert eng.cost_model is None              # nothing before warmup
    eng.warmup()
    cm = eng.cost_model
    assert isinstance(cm, StepCostModel)
    assert cm is eng.obs.cost_model            # attached to telemetry
    assert cm.peak_flops > 0 and cm.hbm_bw > 0
    assert cm.flops_per_token == 2.0 * cfg.param_counts()["active"]
    for label, fc in cm.families.items():
        assert fc.predicted_s == max(fc.compute_s, fc.memory_s) > 0
        assert fc.per_token_s == pytest.approx(
            fc.predicted_s / max(1, fc.width))
        assert fc.bottleneck in ("compute", "memory")
        assert fc.kv_gather_bytes > 0          # paged caches are gathered
    # flat ladder families are in the model under the engine's labels
    assert any(l.startswith("flat[1,") for l in cm.families)
    # telemetry off -> no model is ever built
    plain = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                   token_budget=24)
    plain.warmup()
    assert plain.cost_model is None


def test_attribution_is_an_observer(smollm):
    """Token identity and the zero-retrace invariant survive the
    attribution layer: the warmup cost-model build uses fresh jit
    wrappers, so the model's counted caches see no new traces, and a
    telemetry-on drain emits the same tokens as a telemetry-off one."""
    cfg, m, params = smollm
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    plain = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                   token_budget=24)
    plain.warmup()
    want = [r.out_tokens for r in _drain(plain, reqs)]

    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()                               # builds the cost model too
    before = dict(m.trace_counts)
    got = [r.out_tokens for r in _drain(eng, reqs)]
    assert got == want
    assert dict(m.trace_counts) == before, \
        f"attribution retraced: {before} -> {dict(m.trace_counts)}"


# ---------------------------------------------------------------------------
# exposition formats
# ---------------------------------------------------------------------------

def test_prometheus_text_lints_clean_and_counters_monotone(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()
    reqs = list(zip(_prompts(cfg, REQS[0]), REQS[1]))
    _drain(eng, reqs)

    def scrape():
        text = prometheus_text(eng.obs)
        assert lint_prometheus(text) == [], lint_prometheus(text)
        vals = {}
        for line in text.splitlines():
            if line.startswith("#") or not line.strip():
                continue
            name, val = line.rsplit(" ", 1)
            vals[name] = float(val)
        return text, vals

    text, first = scrape()
    assert "repro_tokens_out_total" in first
    assert first["repro_tokens_out_total"] == sum(n for _, n in reqs)
    assert any(k.startswith("repro_family_steps_total{family=")
               for k in first)
    assert 0 < first["repro_mfu"] < 1
    assert first["repro_goodput_ratio"] == 1.0

    _drain(eng, reqs)                          # second drain, no reset
    _, second = scrape()
    for name, v in first.items():
        if name.endswith("_total}") or "_total{" in name \
                or name.endswith("_total"):
            assert second.get(name, 0.0) >= v, f"counter {name} regressed"


def test_prometheus_lint_catches_format_violations():
    """The lint is a real gate, not a rubber stamp."""
    assert lint_prometheus("# TYPE x counter\nx_total 1\n") == []
    bad = [
        "# TYPE x counter\nx 1\n",                     # counter sans _total
        "# TYPE x counter\nx_total -1\n",              # negative counter
        "x_total 1\n",                                 # sample without TYPE
        "# TYPE x gauge\nx 1\nx 2\n",                  # duplicate sample
        '# TYPE x gauge\nx{__bad="y"} 1\n',            # reserved label
        "# TYPE x gauge\nx notafloat\n",               # unparseable value
    ]
    for text in bad:
        assert lint_prometheus(text), f"lint missed: {text!r}"


def test_html_report_schema_and_write(smollm, tmp_path):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()
    _drain(eng, list(zip(_prompts(cfg, REQS[0]), REQS[1])))

    page = html_report(eng.obs, title="t&t")
    assert page.startswith("<!doctype html>")
    assert "t&amp;t" in page                   # titles are escaped
    for marker in ("Attribution waterfall", "Per-family predicted vs",
                   "Latency percentiles", "Alerts", "class='bar'",
                   "cost model:"):
        assert marker in page, f"report missing {marker!r}"
    assert "<script" not in page               # self-contained, no JS
    for label in eng.obs.attribution_summary()["families"]:
        assert label.replace("<", "&lt;") in page

    # the telemetry(report=...) path writes the .html/.prom pair
    tel = eng.telemetry(report=tmp_path / "drain")
    paths = tel["report"]
    html_text = open(paths["html"]).read()
    prom_text = open(paths["prom"]).read()
    assert html_text == html_report(eng.obs)
    assert lint_prometheus(prom_text) == []
    assert tel["attribution"]["totals"]["steps"] > 0


# ---------------------------------------------------------------------------
# anomaly monitors
# ---------------------------------------------------------------------------

def test_slo_burn_alert_fires_once_per_excursion(smollm):
    """An unmeetable ITL target trips ``slo-burn`` — exactly once, not
    once per step (the re-arm contract) — and the alert is visible in
    ``Engine.telemetry()`` and the alert counter."""
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.obs.monitors.slo_itl_s = 1e-12         # every emission violates
    eng.warmup()
    _drain(eng, list(zip(_prompts(cfg, REQS[0]), REQS[1])))
    burns = [a for a in eng.obs.alerts if a.kind == "slo-burn"]
    assert len(burns) == 1, [a.message for a in eng.obs.alerts]
    a = burns[0]
    assert a.severity == "crit" and a.value > a.threshold
    assert "itl" in a.message
    tel = eng.telemetry()
    assert any(d["kind"] == "slo-burn" for d in tel["alerts"])
    assert eng.obs.registry.snapshot()["alerts_emitted"] >= 1


def test_monitor_rules_standalone():
    """Rule-level checks without an engine: a synthetic scheduler drives
    the preemption-storm and queue-growth detectors."""
    class FakeSched:
        max_slots = 2
        num_preemptions = 0
        waiting: list = []

    class FakeReg:
        class _C:
            value = 0
        def counter(self, name):
            return self._C()

    class FakeTel:
        registry = FakeReg()

    mon = Monitors(window=4)
    sched, tel = FakeSched(), FakeTel()
    fired = []
    for i in range(6):
        sched.num_preemptions += 2             # storm: 8 > 2 within window
        sched.waiting = list(range(3 * (i + 1)))   # monotone growth >= 2
        fired += mon.observe_step(t=float(i), scheduler=sched,
                                  telemetry=tel, families=[],
                                  device_s=0.0)
    kinds = [a.kind for a in fired]
    assert kinds.count("preempt-storm") == 1   # re-armed only on clearing
    assert kinds.count("queue-growth") == 1
    # clearing re-arms: a calm stretch then a second storm fires again
    sched.waiting = []
    for i in range(6):
        fired += mon.observe_step(t=10.0 + i, scheduler=sched,
                                  telemetry=tel, families=[], device_s=0.0)
    sched.num_preemptions += 20
    fired += mon.observe_step(t=20.0, scheduler=sched, telemetry=tel,
                              families=[], device_s=0.0)
    assert [a.kind for a in fired].count("preempt-storm") == 2


def test_step_outlier_detects_spike_per_family():
    class FakeSched:
        max_slots = 2
        num_preemptions = 0
        waiting: list = []

    class FakeReg:
        class _C:
            value = 0
        def counter(self, name):
            return self._C()

    class FakeTel:
        registry = FakeReg()

    mon = Monitors(outlier_min=8)
    sched, tel = FakeSched(), FakeTel()
    for i in range(10):                        # warm the rolling median
        mon.observe_step(t=float(i), scheduler=sched, telemetry=tel,
                         families=[("flat[1,16]/k1", 8, 16, 0.010)],
                         device_s=0.010)
    fired = mon.observe_step(t=11.0, scheduler=sched, telemetry=tel,
                             families=[("flat[1,16]/k1", 8, 16, 0.100)],
                             device_s=0.100)
    assert [a.kind for a in fired] == ["step-outlier"]
    assert "flat[1,16]/k1" in fired[0].message
    # a different family with no warm window never alerts
    fired = mon.observe_step(t=12.0, scheduler=sched, telemetry=tel,
                             families=[("chunk[3,16]", 8, 48, 0.500)],
                             device_s=0.500)
    assert fired == []


# ---------------------------------------------------------------------------
# goodput + stats surface
# ---------------------------------------------------------------------------

def _timed_drain(eng, dt=1.0):
    """Drive ``step(now=...)`` with an advancing synthetic clock (deadline
    expiry needs a clock; ``drain()``'s default ``now=None`` is untimed)."""
    t, fin = 0.0, []
    while eng.scheduler.has_work or eng._finished_oob:
        t += dt
        fin.extend(eng.step(now=t))
    return fin


def test_goodput_counts_only_in_deadline_tokens(smollm):
    """Goodput is judged on the engine clock: a request expired before
    its first emission contributes nothing, tokens emitted *before* the
    timeout still count (the cut does not retro-revoke them), and
    ``Engine.stats()['slo']`` reports the ledger."""
    cfg, m, params = smollm
    prompts = _prompts(cfg, REQS[0])
    # deadline shorter than the first step: timeout before any emission
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()
    rids = [eng.add_request(p, n, deadline_s=0.5)
            for p, n in zip(prompts, REQS[1])]
    fin = {r.rid: r for r in _timed_drain(eng)}
    assert sorted(fin) == sorted(rids)
    assert all(fin[r].finish_reason == "timeout" for r in rids)
    slo = eng.stats()["slo"]
    assert slo["tokens_out"] == 0 and slo["goodput_tokens"] == 0
    assert slo["goodput_ratio"] == 0.0
    assert slo["ttft_p99_s"] >= 0.0            # empty histogram, no crash
    # a mid-drain deadline: some requests are cut short, but every token
    # they emitted while alive stays goodput
    eng2 = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                  token_budget=24, telemetry=True)
    eng2.warmup()
    rids = [eng2.add_request(p, n, deadline_s=3.5)
            for p, n in zip(prompts, REQS[1])]
    fin2 = {r.rid: r for r in _timed_drain(eng2)}
    assert sorted(fin2) == sorted(rids)
    assert any(r.finish_reason == "timeout" for r in fin2.values())
    slo2 = eng2.stats()["slo"]
    assert slo2["tokens_out"] > 0
    assert slo2["goodput_tokens"] == slo2["tokens_out"]
    assert slo2["goodput_ratio"] == 1.0
    # and without deadlines everything is goodput
    eng3 = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                  token_budget=24, telemetry=True)
    _drain(eng3, list(zip(prompts, REQS[1])))
    slo3 = eng3.stats()["slo"]
    assert slo3["goodput_ratio"] == 1.0
    assert slo3["goodput_tokens"] == slo3["tokens_out"] > 0


def test_telemetry_reset_clears_attribution(smollm):
    cfg, m, params = smollm
    eng = Engine(m, params, max_slots=3, page_tokens=8, chunk_tokens=16,
                 token_budget=24, telemetry=True)
    eng.warmup()
    _drain(eng, list(zip(_prompts(cfg, REQS[0]), REQS[1])))
    assert eng.obs.attribution_summary()["totals"]["steps"] > 0
    eng.telemetry(reset=True)
    after = eng.obs.attribution_summary()
    assert after["totals"] == fresh_totals()
    assert after["families"] == {}
    assert len(eng.obs.step_records) == 0
    assert eng.cost_model is not None          # the model survives resets


# ---------------------------------------------------------------------------
# AST invariant lint coverage for the new modules
# ---------------------------------------------------------------------------

def test_obs_attrib_modules_pass_ast_lint():
    from pathlib import Path

    from repro.analysis.ast_lint import lint_paths

    repo = Path(__file__).resolve().parent.parent
    obs = repo / "src" / "repro" / "obs"
    serving = repo / "src" / "repro" / "serving"
    targets = [obs / "attrib.py", obs / "monitors.py", obs / "export.py",
               obs / "telemetry.py"]
    assert all(p.exists() for p in targets)
    findings = lint_paths(targets, serving_root=serving,
                          clock_roots=(serving, obs))
    assert findings == [], [f.format() for f in findings]
