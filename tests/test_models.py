"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, shape + finiteness asserts; decode-vs-parallel consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.training.optimizer import make_optimizer
from repro.training.step import make_train_step
from repro.training.train_state import TrainState

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)
SHAPE = ShapeSpec("smoke", 32, 2, "train")
# the heavyweight compiles of the sweep (the 8-layer hybrid, the enc-dec and
# the big-MoE configs) are slow-marked; all keep fast coverage through
# test_decode_matches_parallel* / test_serving / test_mixers.
_HEAVY = {"jamba-v0.1-52b", "whisper-small", "arctic-480b",
          "qwen3-moe-235b-a22b", "qwen2-7b", "qwen3-8b", "rwkv6-1.6b",
          "internvl2-26b"}
ALL_ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
             for a in ASSIGNED + ["smollm2-135m"]]


def _batch(m, cfg, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    b = {"tokens": jax.random.randint(ks[0], (2, m.text_len), 0, cfg.vocab),
         "labels": jax.random.randint(ks[1], (2, m.text_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(ks[2], (2, m.enc_len, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(ks[2], (2, cfg.vision_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    m = build_model(cfg, RUN, SHAPE)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(m, cfg)

    # shape contract via eval_shape (free); numerics via the train step below
    # (its forward IS m.forward — a second jitted forward compile added ~40%
    # per arch for no extra coverage)
    logits, _ = jax.eval_shape(m.forward, params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab

    opt = make_optimizer(RUN, total_steps=10)
    step = jax.jit(make_train_step(m, opt, RUN))
    state = TrainState.create(params, opt)
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diff = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, params))
    assert max(diff) > 0


# fast set: one of each decode-cache shape (dense+bias, ssm, plain dense);
# the remaining variants (qk-norm, non-parametric LN, partial RoPE, encdec —
# whisper keeps fast E2E coverage via test_serving) ride the slow sweep.
@pytest.mark.parametrize("arch", [
    "qwen2-7b",
    pytest.param("qwen3-8b", marks=pytest.mark.slow),
    pytest.param("olmo-1b", marks=pytest.mark.slow),
    pytest.param("chatglm3-6b", marks=pytest.mark.slow),
    pytest.param("whisper-small", marks=pytest.mark.slow),
    "rwkv6-1.6b", "smollm2-135m"])
def test_decode_matches_parallel(arch):
    cfg = reduced_config(get_config(arch))
    s = 12
    shape = ShapeSpec("smoke", s, 2, "train")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(m, cfg)
    batch = {k: (v[:, :s] if k in ("tokens", "labels") else v)
             for k, v in batch.items()}
    toks = batch["tokens"]
    logits_full, _ = m.forward(params, batch)
    caches = m.prefill_cache(params, batch)
    step = jax.jit(m.decode_step)
    for t in range(s):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", [
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow),
    "qwen3-moe-235b-a22b",
    pytest.param("arctic-480b", marks=pytest.mark.slow)])
def test_decode_matches_parallel_moe(arch):
    """MoE archs compared at high capacity (capacity drops are prefill-only
    semantics, so consistency requires no drops)."""
    cfg = dataclasses.replace(reduced_config(get_config(arch)),
                              capacity_factor=8.0)
    s = 12
    shape = ShapeSpec("smoke", s, 2, "train")
    m = build_model(cfg, RUN, shape)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    logits_full, _ = m.forward(params, {"tokens": toks})
    caches = m.prefill_cache(params, {"tokens": toks})
    step = jax.jit(m.decode_step)
    for t in range(s):
        lg, caches = step(params, caches, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_prefill_matches_full():
    """decode_step with a multi-token chunk == full forward (prefill path)."""
    cfg = reduced_config(get_config("qwen2-7b"))
    s = 16
    m = build_model(cfg, RUN, ShapeSpec("smoke", s, 2, "train"))
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    logits_full, _ = m.forward(params, {"tokens": toks})
    caches = m.init_cache(2, s)
    lg1, caches = m.decode_step(params, caches, toks[:, :10], jnp.int32(0))
    lg2, _ = m.decode_step(params, caches, toks[:, 10:], jnp.int32(10))
    got = jnp.concatenate([lg1, lg2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_policies_agree_end_to_end():
    """The three codegen policies produce the same model function (the
    unpacked reference forward is computed once, not once per policy)."""
    cfg = reduced_config(get_config("smollm2-135m"))
    m_ref = build_model(cfg, dataclasses.replace(RUN, layout_policy="unpacked"),
                        SHAPE)
    params = m_ref.init(jax.random.PRNGKey(0))
    batch = _batch(m_ref, cfg)
    logits_ref, _ = m_ref.forward(params, batch)
    for policy in ("scalable", "fixed"):
        run = dataclasses.replace(RUN, layout_policy=policy)
        m = build_model(cfg, run, SHAPE)
        logits, _ = m.forward(params, batch)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                                   rtol=2e-3, atol=2e-3)


def test_param_counts_match_scale():
    """Full-config param counts match the published model sizes."""
    expect = {"qwen2-7b": 7.6e9, "qwen3-8b": 8.2e9, "olmo-1b": 1.2e9,
              "chatglm3-6b": 6.2e9, "qwen3-moe-235b-a22b": 235e9,
              "arctic-480b": 477e9, "jamba-v0.1-52b": 52e9,
              "rwkv6-1.6b": 1.6e9, "internvl2-26b": 20e9,
              "smollm2-135m": 0.135e9}
    for arch, n in expect.items():
        got = get_config(arch).param_counts()["total"]
        assert 0.6 * n < got < 1.45 * n, (arch, got, n)
    # MoE active << total
    moe = get_config("qwen3-moe-235b-a22b").param_counts()
    assert moe["active"] < 0.15 * moe["total"]
