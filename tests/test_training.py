"""Training substrate: optimizer correctness, 8-bit states, compression,
checkpoint roundtrip/corruption, trainer crash-resume, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.optimizer import AdamW, global_norm
from repro.training.trainer import Trainer
from repro.training.train_state import TrainState

RUN = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False)


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"a": {"w": jax.random.normal(k1, (16, 8))},
            "b": {"w": jax.random.normal(k2, (8, 4)), "b": jnp.zeros((4,))}}


def _toy_grads(params, seed=0):
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [jax.random.normal(k, l.shape)
                                        for k, l in zip(ks, leaves)])


def test_adamw_matches_manual_reference():
    opt = AdamW(lr=1e-2, weight_decay=0.0, grad_clip=0.0, warmup_steps=1,
                total_steps=10**9, min_lr_frac=1.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = opt.init(params)
    new_p, _, _ = opt.update(g, st, params, jnp.int32(0))
    # manual: m=(1-b1)g, v=(1-b2)g^2; bias-corrected => update = lr*g/|g|
    expect = params["w"] - 1e-2 * g["w"] / (jnp.abs(g["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect),
                               rtol=1e-4)


def test_adamw_8bit_close_to_fp32():
    params = _toy_params(jax.random.PRNGKey(0))
    g = _toy_grads(params)
    full = AdamW(lr=1e-2, eightbit=False, warmup_steps=1)
    q8 = AdamW(lr=1e-2, eightbit=True, warmup_steps=1)
    p1, s1, _ = full.update(g, full.init(params), params, jnp.int32(0))
    p2, s2, _ = q8.update(g, q8.init(params), params, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-3)
    # 8-bit states really are int8
    assert all(l.dtype == jnp.int8 for l in jax.tree.leaves(s2["m_q"])
               if l.ndim >= 2)


def test_grad_clip():
    opt = AdamW(grad_clip=1.0)
    g = {"w": jnp.full((10,), 100.0)}
    assert float(global_norm(g)) > 1.0
    p = {"w": jnp.zeros((10,))}
    _, _, metrics = opt.update(g, opt.init(p), p, jnp.int32(0))
    assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip


def test_schedule_warmup_cosine():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(opt.schedule(jnp.int32(0))) == pytest.approx(0.1, rel=1e-3)
    assert float(opt.schedule(jnp.int32(9))) == pytest.approx(1.0, rel=1e-3)
    assert float(opt.schedule(jnp.int32(109))) == pytest.approx(0.1, rel=1e-2)


def test_compression_error_feedback():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    err = compression.init_error_buffer(params)
    g = _toy_grads(params, seed=1)
    # accumulated compressed grads converge to accumulated true grads
    acc_true = jnp.zeros((64, 64))
    acc_comp = jnp.zeros((64, 64))
    for i in range(20):
        gc, err = compression.compress_with_feedback(g, err)
        acc_true += g["w"]
        acc_comp += gc["w"]
    rel = float(jnp.linalg.norm(acc_comp - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.02  # error feedback keeps long-run bias tiny


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": _toy_params(jax.random.PRNGKey(0)),
            "opt": {"m": jnp.arange(5, dtype=jnp.float32),
                    "q": jnp.arange(5, dtype=jnp.int8),
                    "bf": jnp.ones((3,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"}, fingerprint="fp")
    got, extra, step = ckpt.restore(str(tmp_path), fingerprint="fp")
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_fingerprint_mismatch(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.zeros(3)}, fingerprint="aaa")
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), fingerprint="bbb")


def test_checkpoint_skips_corrupt(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.zeros(3)})
    ckpt.save(str(tmp_path), 2, {"x": jnp.ones(3)})
    # corrupt the newest
    os.remove(os.path.join(str(tmp_path), "step_00000002", "arrays.npz"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_prune(tmp_path):
    for s in range(5):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(1)})
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(str(tmp_path)))[-2:] == ["step_00000003",
                                                      "step_00000004"]


def test_data_pipeline_deterministic_and_sharded():
    cfg = reduced_config(get_config("smollm2-135m"))
    shape = ShapeSpec("t", 16, 8, "train")
    a = SyntheticLM(cfg, shape, seed=1).batch_at(3)
    b = SyntheticLM(cfg, shape, seed=1).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, shape, seed=1).batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the batch size
    s0 = SyntheticLM(cfg, shape, seed=1, shard_index=0, shard_count=2).batch_at(3)
    assert s0["tokens"].shape[0] == 4
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


@pytest.mark.slow
def test_trainer_crash_resume_bitwise(tmp_path):
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("t", 32, 4, "train")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat=False, warmup_steps=2)
    model = build_model(cfg, run, shape)
    data = SyntheticLM(cfg, shape, seed=0)

    def mk(d):
        return Trainer(model, data, run, ckpt_dir=str(d), total_steps=12,
                       ckpt_every=4, log_fn=lambda *_: None)

    # uninterrupted reference
    t_ref = mk(tmp_path / "ref")
    state_ref, hist_ref = t_ref.fit(jax.random.PRNGKey(0))

    # crash at step 10 (after ckpt at 8), then resume
    t1 = mk(tmp_path / "a")
    with pytest.raises(RuntimeError):
        t1.fit(jax.random.PRNGKey(0), fail_at=10)
    t2 = mk(tmp_path / "a")
    state2, hist2 = t2.fit(jax.random.PRNGKey(0))

    assert int(state2.step) == int(state_ref.step) == 12
    np.testing.assert_allclose(hist2[-1], hist_ref[-1], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_ref.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_loss_decreases_on_learnable_stream():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("t", 64, 4, "train")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat=False, lr=3e-3, warmup_steps=5)
    model = build_model(cfg, run, shape)
    data = SyntheticLM(cfg, shape, seed=0)
    tr = Trainer(model, data, run, total_steps=40, log_fn=lambda *_: None)
    _, hist = tr.fit(jax.random.PRNGKey(0))
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.2


@pytest.mark.slow
def test_microbatch_grads_match_full_batch():
    import dataclasses
    from repro.training.optimizer import make_optimizer
    from repro.training.step import make_train_step
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("t", 16, 8, "train")
    data = SyntheticLM(cfg, shape, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    outs = {}
    for n in (0, 4):
        run = dataclasses.replace(RUN, microbatch=n, warmup_steps=1)
        model = build_model(cfg, run, shape)
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer(run, 10)
        st = TrainState.create(params, opt)
        st2, m = make_train_step(model, opt, run)(st, batch)
        outs[n] = st2.params
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[4])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)
