"""Layout-contract analyzer (src/repro/analysis/).

Two halves, mirroring how a verifier earns trust:

* **green**: every pass runs clean on the engine configurations the repo
  actually ships (monolithic / chunked / flat / spec / prefix-cache),
  with the sanitizer installed and real traffic — the analyzer gating CI
  must not cry wolf;
* **seeded bugs**: each contract is deliberately broken — a mis-aligned
  chunk width, an in-place write to a shared page, a post-warmup retrace
  via a leaked python scalar, a direct free-list append in a scratch
  module — and the owning pass must catch exactly it, with a diagnostic
  naming the offending width/page/argument/line.
"""

import numpy as np
import pytest

from repro.analysis import (RetraceDetector, SanitizerError,
                            check_pool_consistency, install,
                            lint_engine_aliasing, lint_engine_shapes,
                            lint_kernel_oracles, lint_paths, run_ast_lint)
from repro.analysis.aliasing import lint_kv_writes, taint_step
from repro.analysis.runner import CONFIG_MATRIX, analyze_engine, build_model
from repro.serving.engine import Engine
from repro.serving.kv_cache import PagedKVPool, SequencePages

pytestmark = pytest.mark.analysis


@pytest.fixture(scope="module")
def smollm():
    return build_model()


def _drain_traffic(engine, seed=0):
    rng = np.random.Generator(np.random.Philox(seed))
    shared = rng.integers(1, 50, size=12).astype(np.int32)
    for p, n in [(np.concatenate([shared,
                                  rng.integers(1, 50, size=5)]).astype(
                      np.int32), 6),
                 (rng.integers(1, 50, size=21).astype(np.int32), 5),
                 (np.concatenate([shared,
                                  rng.integers(1, 50, size=2)]).astype(
                      np.int32), 4)]:
        engine.add_request(p, n)
    return engine.drain(greedy=True, seed=seed)


# ---------------------------------------------------------------------------
# green: the shipped configurations pass every static pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label,kwargs", CONFIG_MATRIX,
                         ids=[c[0] for c in CONFIG_MATRIX])
def test_static_passes_green(smollm, label, kwargs):
    """Shape-ladder algebra + KV-write aliasing are clean on every config
    (jaxpr tracing included for one config to keep the default run fast —
    the full matrix traces in tier1.sh --analyze)."""
    model, params = smollm
    engine = Engine(model, params, **kwargs)
    findings = lint_engine_shapes(engine, label, trace=(label == "flat"))
    findings += lint_engine_aliasing(engine, label)
    assert not findings, "\n".join(f.format() for f in findings)


def test_sanitized_traffic_green(smollm):
    """A sanitized drain with prefix-cache sharing, growth and retrace
    watching stays clean — and the sanitizer demonstrably inspected the
    steps it certified."""
    model, params = smollm
    engine = Engine(model, params, chunk_tokens=16, prefix_cache=True,
                    flat=False)
    san = install(engine)
    det = RetraceDetector(model)
    engine.warmup()
    det.mark()
    out = _drain_traffic(engine)
    assert len(out) == 3 and all(r.out_tokens for r in out)
    assert san.checks > 0 and san.pages_checked > 0
    assert det.findings() == []
    assert check_pool_consistency(engine) == []


def test_ast_lint_green_on_tree():
    report = run_ast_lint()
    assert report.ok, report.format()


# ---------------------------------------------------------------------------
# seeded bug 1: mis-aligned chunk width -> shape-ladder linter
# ---------------------------------------------------------------------------

def test_seeded_misaligned_chunk(smollm):
    """chunk_tokens hacked to a non-m_r multiple after construction: the
    linter re-derives the ladder and names the width and m_r."""
    model, params = smollm
    engine = Engine(model, params, chunk_tokens=16, flat=False)
    engine.chunk_tokens = 11          # m_r = 8: not tile-aligned
    findings = lint_engine_shapes(engine, "seeded", trace=False)
    rules = {f.rule for f in findings}
    assert "chunk-align" in rules, findings
    msg = next(f for f in findings if f.rule == "chunk-align").message
    assert "11" in msg and "m_r" in msg


def test_seeded_broken_flat_ladder(smollm):
    """A width pushed onto the flat ladder that the declared geometric
    ladder doesn't contain is caught by ladder re-derivation."""
    model, params = smollm
    engine = Engine(model, params, chunk_tokens=16)
    real = engine._flat_shapes()

    engine._flat_shapes = lambda: sorted(set(real) | {24}, reverse=True)
    findings = lint_engine_shapes(engine, "seeded", trace=False)
    assert any(f.rule == "flat-ladder" and "24" in f.message
               for f in findings), findings


# ---------------------------------------------------------------------------
# seeded bug 2: in-place write to a shared page -> sanitizer
# ---------------------------------------------------------------------------

def test_seeded_shared_page_write(smollm):
    """Force ref > 1 on the page a decode row is about to write: the
    sanitizer must refuse the step, naming page, refcount and owner."""
    model, params = smollm
    engine = Engine(model, params, chunk_tokens=16)
    install(engine)
    engine.warmup()
    engine.add_request(np.arange(1, 14, dtype=np.int32), 24)
    engine.step()                     # admit + start prefill
    req = next(iter(engine.scheduler.running.values()))
    T = engine.pool.page_tokens

    def pos():
        return len(req.prompt) + len(req.out_tokens)

    # decode to a mid-page position so the next few writes stay inside
    # one page (no boundary crossing into a freshly allocated page)
    while not req.out_tokens or not 2 <= pos() % T <= T - 3:
        engine.step()
    target = req.pages.pages[pos() // T]
    engine.pool.share([target])       # simulate a missing cow()
    with pytest.raises(SanitizerError) as ei:
        for _ in range(3):
            engine.step()
    msg = str(ei.value)
    assert f"page {target}" in msg
    assert "ref=2" in msg
    assert str(req.rid) in msg        # owner named via pool.holders


def test_sanitizer_write_to_freed_page():
    """The page-level check alone (no engine): a block table referencing
    a freed page fails with ref=0."""
    pool = PagedKVPool(6, 8)
    seq = SequencePages(pool, owner=7)
    seq.ensure(8)
    page = seq.pages[0]
    pool.free([page])

    class _E:                        # minimal engine stand-in
        def __init__(self):
            self.pool = pool
            self._bucket = 8
            self.chunked = False
            self.flat = False
            self.spec_tokens = None

        def _prefill_bucket(self, l):
            return 8

    from repro.analysis.sanitize import StepSanitizer
    san = StepSanitizer(_E())
    with pytest.raises(SanitizerError, match=rf"page {page} \(ref=0\)"):
        san.check_paged(np.zeros((1, 1), np.int32),
                        np.full((1, 2), page, np.int32),
                        np.zeros((1,), np.int32), np.ones((1,), np.int32))


# ---------------------------------------------------------------------------
# seeded bug 3: post-warmup retrace via a leaked python scalar
# ---------------------------------------------------------------------------

def test_seeded_weak_type_retrace(smollm):
    """Warm the static decode step with a strong int32 position, then call
    it with a raw python 0 — the detector must attribute the retrace to
    the pos argument's weak_type flip."""
    import jax.numpy as jnp
    model, params = smollm
    step = model.jit_step("decode")
    caches = model.init_cache(1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    _, caches = step(params, caches, tok, jnp.int32(0))      # "warmup"
    det = RetraceDetector(model)
    n0 = model.trace_counts["decode"]
    _, caches = step(params, caches, tok, 0)                 # the leak
    assert model.trace_counts["decode"] == n0 + 1
    findings = det.findings()
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "post-warmup-trace"
    assert "pos" in f.message and "weak_type" in f.message, f.message


def test_retrace_detector_quiet_on_cache_hit(smollm):
    """Replaying a warmed signature must not produce findings."""
    import jax.numpy as jnp
    model, params = smollm
    step = model.jit_step("decode")
    caches = model.init_cache(1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    _, caches = step(params, caches, tok, jnp.int32(0))
    det = RetraceDetector(model)
    _, caches = step(params, caches, tok, jnp.int32(1))      # same signature
    assert det.findings() == []


# ---------------------------------------------------------------------------
# seeded bug 4: allocator mutation in a scratch module -> AST lint
# ---------------------------------------------------------------------------

def test_seeded_free_list_mutation(tmp_path):
    scratch = tmp_path / "scratch.py"
    scratch.write_text(
        "def leak(pool, p):\n"
        "    pool._free.append(p)      # bypasses the double-free check\n"
        "    pool._ref[p] = 1\n")
    findings = lint_paths([scratch])
    assert len(findings) == 2
    assert all(f.rule == "allocator-privacy" for f in findings)
    assert "scratch.py:2" in findings[0].where
    assert "_free" in findings[0].message
    assert "scratch.py:3" in findings[1].where


def test_seeded_raw_capacity_assert(tmp_path):
    serving = tmp_path / "serving"
    serving.mkdir()
    bad = serving / "sched_patch.py"
    bad.write_text(
        "def admit(pool, need):\n"
        "    assert need <= pool.free_pages\n")
    findings = lint_paths([serving], serving_root=serving)
    assert [f.rule for f in findings] == ["capacity-asserts"]
    assert "free_pages" in findings[0].message


def test_seeded_raw_capacity_raise_guard(tmp_path):
    """The PR-8 typed-exception conversion must not be a lint escape
    hatch: an `if <raw capacity>: raise ...` guard is flagged exactly
    like the assert it replaced, while guards on num_available pass."""
    serving = tmp_path / "serving"
    serving.mkdir()
    bad = serving / "sched_patch.py"
    bad.write_text(
        "def admit(pool, need):\n"
        "    if need > pool.num_free:\n"
        "        raise AdmissionError(0, 'page-demand', 'full')\n"
        "    if need > pool.num_available:\n"
        "        raise AdmissionError(0, 'page-demand', 'full')\n")
    findings = lint_paths([serving], serving_root=serving)
    assert [f.rule for f in findings] == ["capacity-asserts"]
    assert "num_free" in findings[0].message
    assert "sched_patch.py:2" in findings[0].where


def test_seeded_unseeded_randomness(tmp_path):
    bad = tmp_path / "noise.py"
    bad.write_text(
        "import random\n"
        "import numpy as np\n"
        "jitter = random.random()\n"
        "noise = np.random.randn(4)\n"
        "rng = np.random.default_rng()\n"
        "ok = np.random.Generator(np.random.Philox(0))\n"
        "ok2 = np.random.default_rng(7)\n")
    findings = lint_paths([bad])
    assert [f.rule for f in findings] == ["unseeded-randomness"] * 3
    lines = {int(f.where.rsplit(":", 1)[1]) for f in findings}
    assert lines == {3, 4, 5}        # the two seeded constructions pass


def test_seeded_wall_clock_in_serving(tmp_path):
    """Seeded bug for the monotonic-clock rule: a timing patch in a
    clock-ruled tree (serving/obs) that reads ``time.time()`` — via the
    module, an alias, or ``from time import time`` — is flagged, while
    ``perf_counter`` and deadline math on a caller-supplied ``now=``
    stay clean."""
    obs = tmp_path / "obs"
    obs.mkdir()
    bad = obs / "timing_patch.py"
    bad.write_text(
        "import time\n"
        "import time as walltime\n"
        "from time import time as tt\n"
        "def span():\n"
        "    t0 = time.time()\n"
        "    t1 = walltime.time()\n"
        "    t2 = tt()\n"
        "    ok = time.perf_counter()\n"
        "    return t1 - t0, t2, ok\n"
        "def expired(req, now):\n"
        "    return now >= req.deadline\n")
    findings = lint_paths([obs], clock_roots=(obs,))
    assert [f.rule for f in findings] == ["monotonic-clock"] * 3
    lines = {int(f.where.rsplit(":", 1)[1]) for f in findings}
    assert lines == {5, 6, 7}        # perf_counter and now= math pass
    assert "perf_counter" in findings[0].message

    # outside the clock roots the same file is none of the lint's
    # business — scripts and tests may read the wall clock freely
    assert lint_paths([obs]) == []


def test_kernel_oracle_rule(tmp_path):
    kernels = tmp_path / "kernels"
    (kernels / "fancy").mkdir(parents=True)
    (kernels / "fancy" / "kernel.py").write_text("def k():\n    pass\n")
    (kernels / "fancy" / "ref.py").write_text("def fancy_ref():\n    pass\n")
    tests = tmp_path / "tests"
    tests.mkdir()
    (tests / "test_none.py").write_text("import math\n")
    findings = lint_kernel_oracles(kernels, tests)
    assert [f.rule for f in findings] == ["kernel-oracle"]
    assert "fancy" in findings[0].message

    (tests / "test_none.py").write_text(
        "from repro.kernels.fancy.ref import fancy_ref\n")
    assert lint_kernel_oracles(kernels, tests) == []


# ---------------------------------------------------------------------------
# the aliasing pass sees and judges real write sites
# ---------------------------------------------------------------------------

def test_aliasing_flags_unguarded_write():
    """A scatter addressed without the trash-guard/where must be flagged —
    the pass proves the guard, it doesn't assume it."""
    import jax
    import jax.numpy as jnp

    def bad_update(pages, idx, val):
        return pages.at[idx].set(val)          # no validity route, no guard

    S = jax.ShapeDtypeStruct
    walker = taint_step(
        bad_update,
        (S((8, 4), jnp.float32), S((2,), jnp.int32), S((2, 4), jnp.float32)),
        {0: "pages", 1: "block_tables"})       # indices lack trash0
    findings = lint_kv_writes(walker, "seeded-bad-update")
    assert any(f.rule == "unguarded-write" and "trash0" in f.message
               for f in findings), findings


def test_aliasing_accepts_guarded_write():
    """The real guard shape — jnp.where(valid, bt-gathered page, 0) —
    earns both labels and passes."""
    import jax
    import jax.numpy as jnp

    def good_update(pages, bt, counts, val):
        pos = jnp.arange(val.shape[0], dtype=jnp.int32)
        valid = pos < counts
        page = jnp.where(valid, bt[pos], 0)
        return pages.at[page].set(val)

    S = jax.ShapeDtypeStruct
    walker = taint_step(
        good_update,
        (S((8, 4), jnp.float32), S((6,), jnp.int32), S((), jnp.int32),
         S((6, 4), jnp.float32)),
        {0: "pages", 1: "block_tables", 2: "validity"})
    findings = lint_kv_writes(walker, "guarded-update")
    assert findings == [], [f.format() for f in findings]


def test_pool_ledger_catches_stale_refcount(smollm):
    model, params = smollm
    engine = Engine(model, params, chunk_tokens=16)
    engine.warmup()
    engine.add_request(np.arange(1, 14, dtype=np.int32), 4)
    engine.drain()
    assert check_pool_consistency(engine) == []
    leaked = SequencePages(engine.pool, owner=99)
    leaked.pages.append(3)            # holds page 3 without a reference
    findings = check_pool_consistency(engine)
    assert any(f.rule == "ledger-mismatch" and "page 3" in f.message
               for f in findings), findings
    leaked.pages.clear()


# ---------------------------------------------------------------------------
# the full driver (slow: every config, traced + traffic)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_all_green(smollm):
    from repro.analysis import run_all
    report = run_all()
    assert report.ok, report.format()
    assert len(report.sections) >= 2 + 4 * len(CONFIG_MATRIX)
