"""Layout algebra: tile functions, policies, padding math (paper §4.2)."""

import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hardware import HardwareSpec, presets, query, sublane_packing
from repro.core.layout import LayoutPolicy, make_layout, ceil_div, round_up


def test_scalable_tiles_follow_hardware():
    """The SVE property: tile sizes are functions of the hardware descriptor."""
    for dtype, pack in [(jnp.float32, 1), (jnp.bfloat16, 2), (jnp.int8, 4)]:
        lay = make_layout("scalable", presets["tpu_v5e"], dtype)
        assert lay.m_r == 8 * pack          # dtype packing (SVE width scaling)
        assert lay.n_r == 128               # VL analogue
        assert lay.k_r == 128               # MXU depth


def test_scalable_tiles_scale_with_vl():
    """Widening the 'vector length' widens the layout (Fig 3 premise)."""
    base = make_layout("scalable", presets["tpu_vl128"], jnp.float32)
    wide = make_layout("scalable", presets["tpu_vl512"], jnp.float32)
    assert wide.n_r == 4 * base.n_r
    assert wide.k_r == 4 * base.k_r


def test_fixed_tiles_ignore_hardware():
    """The NEON property: frozen constants regardless of hardware."""
    a = make_layout("fixed", presets["tpu_vl128"], jnp.bfloat16)
    b = make_layout("fixed", presets["tpu_vl512"], jnp.bfloat16)
    assert (a.m_r, a.n_r, a.k_r) == (b.m_r, b.n_r, b.k_r) == (8, 128, 128)


def test_chain_compatibility():
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    assert lay.chain_compatible  # n_r == k_r: free propagation across matmuls


@given(m=st.integers(1, 4096), k=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_packed_shape_math(m, k):
    lay = make_layout("scalable", presets["tpu_v5e"], jnp.float32)
    mo, ko, mr, kr = lay.packed_lhs_shape(m, k)
    assert mo * mr >= m and (mo - 1) * mr < m
    assert ko * kr >= k and (ko - 1) * kr < k
    assert lay.flops(m, 1, k) == 2 * mo * mr * round_up(k, kr) * lay.n_r


@given(a=st.integers(1, 10**6), b=st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_ceil_div_round_up(a, b):
    assert ceil_div(a, b) * b >= a > (ceil_div(a, b) - 1) * b
    assert round_up(a, b) % b == 0


def test_hardware_query_env(monkeypatch):
    monkeypatch.setenv("REPRO_HW", "tpu_vl256")
    assert query().lanes == 256
    monkeypatch.delenv("REPRO_HW")
    assert query().name in presets


def test_scaled_spec_controls_only_width():
    """Scaling study premise: compute scales, memory system fixed."""
    hw = presets["tpu_v5e"]
    hw4 = hw.scaled(4)
    assert hw4.flops_bf16 == 4 * hw.flops_bf16
    assert hw4.hbm_bw == hw.hbm_bw and hw4.ici_bw == hw.ici_bw
