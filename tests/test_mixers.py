"""Mixer-level correctness: GQA attention, partial RoPE, MoE dispatch
invariants (hypothesis), Mamba scan vs sequential, RWKV6 chunking."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.core.layout import LayoutPolicy
from repro.core.linear import MatmulContext
from repro.models import attention, mamba, moe, rwkv6
from repro.models.common import apply_rope

CTX = MatmulContext(policy=LayoutPolicy.UNPACKED)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True):
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1)])
def test_core_attention_vs_naive_gqa(hq, hkv):
    b, s, dh = 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    got = attention.core_attention(q, k, v, causal=True,
                                   q_pos=jnp.arange(s))
    want = _naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    b, s, h, dh = 1, 8, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    qr, kr = apply_rope(q, k, jnp.arange(s))
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(qr, axis=-1)),
                               np.asarray(jnp.linalg.norm(q, axis=-1)),
                               rtol=1e-5)
    # relative property: <rot(q,i), rot(k,i)> is independent of i
    q0 = q[:, :1].repeat(s, 1)
    k0 = k[:, :1].repeat(s, 1)
    qr0, kr0 = apply_rope(q0, k0, jnp.arange(s))
    d = jnp.einsum("bshd,bshd->bsh", qr0, kr0)
    base = jnp.einsum("bshd,bshd->bsh",
                      *apply_rope(q0[:, :1], k0[:, :1], jnp.arange(1)))
    np.testing.assert_allclose(np.asarray(d[:, 0]), np.asarray(base[:, 0]),
                               rtol=1e-5)


def test_partial_rope_leaves_tail_untouched():
    b, s, h, dh = 1, 4, 1, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    qr, kr = apply_rope(q, k, jnp.arange(s), pct=0.5)
    np.testing.assert_array_equal(np.asarray(qr[..., 8:]),
                                  np.asarray(q[..., 8:]))
    assert not np.allclose(np.asarray(qr[..., :8]), np.asarray(q[..., :8]))


# ---------------------------------------------------------------------------
# MoE invariants (hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@given(seed=st.integers(0, 1000), tokens8=st.integers(1, 5),
       topk=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_invariants(seed, tokens8, topk):
    tokens = 8 * tokens8  # coarse token grid: bounds distinct XLA compiles
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-moe-235b-a22b")),
                              top_k=topk, capacity_factor=1.25)
    p = moe.moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, tokens, cfg.d_model))
    y, aux = moe.moe_apply(p, x, CTX, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped fraction within [0, 1); load balance >= 1 (perfectly balanced = 1)
    assert 0.0 <= float(aux["dropped_frac"]) < 1.0
    assert float(aux["load_balance"]) >= 0.5


def test_moe_zero_capacity_drop_effect():
    """With tiny capacity, most tokens are dropped -> output mostly zero."""
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-moe-235b-a22b")),
                              capacity_factor=0.01, dense_residual=False)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    y, aux = moe.moe_apply(p, x, CTX, cfg)
    assert float(aux["dropped_frac"]) > 0.5


# ---------------------------------------------------------------------------
# mamba / rwkv
# ---------------------------------------------------------------------------

def test_mamba_assoc_scan_matches_sequential():
    b, s, di, n = 2, 16, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    da = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, di, n)))
    dbx = jax.random.normal(ks[1], (b, s, di, n))
    h_par = mamba._ssm_scan(da, dbx)
    h = jnp.zeros((b, di, n))
    outs = []
    for t in range(s):
        h = da[:, t] * h + dbx[:, t]
        outs.append(h)
    h_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-6)


def test_rwkv_chunked_scan_matches_plain():
    """_CHUNK-divisible and ragged lengths agree with the step recurrence."""
    b, h, dh = 1, 2, 4
    for s in (rwkv6._CHUNK * 2, 37):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        r = jax.random.normal(ks[0], (b, s, h, dh))
        k = jax.random.normal(ks[1], (b, s, h, dh))
        v = jax.random.normal(ks[2], (b, s, h, dh))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dh)))
        u = jnp.zeros((h, dh))
        s0 = jnp.zeros((b, h, dh, dh))
        y, s_fin = rwkv6._wkv_scan(r, k, v, w, u, s0)
        # manual recurrence
        state = np.zeros((b, h, dh, dh), np.float32)
        ys = []
        rn, kn, vn, wn = map(np.asarray, (r, k, v, w))
        for t in range(s):
            a = kn[:, t][..., :, None] * vn[:, t][..., None, :]
            ys.append(np.einsum("bhij,bhi->bhj", state + 0 * a, rn[:, t]))
            state = wn[:, t][..., None] * state + a
        np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_fin), state, rtol=1e-4,
                                   atol=1e-4)


def test_moe_local_dispatch_matches_global():
    """§Perf it.8: per-DP-shard dispatch == global dispatch at high capacity
    (and deviates only via per-shard capacity semantics otherwise)."""
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-moe-235b-a22b")),
                              capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_g, _ = moe.moe_apply(p, x, CTX, cfg, local_dispatch=False)
    ctx_l = MatmulContext(policy=LayoutPolicy.UNPACKED, dp_size=4)
    y_l, _ = moe.moe_apply(p, x, ctx_l, cfg, local_dispatch=True)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_l),
                               rtol=2e-4, atol=2e-4)
