"""Multi-device dry-run integration tests.

These run in SUBPROCESSES because the dry-run needs
``--xla_force_host_platform_device_count`` set before JAX initializes,
while the rest of the suite must see 1 device.  Meshes are scaled down
(16 fake devices) — the full 256/512-chip sweep is the
``python -m repro.launch.dryrun --all --mesh both`` run recorded in
EXPERIMENTS.md.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json, sys, jax
    from repro.configs import RunConfig
    from repro.launch import dryrun
    dryrun.MESHES = {
        "pod": lambda: jax.make_mesh((4, 4), ("data", "model")),
        "multipod": lambda: jax.make_mesh((2, 2, 4), ("pod", "data", "model")),
    }
    arch, shape, mesh = sys.argv[1:4]
    run = RunConfig(microbatch=4)
    rec = dryrun.run_cell(arch, shape, mesh, run, out_dir=None, verbose=False)
    print("RESULT " + json.dumps({k: rec[k] for k in
        ("status", "bottleneck", "hlo_flops_per_chip",
         "collective_bytes_per_chip", "chips")}))
""")


def _run(arch, shape, mesh, timeout=540):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    p = subprocess.run([sys.executable, "-c", _SCRIPT, arch, shape, mesh],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("smollm2-135m", "train_4k"),
    ("smollm2-135m", "decode_32k"),
    ("whisper-small", "train_4k"),
])
def test_dryrun_pod_mesh(arch, shape):
    rec = _run(arch, shape, "pod")
    assert rec["status"] == "ok"
    assert rec["hlo_flops_per_chip"] > 0
    assert rec["chips"] == 16


@pytest.mark.slow
def test_dryrun_multipod_mesh():
    rec = _run("smollm2-135m", "train_4k", "multipod")
    assert rec["status"] == "ok"
    # the pod axis shards: collectives must exist across the mesh
    assert rec["collective_bytes_per_chip"] > 0


@pytest.mark.slow
def test_dryrun_long_context_ssm():
    """long_500k runs for the sub-quadratic arch (sequence-sharded state)."""
    rec = _run("rwkv6-1.6b", "decode_32k", "pod")
    assert rec["status"] == "ok"


def test_cell_matrix_skips():
    from repro.configs import cells, SHAPES, get_config, cell_status
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    # long_500k skips exactly the 8 pure full-attention archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, _, _ in skipped)
    assert {a for a, *_ in skipped} == {
        "qwen2-7b", "qwen3-8b", "olmo-1b", "chatglm3-6b", "whisper-small",
        "qwen3-moe-235b-a22b", "arctic-480b", "internvl2-26b"}
    # SSM/hybrid run it
    runnable_long = {a for a, s, ok, _ in all_cells if s == "long_500k" and ok}
    assert runnable_long == {"jamba-v0.1-52b", "rwkv6-1.6b"}
