"""Seeded-random property-check fallback for ``hypothesis``.

The property tests import ``from hypothesis import given, settings,
strategies as st``.  When hypothesis is not installed, ``conftest.py``
installs this module under ``sys.modules["hypothesis"]`` so the test modules
always collect and the properties still run — as a deterministic seeded
sweep instead of an adaptive search.

Semantics implemented (the subset the suite uses):
  - ``st.integers(lo, hi)``: uniform draw in [lo, hi] + the corner values
    (lo and hi are always exercised first — shrink-target analogues).
  - ``@settings(max_examples=N, deadline=...)``: records N on the function.
  - ``@given(**strategies)``: runs the wrapped test for
    ``min(N, REPRO_PROP_EXAMPLES)`` deterministic examples.  The draw
    sequence depends only on the test name, so runs are reproducible.

``REPRO_PROP_EXAMPLES`` (default 3) caps the per-property example count to
keep tier-1 fast (every distinct drawn shape is a fresh XLA compile); set it
higher for a deeper local sweep.
"""

from __future__ import annotations

import functools
import inspect
import os
import random

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 20


class _Integers:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def corner(self, i: int) -> int:
        return (self.lo, self.hi)[i % 2]

    def draw(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


class strategies:  # mimics `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        requested = getattr(fn, "_propcheck_max_examples", _DEFAULT_EXAMPLES)
        cap = int(os.environ.get("REPRO_PROP_EXAMPLES", "3"))
        n = max(2, min(requested, cap))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(f"propcheck:{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                if i < 2:  # corner examples first: all-lo, then all-hi
                    drawn = {k: s.corner(i) for k, s in strats.items()}
                else:
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i}: {drawn}") from e

        # pytest must not see the drawn parameters as fixtures
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for name, p in sig.parameters.items()
                        if name not in strats])
        del wrapper.__wrapped__
        return wrapper
    return deco
