"""Roofline machinery: while-aware HLO cost parser calibration."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_cost import parse_hlo, xla_cost_dict
from repro.roofline.analysis import model_flops
from repro.configs import SHAPES, get_config


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_dot_flops_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 32), jnp.float32))
    r = parse_hlo(c.as_text())
    assert r.dot_flops == 2 * 64 * 128 * 32


def test_scan_trip_counts_multiply():
    """The reason this parser exists: XLA cost_analysis counts a scan body
    once; parse_hlo multiplies by the trip count."""
    w = jnp.ones((64, 64))

    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=9)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((32, 64), jnp.float32))
    assert abs(xla_cost_dict(c.cost_analysis())["flops"]
               - 2 * 32 * 64 * 64) < 64                            # body once
    r = parse_hlo(c.as_text())
    assert r.dot_flops == 9 * 2 * 32 * 64 * 64                     # corrected
    assert list(r.while_trips.values()) == [9]


def test_nested_scan_trips():
    w = jnp.ones((32, 32))

    def f(x):
        def outer(c, _):
            c, _ = jax.lax.scan(lambda ci, _: (ci @ w, None), c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((8, 32), jnp.float32))
    r = parse_hlo(c.as_text())
    assert r.dot_flops == 15 * 2 * 8 * 32 * 32
    assert sorted(r.while_trips.values()) == [3, 5]


def test_batched_dot_flops():
    c = _compile(lambda q, k: jnp.einsum("bqhd,bkhd->bhqk", q, k),
                 jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.float32),
                 jax.ShapeDtypeStruct((2, 128, 4, 32), jnp.float32))
    r = parse_hlo(c.as_text())
    assert r.dot_flops == 2 * 2 * 4 * 64 * 128 * 32


def test_hbm_bytes_reasonable():
    c = _compile(lambda a, b: jnp.tanh(a @ b),
                 jax.ShapeDtypeStruct((256, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 256), jnp.float32))
    r = parse_hlo(c.as_text())
    lo = 3 * 256 * 256 * 4          # read a, b; write out
    assert lo <= r.hbm_bytes <= 6 * lo


def test_model_flops_kinds():
    cfg = get_config("qwen2-7b")
    n = cfg.param_counts()["active"]
    assert model_flops(cfg, SHAPES["train_4k"]) == 6.0 * n * 256 * 4096
    assert model_flops(cfg, SHAPES["prefill_32k"]) == 2.0 * n * 32 * 32768
    assert model_flops(cfg, SHAPES["decode_32k"]) == 2.0 * n * 128
    moe = get_config("qwen3-moe-235b-a22b")
    assert (model_flops(moe, SHAPES["train_4k"])
            == 6.0 * moe.param_counts()["active"] * 256 * 4096)
