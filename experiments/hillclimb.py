"""§Perf hillclimb driver: run RunConfig variants on the three chosen cells
and record per-variant roofline terms (experiments/perf/<tag>.json).

Cells (chosen from the baseline table):
  - qwen2-7b x train_4k      : most representative of the paper's technique
                               (dense, matmul-dominated)
  - qwen3-moe-235b x train_4k: most collective-bound
  - <worst-roofline cell>    : memory-bound decode/prefill representative

Usage: PYTHONPATH=src python experiments/hillclimb.py [--cell qwen2|moe|decode]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

from repro.configs import RunConfig
from repro.launch.dryrun import run_cell

BASE = RunConfig(microbatch=8)

VARIANTS = {
    # paper-faithful layout ablation: propagation ON (paper §4.3) vs OFF
    "baseline": BASE,
    "noprop": dataclasses.replace(BASE, propagate=False),
    "unpacked": dataclasses.replace(BASE, layout_policy="unpacked"),
    "fixed": dataclasses.replace(BASE, layout_policy="fixed"),
    # distribution iterations
    "nofsdp": dataclasses.replace(BASE, fsdp=False),
    "mb4": dataclasses.replace(BASE, microbatch=4),
    "mb16": dataclasses.replace(BASE, microbatch=16),
    "noseqkv": dataclasses.replace(BASE, seq_shard_kv=False),
    "moelocal": dataclasses.replace(BASE, moe_local_dispatch=True),
}

CELLS = {
    "qwen2": ("qwen2-7b", "train_4k",
              ["baseline", "noprop", "unpacked", "fixed", "nofsdp", "mb4",
               "mb16"]),
    "moe": ("qwen3-moe-235b-a22b", "train_4k",
            ["baseline", "noprop", "nofsdp", "mb4", "moelocal"]),
    "decode": ("qwen2-7b", "decode_32k",
               ["baseline", "noprop", "unpacked", "noseqkv"]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all", *CELLS.keys()])
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = CELLS if args.cell == "all" else {args.cell: CELLS[args.cell]}
    for cell, (arch, shape, variants) in todo.items():
        if args.variant:
            variants = [args.variant]
        for v in variants:
            run = VARIANTS[v]
            try:
                rec = run_cell(arch, shape, "pod", run, out_dir=None,
                               verbose=False)
                rec["variant"] = v
                tag = f"{cell}_{v}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                mem = (rec.get("memory_per_device") or {})
                print(f"[perf] {cell:7s} {v:9s}: "
                      f"cmp {rec['compute_s']*1e3:9.1f}ms "
                      f"mem {rec['memory_s']*1e3:9.1f}ms "
                      f"coll {rec['collective_s']*1e3:9.1f}ms "
                      f"temp {mem.get('temp_size_in_bytes', 0)/2**30:6.1f}GiB "
                      f"bound={rec['bottleneck']}")
            except Exception as e:
                print(f"[perf] {cell} {v}: FAIL {str(e)[:200]}")


if __name__ == "__main__":
    main()
