"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  t3_*    Table 3 / Fig 2a  (scalable vs fixed vs unpacked codegen)
  t45_*   Tables 4-5 / Fig 2b-c (packed pipeline vs compiled vs eager)
  fig3_*  Fig 3 (vector-length scaling study, roofline-model times)
  kern_*  kernel-level: pack amortization + BlockSpec working sets
  cell_*  roofline summary per dry-run cell (reads experiments/dryrun JSONs
          when present; see EXPERIMENTS.md)
"""

from __future__ import annotations

import glob
import json
import os


def _cells() -> None:
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(path) as f:
            rec = json.load(f)
        name = os.path.basename(path)[:-5]
        bound = rec.get("step_time_bound_s", 0.0) * 1e6
        print(f"cell_{name},{bound:.1f},"
              f"bottleneck={rec.get('bottleneck')};"
              f"roofline_frac={rec.get('roofline_fraction', 0):.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_packed_vs_fixed, bench_frameworks,
                            bench_vl_scaling, bench_kernels)
    bench_packed_vs_fixed.run()
    bench_frameworks.run()
    bench_vl_scaling.run()
    bench_kernels.run()
    _cells()


if __name__ == "__main__":
    main()
