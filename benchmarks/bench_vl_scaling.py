"""Paper Fig. 3 analogue: vector-length scaling study.

The paper runs the SAME binary on gem5 models that differ only in SVE
width (128/256/512) and shows near-ideal scaling on compute-bound matmuls,
collapse once memory-bound, and partial end-to-end scaling (non-matmul ops
don't scale).

Here, the same controlled experiment against the roofline model of
hypothetical TPUs that differ ONLY in vector width (``HardwareSpec.scaled``:
lanes x2/x4 => peak FLOPs x2/x4; memory system fixed — the same isolation
the paper's gem5 study makes).  For each workload we lower the *same layout-
parametric code* at each VL, derive compute/memory roofline times from the
compiled HLO, and report speedup vs VL-128.  Square matmuls N=64..2048 +
skinny-K (2048x2048x512) + SmolLM2-135M forward, mirroring the figure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core import make_layout, packed_matmul, presets
from repro.models.model import build_model
from repro.roofline.hlo_cost import parse_hlo

VLS = ["tpu_vl128", "tpu_vl256", "tpu_vl512"]


def _roofline_time(fn, specs, hw, dtype=jnp.float32,
                   compulsory_bytes: float | None = None) -> float:
    """max(compute, memory) seconds from the compiled HLO — the same
    bound the gem5 study measures in cycles.

    ``compulsory_bytes``: for the isolated-matmul cases, the memory term is
    the compulsory traffic (each operand streamed once) — the gem5 study's
    cache-resident setting where tiles stay in L2/L3 between reuses.  The
    end-to-end case uses the full parsed HBM-traffic model instead.
    """
    compiled = jax.jit(fn).lower(*specs).compile()
    cost = parse_hlo(compiled.as_text())
    peak = hw.peak_flops(dtype)
    nbytes = compulsory_bytes if compulsory_bytes is not None else cost.hbm_bytes
    return max(cost.dot_flops / peak, nbytes / hw.hbm_bw)


def run(**_) -> None:
    # -- square + skinny-K matmuls --------------------------------------
    cases = {f"mm{n}": (n, n, n) for n in (64, 128, 256, 512, 1024, 2048)}
    cases["skinnyK"] = (2048, 512, 2048)
    for name, (m, k, n) in cases.items():
        base = None
        for vl in VLS:
            hw = presets[vl]
            lay = make_layout("scalable", hw, jnp.float32)
            fn = lambda a, b, lay_=lay: packed_matmul(a, b, lay_)
            compulsory = 4.0 * (m * k + k * n + m * n)
            t = _roofline_time(
                fn, (jax.ShapeDtypeStruct((m, k), jnp.float32),
                     jax.ShapeDtypeStruct((k, n), jnp.float32)), hw,
                compulsory_bytes=compulsory)
            base = base or t
            emit(f"fig3_{name}_{vl}", t * 1e6,
                 f"speedup_vs_vl128={base / t:.2f}x")

    # -- end-to-end SmolLM2-135M forward (seq 32, like the paper) -------
    cfg = get_config("smollm2-135m")
    shape = ShapeSpec("fig3", 32, 1, "prefill")
    base = None
    for vl in VLS:
        hw = presets[vl]
        run_cfg = RunConfig(param_dtype="float32", compute_dtype="float32",
                            remat=False)
        mdl = build_model(cfg, run_cfg, shape, hw=hw)
        params_sds = jax.eval_shape(mdl.init, jax.random.PRNGKey(0))
        batch_sds = mdl.input_specs("prefill")
        t = _roofline_time(lambda p, b: mdl.forward(p, b)[0],
                           (params_sds, batch_sds), hw)
        base = base or t
        emit(f"fig3_smollm2_e2e_{vl}", t * 1e6,
             f"speedup_vs_vl128={base / t:.2f}x")


if __name__ == "__main__":
    run()
