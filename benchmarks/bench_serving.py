"""Serving throughput: continuous batching vs static batching at mixed
prompt lengths / token budgets; scalable vs fixed layout policy; lazy page
allocation vs eager full-lifetime reservation on a long-tail trace;
chunked prefill vs monolithic prefill on a mixed long/short-prompt trace
(time-to-first-token and inter-token latency percentiles); speculative
decoding vs plain decode on an n-gram-friendly trace (token-identical
outputs asserted for greedy and sampled, decode tokens per row-step as the
speedup measure); and the prefix cache vs cache-off on a shared-system-
prompt trace (token-identical outputs asserted across greedy/sampled,
monolithic/chunked and spec-on at <= 0.5x the prefill tokens computed,
plus a tight-pool run showing preempt-resume recomputing only the
uncached suffix); and an overload section replaying arrivals at 130% of
the calibrated capacity with an unbounded vs bounded wait queue
(bounded admission sheds typed ``rejected`` rows and holds p95 TTFT for
the admitted requests — shed rate recorded, surviving outputs asserted
token-identical to the offline drain).

Results are also written machine-readable to ``BENCH_serving.json`` (see
``--json-out``) so the repo's perf trajectory is tracked across PRs.

Workload: N requests with mixed prompt lengths and per-request budgets,
all available at t=0 (offline throughput).

  - static: requests are grouped into arrival-order batches of ``--slots``;
    each batch pads every prompt to the batch max and decodes lock-step to
    the batch-max budget (tokens past a request's own budget are waste —
    that, plus prompt padding, is exactly the cost continuous batching
    removes).  Padded prompts make static outputs approximate; this is a
    throughput comparison, correctness equivalence is proven in
    tests/test_scheduler.py.
  - continuous: every request is admitted into a paged-KV slot as one frees,
    prefilled at its own (m_r-bucketed) length, and retired the step its own
    budget completes.

Useful tokens are identical in both modes (each request's own budget), so
throughput ratios are directly comparable.  Each mode runs once untimed
(compile warmup) and once timed.

The **long-tail section** replays a trace where most requests have short
output budgets and a tail runs to the context limit, against a KV pool
sized at 50% of what eager reservation would need to keep every slot busy.
Eager admission serializes behind the tail's reservations; lazy allocation
admits by actual prompt size, grows pages per decode step, and preempts
(by recomputation) when the pool runs dry — same pool, higher mean slot
occupancy and 1.4-2x the throughput at the default sizes (CPU-host timing
is noisy; the occupancy gap is the stable signal), with bit-identical
greedy outputs (asserted against the eager baseline).  A chunked row runs
the same trace through the fused ragged step — outputs must again be
bit-identical, through folds, pauses and stalls.

The **chunked-prefill section** replays a mixed trace — decode-heavy short
requests punctuated by long prompts — at a fixed offered load (95% of the
calibrated monolithic capacity, the serving-benchmark standard) and
compares monolithic prefill (every admission freezes all decode slots for
one full-prompt forward) against the fused chunked step (each admission is
spread across steps at ``chunk_tokens`` per step while every decode row
keeps advancing).  The headline is the p95 inter-token latency at equal
delivered throughput: under monolithic prefill the p95 ITL *is* the
long-prompt prefill time; chunked bounds it near one fused step — >= 2x
better at the default sizes (90%+ offered load, 0.95 in the default run).
Outputs are asserted token-identical, so the latency win is free.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py
Toy:  PYTHONPATH=src python benchmarks/bench_serving.py --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import time
from datetime import datetime, timezone

import jax
import numpy as np

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core.layout import ceil_div, round_up
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.speculative import DraftModelDrafter


def make_workload(cfg, n, max_prompt, max_new, seed=0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        reqs.append((prompt, int(rng.integers(2, max_new + 1))))
    return reqs


def run_static(engine: Engine, reqs, slots: int) -> int:
    """Arrival-order batches, prompts padded to the batch max, lock-step
    decode to the batch-max budget.  Returns useful token count."""
    useful = 0
    for i in range(0, len(reqs), slots):
        chunk = reqs[i:i + slots]
        plen = max(p.shape[0] for p, _ in chunk)
        budget = max(n for _, n in chunk)
        toks = np.zeros((len(chunk), plen), np.int32)
        for j, (p, _) in enumerate(chunk):
            toks[j, :p.shape[0]] = p
        engine.generate_static({"tokens": toks}, budget)
        useful += sum(n for _, n in chunk)
    return useful


def run_continuous(engine: Engine, reqs) -> int:
    for p, n in reqs:
        engine.add_request(p, n)
    finished = engine.drain()
    return sum(len(r.out_tokens) for r in finished)


def bench(model, params, reqs, slots, mode) -> tuple[float, int]:
    runner = {"static": lambda e: run_static(e, reqs, slots),
              "continuous": lambda e: run_continuous(e, reqs)}[mode]
    runner(Engine(model, params, max_slots=slots))      # compile warmup
    eng = Engine(model, params, max_slots=slots)
    t0 = time.perf_counter()
    useful = runner(eng)
    return time.perf_counter() - t0, useful


# ---------------------------------------------------------------------------
# long-tail trace: lazy allocation vs eager reservation at the same pool size
# ---------------------------------------------------------------------------

def make_longtail_workload(cfg, n, max_prompt, max_new, max_len, seed=0):
    """Short prompts; most requests want a short continuation but every 4th
    runs to the context limit — the output-length distribution where eager
    full-lifetime reservation idles most of a pool sized for the average
    (the reservation is all *future* tokens, which lazy allocation defers)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, max(3, max_prompt // 4) + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        budget = (max_len - plen) if i % 4 == 3 \
            else int(rng.integers(2, max_new + 1))
        reqs.append((prompt, budget))
    return reqs


def run_longtail(model, params, reqs, slots, *, eager, num_pages,
                 page_tokens=16, chunk_tokens=None):
    # flat=False: this section compares allocation policies over the dense
    # chunked step; the flat layout gets its own A/B in bench_flat
    eng = Engine(model, params, max_slots=slots, eager=eager,
                 num_pages=num_pages, page_tokens=page_tokens,
                 chunk_tokens=chunk_tokens, flat=False)
    eng.warmup()       # compile decode + every prefill bucket before timing
    rids = [eng.add_request(p, n) for p, n in reqs]
    t0 = time.perf_counter()
    fin, steps = {}, 0
    while eng.scheduler.has_work:
        fin.update((r.rid, r) for r in eng.step())
        steps += 1
    dt = time.perf_counter() - t0
    assert sorted(fin) == sorted(rids), "drain lost requests"
    outs = [fin[rid].out_tokens for rid in rids]
    return eng, outs, dt, steps


def bench_longtail(model, params, reqs, slots, chunk_tokens):
    # page size the engine will actually use (16 rounded up to the layout m_r)
    pt = round_up(16, model.ctx.layout(model.compute_dtype).m_r)
    per_req = [ceil_div(p.shape[0] + n - 1, pt) for p, n in reqs]
    eager_pages = slots * max(per_req)     # eager never page-blocked
    half = 1 + eager_pages // 2            # +1: trash page
    total_new = sum(n for _, n in reqs)
    print(f"[bench_serving] long-tail: {len(reqs)} requests, "
          f"{total_new} tokens, {slots} slots, page={pt} tok; "
          f"eager requirement {eager_pages} pages, pool capped at "
          f"{half - 1} (50%)")

    base_eng, base_out, base_dt, base_steps = run_longtail(
        model, params, reqs, slots, eager=True, num_pages=1 + eager_pages,
        page_tokens=pt)
    rows = [("eager/full", base_eng, base_out, base_dt, base_steps,
             1 + eager_pages)]
    policies = [("eager/half", True, None), ("lazy/half", False, None)]
    if all(t == "attn" for t in model.cfg.layer_types):
        # hybrids keep monolithic prefill (scan state is not inert on
        # padded chunk rows) — no chunked row for them
        policies.append(("lazy/half/chunked", False, chunk_tokens))
    for label, eager, chunk in policies:
        eng, outs, dt, steps = run_longtail(model, params, reqs, slots,
                                            eager=eager, num_pages=half,
                                            page_tokens=pt,
                                            chunk_tokens=chunk)
        rows.append((label, eng, outs, dt, steps, half))
    record = {}
    for label, eng, outs, dt, steps, pages in rows:
        s = eng.scheduler
        # mean slot occupancy: tokens produced per engine step — eager
        # reservation idles slots behind long-tail page reservations
        print(f"  {label:<17} {total_new / dt:8.1f} tok/s ({dt:.2f}s)  "
              f"concurrency={total_new / steps:.2f} avg / "
              f"{s.peak_running} peak  "
              f"preemptions={s.num_preemptions} pauses={s.num_pauses}  "
              f"peak_pages={eng.pool.peak_used}/{pages - 1}")
        # the tentpole contract: whatever the policy — eager or lazy,
        # monolithic or chunked, through folds/pauses/stalls — the tokens
        # are identical
        assert outs == base_out, \
            f"{label}: outputs diverged from the eager baseline"
        assert eng.pool.num_used == 0, f"{label}: leaked pages"
        record[label] = {"tok_per_s": total_new / dt, "steps": steps,
                         "preemptions": s.num_preemptions,
                         "pauses": s.num_pauses,
                         "peak_pages": eng.pool.peak_used}
    lazy_eng, lazy_steps = rows[2][1], rows[2][4]
    eager_half_steps = rows[1][4]
    assert lazy_eng.scheduler.num_preemptions >= 1, \
        "long-tail trace at 50% pool should force at least one preemption"
    ratio = eager_half_steps / lazy_steps
    record["lazy_vs_eager_concurrency"] = ratio
    record["chunk_tokens"] = chunk_tokens   # per-section provenance
    print(f"  lazy/eager mean concurrency at the same pool = {ratio:.2f}x; "
          f"outputs token-identical across all {len(rows)} runs")
    return record


# ---------------------------------------------------------------------------
# chunked prefill vs monolithic: TTFT and inter-token latency percentiles
# ---------------------------------------------------------------------------

def make_mixed_trace(cfg, n, max_len, seed=0):
    """Decode-heavy short requests punctuated by long prompts (every 3rd):
    the workload where a monolithic prefill freezes every running decode
    for one full-prompt forward, so the long prompts' admissions *are* the
    monolithic p95 inter-token latency.  Long prompts sit just past the
    half-context power-of-two boundary: the monolithic policy's geometric
    bucket pads them to a full ``max_len`` forward (the compile-count
    compromise recompute-prefills force on it), while the chunked policy
    pays exact ``ceil(len/chunk)`` chunks — bucket padding is a real cost
    of the monolithic design, not a benchmark artifact."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        if i % 3 == 2:
            plen = int(rng.integers(max_len // 2 + 2, max_len * 9 // 16 + 2))
            budget = int(rng.integers(4, 9))
        else:
            plen = int(rng.integers(2, 9))
            budget = int(rng.integers(12, 25))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        reqs.append((prompt, budget))
    return reqs


def run_traced(model, params, reqs, slots, *, chunk_tokens, num_pages=None,
               page_tokens=16, arrivals=None, flat=False):
    """Serve ``reqs`` recording a wall-clock stamp per generated token.
    ``arrivals`` (seconds, per request) replays an online offered load —
    ``Engine.step(now=...)`` gates admission by wall time; ``None`` drains
    offline.  Returns (outputs, per-request token-time lists, wall seconds,
    engine).  ``flat`` is passed explicitly (default dense): the engine's
    own default turns the flat step on with chunking, and the A/B sections
    here need the dense [slots, chunk] grid as a named baseline."""
    eng = Engine(model, params, max_slots=slots, num_pages=num_pages,
                 page_tokens=page_tokens, chunk_tokens=chunk_tokens,
                 flat=flat)
    eng.warmup()
    compiles = dict(model.trace_counts)
    arr = arrivals or [0.0] * len(reqs)
    rids = [eng.add_request(p, n, arrival=a)
            for (p, n), a in zip(reqs, arr)]
    times = {rid: [] for rid in rids}
    seen, fin = {}, {}
    t0 = time.perf_counter()
    while eng.scheduler.has_work:
        now = time.perf_counter() - t0
        done = eng.step(now=now if arrivals is not None else None)
        t = time.perf_counter() - t0
        fin.update((r.rid, r) for r in done)
        for r in list(eng.scheduler.running.values()) + done:
            have = seen.get(r.rid, 0)
            if len(r.out_tokens) > have:
                times[r.rid].extend([t] * (len(r.out_tokens) - have))
                seen[r.rid] = len(r.out_tokens)
        if not eng.scheduler.running and not done:
            time.sleep(5e-4)             # idle gap before the next arrival
    dt = time.perf_counter() - t0
    assert dict(model.trace_counts) == compiles, \
        "step() compiled a new XLA program after warmup()"
    assert sorted(fin) == sorted(rids), "drain lost requests"
    outs = [fin[rid].out_tokens for rid in rids]
    return outs, [times[rid] for rid in rids], dt, eng


def _latency_metrics(token_times, dt, total_new, arrivals=None):
    arr = arrivals or [0.0] * len(token_times)
    ttft = [ts[0] - a for ts, a in zip(token_times, arr) if ts]
    itl = [b - a for ts in token_times for a, b in zip(ts, ts[1:])]
    pct = lambda xs, q: float(np.percentile(np.asarray(xs), q)) if xs else 0.0
    return {"tok_per_s": total_new / dt, "wall_s": dt,
            "ttft_p50_ms": 1e3 * pct(ttft, 50),
            "ttft_p95_ms": 1e3 * pct(ttft, 95),
            "itl_p50_ms": 1e3 * pct(itl, 50),
            "itl_p95_ms": 1e3 * pct(itl, 95)}


def bench_chunked(model, params, reqs, slots, chunk_tokens, load=0.95,
                  repeats=4):
    """Monolithic vs fused-chunked prefill at a fixed offered load (the
    serving-benchmark standard: calibrate capacity offline, then replay the
    same arrival schedule at ``load`` x capacity under both policies) —
    identical tokens asserted, p95 ITL and throughput compared.  Ratios are
    medians of per-round pairs, so host drift cancels.  Target: >= 2x p95
    ITL improvement at equal-or-better throughput.

    Why online: in an offline drain the queue is permanently backlogged, so
    spreading a prefill across steps defers its decode phase and stretches
    the makespan (~0.95x on this CPU toy — recorded as
    ``offline_throughput_ratio``); under an offered load the schedule
    absorbs that slack and the stall removal is visible where it matters,
    in the inter-token tail at the same delivered throughput."""
    total_new = sum(n for _, n in reqs)
    nlong = sum(1 for i in range(len(reqs)) if i % 3 == 2)
    # calibrate: one warm pass per policy (also compiles), then a timed
    # offline drain per policy — monolithic's sets the offered load
    run_traced(model, params, reqs, slots, chunk_tokens=None)
    base_out, _, dt_m, _ = run_traced(model, params, reqs, slots,
                                      chunk_tokens=None)
    run_traced(model, params, reqs, slots, chunk_tokens=chunk_tokens)
    outs, _, dt_c, _ = run_traced(model, params, reqs, slots,
                                  chunk_tokens=chunk_tokens)
    assert outs == base_out, \
        "chunked outputs diverged from monolithic prefill (offline)"
    cap = total_new / dt_m
    arrivals = (np.cumsum([n for _, n in reqs]) / (load * cap)).tolist()
    print(f"[bench_serving] chunked prefill: {len(reqs)} requests "
          f"({nlong} long prompts), {total_new} tokens, {slots} slots, "
          f"chunk={chunk_tokens}; offered load = {load:.2f} x "
          f"{cap:.0f} tok/s monolithic capacity")
    if repeats < 1:        # smoke: the offline equality assert is the point
        ratio = (total_new / dt_c) / cap
        print(f"  outputs token-identical offline at {ratio:.2f}x the "
              f"monolithic drain throughput (smoke skips the online rounds)")
        return {"offline_throughput_ratio": ratio, "capacity_tok_s": cap,
                "chunk_tokens": chunk_tokens}

    rounds = {"monolithic": [], "chunked": []}
    for _ in range(repeats):
        for label, chunk in (("monolithic", None),
                             ("chunked", chunk_tokens)):
            outs, times, dt, eng = run_traced(
                model, params, reqs, slots, chunk_tokens=chunk,
                arrivals=arrivals)
            assert outs == base_out, \
                f"{label}: online outputs diverged (admission timing must " \
                f"not change tokens — rows are independent)"
            m = _latency_metrics(times, dt, total_new, arrivals)
            st = eng.stats()
            m.update(mean_slot_occupancy=st["mean_slot_occupancy"],
                     prefill_stall_steps=st["prefill_stall_steps"],
                     chunks_per_prompt=st["chunks_per_prompt"],
                     preemptions=st["num_preemptions"],
                     pauses=st["num_pauses"])
            rounds[label].append(m)

    med = lambda runs, k: float(np.median([r[k] for r in runs]))
    record = {}
    for label, runs in rounds.items():
        m = {k: med(runs, k) for k in runs[0] if isinstance(runs[0][k],
                                                           (int, float))}
        record[label] = m
        print(f"  {label:<11} {m['tok_per_s']:8.1f} tok/s  "
              f"ttft p50/p95 = {m['ttft_p50_ms']:6.1f}/{m['ttft_p95_ms']:6.1f} ms  "
              f"itl p50/p95 = {m['itl_p50_ms']:5.1f}/{m['itl_p95_ms']:6.1f} ms")
    pair = zip(rounds["monolithic"], rounds["chunked"])
    ratios = [(mm["itl_p95_ms"] / max(1e-9, mc["itl_p95_ms"]),
               mc["tok_per_s"] / max(1e-9, mm["tok_per_s"]))
              for mm, mc in pair]
    itl_ratio = float(np.median([r[0] for r in ratios]))
    thr_ratio = float(np.median([r[1] for r in ratios]))
    record["itl_p95_improvement"] = itl_ratio
    record["throughput_ratio"] = thr_ratio
    record["offered_load"] = load
    record["chunk_tokens"] = chunk_tokens   # per-section provenance
    record["offline_throughput_ratio"] = (total_new / dt_c) / cap
    tag = ("OK (>= 2x, throughput >= 1x)"
           if itl_ratio >= 2.0 and thr_ratio >= 0.98 else "BELOW TARGET")
    print(f"  p95 ITL improvement = {itl_ratio:.2f}x at "
          f"{thr_ratio:.2f}x delivered throughput (offline drain "
          f"{record['offline_throughput_ratio']:.2f}x)  [{tag}]; "
          f"outputs token-identical")
    return record


def run_overload(model, params, reqs, slots, *, chunk_tokens, arrivals,
                 queue_limit=None, page_tokens=16):
    """Online replay that feeds each request to ``Engine.add_request`` only
    once its arrival time has passed, so admission control sheds against
    the queue the server actually has at that moment (enqueueing the whole
    trace up-front would let it reject against requests that haven't
    arrived yet).  Returns (finished Requests in trace order — shed rows
    carry ``finish_reason == "rejected"`` and no tokens — per-request
    token-time lists, wall seconds, engine)."""
    eng = Engine(model, params, max_slots=slots, page_tokens=page_tokens,
                 chunk_tokens=chunk_tokens, flat=False,
                 queue_limit=queue_limit)
    eng.warmup()
    compiles = dict(model.trace_counts)
    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    times = [[] for _ in reqs]
    fin, seen, by_rid, nxt = {}, {}, {}, 0
    t0 = time.perf_counter()
    while len(fin) < len(reqs):
        now = time.perf_counter() - t0
        while nxt < len(order) and arrivals[order[nxt]] <= now:
            i = order[nxt]
            rid = eng.add_request(reqs[i][0], reqs[i][1],
                                  arrival=arrivals[i])
            by_rid[rid] = i
            nxt += 1
        done = eng.step(now=now)
        t = time.perf_counter() - t0
        fin.update((by_rid[r.rid], r) for r in done)
        for r in list(eng.scheduler.running.values()) + done:
            i = by_rid[r.rid]
            have = seen.get(i, 0)
            if len(r.out_tokens) > have:
                times[i].extend([t] * (len(r.out_tokens) - have))
                seen[i] = len(r.out_tokens)
        if not eng.scheduler.running and not done:
            time.sleep(5e-4)             # idle gap before the next arrival
    dt = time.perf_counter() - t0
    assert dict(model.trace_counts) == compiles, \
        "overload step() compiled a new XLA program after warmup()"
    assert eng.pool.num_used == 0, "leaked pages"
    return [fin[i] for i in range(len(reqs))], times, dt, eng


def bench_overload(model, params, reqs, slots, chunk_tokens, load=1.3,
                   repeats=3):
    """Overload: the trace replayed at ``load`` x the calibrated offline
    capacity — an arrival rate the engine cannot sustain — with an
    unbounded wait queue vs the bounded one (``queue_limit = slots``: one
    queued request per busy slot).  Unbounded, every arrival is eventually
    served but the backlog (and thus TTFT) grows for the whole burst;
    bounded, ``Scheduler.add`` sheds arrivals over the limit as typed
    ``rejected`` rows in O(1) and admitted requests keep a bounded wait.
    The headline: bounded p95 TTFT over *admitted* requests <= unbounded,
    with the shed rate recorded — the requests the bounded queue turned
    away are exactly the latency the unbounded queue makes everyone pay.
    Admitted outputs are asserted token-identical to the offline drain:
    admission timing and shedding must not change surviving tokens."""
    total_new = sum(n for _, n in reqs)
    # calibrate: one warm pass (compiles), then a timed offline drain
    run_traced(model, params, reqs, slots, chunk_tokens=chunk_tokens)
    base_out, _, dt_off, _ = run_traced(model, params, reqs, slots,
                                        chunk_tokens=chunk_tokens)
    cap = total_new / dt_off
    arrivals = (np.cumsum([n for _, n in reqs]) / (load * cap)).tolist()
    qlim = max(1, slots)
    print(f"[bench_serving] overload: {len(reqs)} requests, {total_new} "
          f"tokens, {slots} slots, chunk={chunk_tokens}; offered load = "
          f"{load:.2f} x {cap:.0f} tok/s capacity; bounded "
          f"queue_limit={qlim}")

    rounds = {"unbounded": [], "bounded": []}
    for _ in range(repeats):
        for label, ql in (("unbounded", None), ("bounded", qlim)):
            fin, times, dt, eng = run_overload(
                model, params, reqs, slots, chunk_tokens=chunk_tokens,
                arrivals=arrivals, queue_limit=ql)
            admitted = [i for i, r in enumerate(fin)
                        if r.finish_reason != "rejected"]
            shed = len(reqs) - len(admitted)
            for i in admitted:
                assert fin[i].out_tokens == base_out[i], \
                    f"{label}: admitted request {i} diverged under " \
                    f"overload (shedding must not change survivors)"
            assert eng.stats()["resilience"]["sheds"] == shed, \
                "shed count disagrees with the resilience counters"
            served = sum(len(fin[i].out_tokens) for i in admitted)
            m = _latency_metrics([times[i] for i in admitted], dt, served,
                                 [arrivals[i] for i in admitted])
            m["shed_rate"] = shed / len(reqs)
            m["admitted"] = len(admitted)
            rounds[label].append(m)

    med = lambda runs, k: float(np.median([r[k] for r in runs]))
    record = {"offered_load": load, "queue_limit": qlim,
              "capacity_tok_s": cap, "chunk_tokens": chunk_tokens}
    for label, runs in rounds.items():
        m = {k: med(runs, k) for k in runs[0]}
        record[label] = m
        print(f"  {label:<10} ttft p50/p95 = {m['ttft_p50_ms']:6.1f}/"
              f"{m['ttft_p95_ms']:7.1f} ms  {m['tok_per_s']:8.1f} tok/s "
              f"(admitted)  shed rate {m['shed_rate']:.2f}")
    ratios = [b["ttft_p95_ms"] / max(1e-9, u["ttft_p95_ms"])
              for u, b in zip(rounds["unbounded"], rounds["bounded"])]
    ratio = float(np.median(ratios))
    record["ttft_p95_bounded_vs_unbounded"] = ratio
    if record["bounded"]["shed_rate"] == 0:
        # nothing was shed, so both runs served the identical schedule —
        # the ratio is host noise, not an admission-control signal
        tag = "NO SHEDS (queue never filled at this scale)"
    elif ratio <= 1.0:
        tag = "OK (<= 1x)"
    else:
        tag = "ABOVE UNBOUNDED"
    print(f"  bounded/unbounded p95 TTFT = {ratio:.2f}x at shed rate "
          f"{record['bounded']['shed_rate']:.2f}  [{tag}]; admitted "
          f"outputs token-identical to the offline drain")
    return record


def bench_flat(model, params, reqs, slots, chunk_tokens, smoke, repeats=3):
    """Flat [1, budget] token-level step vs the dense [slots, chunk] grid
    and the monolithic baseline, offline drains.  The contract half (what
    ``tier1.sh --bench-smoke`` buys): all three drains must produce
    token-identical outputs — a flat-vs-chunked mismatch fails the run.
    The perf half: the flat step computes only its real tokens plus
    m_r-ladder padding where the dense grid always pays slots x chunk
    positions, so its offline throughput should sit at or above the dense
    step's (target >= 0.99x monolithic); ``fill`` reports real tokens per
    compiled position (the padding tax)."""
    total_new = sum(n for _, n in reqs)
    print(f"[bench_serving] flat step: {len(reqs)} requests, "
          f"{total_new} tokens, {slots} slots, chunk={chunk_tokens}")
    # one warm pass per policy (compiles), then timed offline drains
    run_traced(model, params, reqs, slots, chunk_tokens=None)
    run_traced(model, params, reqs, slots, chunk_tokens=chunk_tokens,
               flat=False)
    run_traced(model, params, reqs, slots, chunk_tokens=chunk_tokens,
               flat=True)
    ratios_m, ratios_c, st = [], [], None
    for _ in range(1 if smoke else repeats):
        base_out, _, dt_m, _ = run_traced(model, params, reqs, slots,
                                          chunk_tokens=None)
        dense_out, _, dt_c, _ = run_traced(model, params, reqs, slots,
                                           chunk_tokens=chunk_tokens,
                                           flat=False)
        flat_out, _, dt_f, eng = run_traced(model, params, reqs, slots,
                                            chunk_tokens=chunk_tokens,
                                            flat=True)
        assert flat_out == dense_out, \
            "flat step outputs diverged from the dense chunked step"
        assert flat_out == base_out, \
            "flat step outputs diverged from monolithic prefill"
        ratios_m.append(dt_m / dt_f)
        ratios_c.append(dt_c / dt_f)
        st = eng.stats()["flat"]
    record = {
        "chunk_tokens": chunk_tokens,
        "token_budget": st["token_budget"],
        "offline_throughput_ratio": float(np.median(ratios_m)),
        "flat_vs_chunked_ratio": float(np.median(ratios_c)),
        "fill": st["fill"],
        "mean_tokens_per_step": st["mean_tokens"],
        "mean_width": st["mean_width"],
    }
    tag = ("OK (>= 0.99x)" if record["offline_throughput_ratio"] >= 0.99
           else "BELOW 0.99x TARGET")
    print(f"  flat drain {record['offline_throughput_ratio']:.3f}x "
          f"monolithic ({record['flat_vs_chunked_ratio']:.2f}x the dense "
          f"chunked step), fill={record['fill']:.2f}  [{tag}]; outputs "
          f"token-identical across flat/chunked/monolithic")
    return record


# ---------------------------------------------------------------------------
# prefix cache: shared-system-prompt trace, cache-on vs cache-off
# ---------------------------------------------------------------------------

def make_prefix_trace(cfg, n, sys_tokens, max_new, seed=0):
    """Every request = one shared system prompt + a short unique suffix —
    the prompt-caching workload (few-shot headers, agent scaffolds) where
    a prefix cache pays: the shared pages are computed once per *content*
    and every later arrival prefills only its own suffix.  Arrivals are
    staggered so admissions see earlier requests' pages (concurrent
    admissions of a cold prefix cannot share — someone must compute it)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    sysp = np.asarray(jax.random.randint(key, (sys_tokens,), 0, cfg.vocab))
    reqs = []
    for i in range(n):
        sfx = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                            (int(rng.integers(2, 7)),), 0,
                                            cfg.vocab))
        reqs.append((np.concatenate([sysp, sfx]),
                     int(rng.integers(3, max_new + 1))))
    return reqs


def run_prefix(model, params, reqs, slots, *, prefix_cache, chunk_tokens=None,
               spec_tokens=None, num_pages=None, greedy=True, seed=0,
               page_tokens=16):
    """Warmed, staggered drain with the zero-recompile assert and (cache
    on) the end-of-drain balance check: clearing the cache must return the
    pool to zero used pages with allocs+shares == frees."""
    # flat=False keeps the "chunked/..." rows on the dense grid they name
    eng = Engine(model, params, max_slots=slots, page_tokens=page_tokens,
                 num_pages=num_pages, chunk_tokens=chunk_tokens,
                 spec_tokens=spec_tokens, prefix_cache=prefix_cache,
                 flat=False)
    eng.warmup()
    compiles = dict(model.trace_counts)
    rids = [eng.add_request(p, n, arrival=float(2 * i))
            for i, (p, n) in enumerate(reqs)]
    clock, fin = 0.0, {}
    t0 = time.perf_counter()
    while eng.scheduler.has_work:
        fin.update((r.rid, r) for r in eng.step(now=clock, greedy=greedy,
                                                seed=seed))
        clock += 1.0
    dt = time.perf_counter() - t0
    assert dict(model.trace_counts) == compiles, \
        "prefix-cache step() compiled a new XLA program after warmup()"
    assert sorted(fin) == sorted(rids), "drain lost requests"
    if eng.prefix_cache is not None:
        eng.prefix_cache.clear()
    assert eng.pool.num_used == 0, "leaked pages"
    assert eng.pool.total_allocs + eng.pool.total_shares \
        == eng.pool.total_frees, "alloc/share/free imbalance"
    return eng, [fin[rid].out_tokens for rid in rids], dt


def bench_prefix(model, params, reqs, slots, chunk_tokens, spec_tokens,
                 smoke):
    """Cache-on vs cache-off on the shared-prefix trace.  The contract
    half (what ``tier1.sh --bench-smoke`` buys): outputs token-identical
    across greedy/sampled, monolithic/chunked and spec-on, at <= 0.5x the
    prefill tokens computed.  The perf half: prefill tokens saved, plus a
    tight-pool run where preemption releases pages into the cache — the
    resume recompute is bounded by tokens generated since admission + one
    partial page, against the PR-2 baseline's full-reprefill recompute."""
    total_prompt = sum(p.shape[0] for p, _ in reqs)
    print(f"[bench_serving] prefix cache: {len(reqs)} requests sharing one "
          f"system prompt ({total_prompt} prompt tokens total), "
          f"{slots} slots")
    base, base_out, base_dt = run_prefix(model, params, reqs, slots,
                                         prefix_cache=False)
    _, base_out_s, _ = run_prefix(model, params, reqs, slots,
                                  prefix_cache=False, greedy=False, seed=13)
    off_tokens = base.stats()["prefill_tokens"]
    record = {"requests": len(reqs), "prompt_tokens": total_prompt,
              "prefill_tokens_off": off_tokens}
    rows = [("mono/greedy", dict()),
            ("chunked/greedy", dict(chunk_tokens=chunk_tokens)),
            ("mono/sampled", dict(greedy=False, seed=13)),
            ("spec/greedy", dict(spec_tokens=spec_tokens))]
    if not smoke:
        rows += [("chunked/sampled", dict(chunk_tokens=chunk_tokens,
                                          greedy=False, seed=13)),
                 ("spec/sampled", dict(spec_tokens=spec_tokens,
                                       greedy=False, seed=13)),
                 ("chunked+spec/greedy", dict(chunk_tokens=chunk_tokens,
                                              spec_tokens=spec_tokens))]
    for label, kw in rows:
        eng, outs, dt = run_prefix(model, params, reqs, slots,
                                   prefix_cache=True, **kw)
        want = base_out_s if kw.get("greedy") is False else base_out
        assert outs == want, \
            f"prefix cache ({label}) outputs diverged from cache-off"
        st = eng.stats()
        pc = st["prefix_cache"]
        on_tokens = st["prefill_tokens"]
        assert on_tokens <= 0.5 * off_tokens, \
            f"{label}: prefill {on_tokens} tokens > 0.5x cache-off " \
            f"{off_tokens} on a shared-prefix trace"
        record[label] = {"prefill_tokens": on_tokens,
                         "prefill_ratio": on_tokens / off_tokens,
                         "hit_rate": pc["hit_rate"],
                         "hit_tokens": pc["hit_tokens"],
                         "cow_copies": pc["cow_copies"],
                         "evictions": pc["evictions"],
                         "tok_per_s": sum(len(o) for o in outs) / dt}
        print(f"  {label:<19} prefill {on_tokens:>5}/{off_tokens} tokens "
              f"({on_tokens / off_tokens:.2f}x)  hit rate {pc['hit_rate']:.2f}"
              f"  cow={pc['cow_copies']} evictions={pc['evictions']}")

    # preempt-resume: short prompts with long budgets on a pool sized well
    # below the concurrent working set, so *growth* (not admission) hits
    # OutOfPages and preempts.  With the cache, the victim's pages go into
    # the cache and its resume recomputes only the uncached suffix; the
    # PR-2 baseline re-prefills the whole folded prompt
    pt = round_up(16, model.ctx.layout(model.compute_dtype).m_r)
    key = jax.random.PRNGKey(17)
    cfg_vocab = int(model.cfg.vocab)
    preqs = [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                            (pt // 2 + i % 3,), 0,
                                            cfg_vocab)),
              2 * pt - 3 * (i % 3))
             for i in range(2 * slots)]
    per_req = max(ceil_div(p.shape[0] + n - 1, pt) for p, n in preqs)
    tight_pages = 1 + max(per_req + 1, (slots * per_req) * 2 // 3)
    tight_kw = dict(num_pages=tight_pages, page_tokens=pt)
    _, ample_out, _ = run_prefix(model, params, preqs, slots,
                                 prefix_cache=False)
    off_eng, off_out, _ = run_prefix(model, params, preqs, slots,
                                     prefix_cache=False, **tight_kw)
    on_eng, on_out, _ = run_prefix(model, params, preqs, slots,
                                   prefix_cache=True, **tight_kw)
    assert on_out == ample_out and off_out == ample_out, \
        "tight-pool outputs diverged (preemption must not change tokens)"
    assert off_eng.num_preemptions >= 1, \
        "the tight pool should force at least one preemption"
    total_pprompt = sum(p.shape[0] for p, _ in preqs)
    off_recompute = off_eng.stats()["prefill_tokens"] - total_pprompt
    on_sched = on_eng.scheduler.stats()
    record["preempt_resume"] = {
        "pool_pages": tight_pages - 1,
        "preemptions_off": off_eng.num_preemptions,
        "preemptions_on": on_eng.num_preemptions,
        "recompute_tokens_off": off_recompute,
        "resumes_on": on_sched["resumes"],
        "recompute_tokens_on": on_sched["resume_recompute_tokens"],
    }
    for e in on_eng.scheduler.resume_events:
        # reclaims and pool-pressure evictions legitimately lose the cached
        # prefix before the resume; every other resume must hit it
        assert e["reclaimed"] or e["evicted"] or \
            e["recompute"] <= e["generated_since"] + pt, \
            f"resume recomputed past the uncached suffix: {e}"
    print(f"  preempt-resume at {tight_pages - 1} pages: cache-off "
          f"recomputed {off_recompute} tokens "
          f"({off_eng.num_preemptions} preemptions); cache-on recomputed "
          f"{on_sched['resume_recompute_tokens']} over "
          f"{on_sched['resumes']} resumes "
          f"({on_eng.num_preemptions} preemptions) — bounded by "
          f"generated-since-admission + one partial page")
    print(f"  outputs token-identical to cache-off for all "
          f"{len(rows)} cache-on configs (greedy + sampled)")
    return record


# ---------------------------------------------------------------------------
# speculative decoding: drafted verify steps vs one-token decode steps
# ---------------------------------------------------------------------------

def make_spec_trace(cfg, n, max_new, seed=0):
    """Decode-heavy, n-gram-friendly trace: every prompt tiles a short
    random motif (prompt-lookup's best case — the context is its own draft
    model) and budgets run long, so greedy decodes of the toy model settle
    into loops the self-ngram drafter also predicts.  This is the honest
    *favourable* workload for speculation, the way the long-tail trace is
    the favourable workload for lazy allocation: acceptance on
    repetition-free traffic would be near zero (and tokens still
    identical, just without the speedup)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        motif = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                              (4,), 0, cfg.vocab))
        prompt = np.tile(motif, int(rng.integers(2, 5)))[:16]
        reqs.append((prompt, int(rng.integers(max(2, max_new // 2),
                                              max_new + 1))))
    return reqs


def run_spec(model, params, reqs, slots, *, spec_tokens=None, drafter=None,
             greedy=True, seed=0):
    """Warmed drain with step counting and the zero-recompile assert."""
    eng = Engine(model, params, max_slots=slots, spec_tokens=spec_tokens,
                 drafter=drafter)
    eng.warmup()
    compiles = dict(model.trace_counts)
    rids = [eng.add_request(p, n) for p, n in reqs]
    t0 = time.perf_counter()
    fin, steps = {}, 0
    while eng.scheduler.has_work:
        fin.update((r.rid, r) for r in eng.step(greedy=greedy, seed=seed))
        steps += 1
    dt = time.perf_counter() - t0
    assert dict(model.trace_counts) == compiles, \
        "speculative step() compiled a new XLA program after warmup()"
    assert sorted(fin) == sorted(rids), "drain lost requests"
    assert eng.pool.num_used == 0, "leaked pages"
    return eng, [fin[rid].out_tokens for rid in rids], dt, steps


def bench_spec(model, params, reqs, slots, spec_tokens, smoke):
    """Speculative vs plain decode on the n-gram-friendly trace.  The
    contract half: spec-on outputs are asserted token-identical to spec-off
    for greedy AND sampled decode (a mismatch fails the run — this is what
    ``tier1.sh --bench-smoke`` buys).  The perf half: decode tokens per
    decode-row-step — how many tokens a decoding row advances per verify
    launch, the step-shape-independent speedup measure — targets >= 1.3x
    at the n-gram acceptance this trace earns; wall-clock is recorded
    honestly (a CPU toy pays the padded verify width in real FLOPs, so its
    wall win trails what per-step accounting promises on real hardware)."""
    total_new = sum(n for _, n in reqs)
    print(f"[bench_serving] speculative: {len(reqs)} requests, "
          f"{total_new} tokens, {slots} slots, k={spec_tokens} "
          f"(n-gram drafter)")
    base_eng, base_out, base_dt, base_steps = run_spec(
        model, params, reqs, slots)
    _, base_out_s, _, _ = run_spec(model, params, reqs, slots,
                                   greedy=False, seed=13)
    record = {"spec_tokens": spec_tokens,
              "baseline": {"tok_per_s": total_new / base_dt,
                           "steps": base_steps}}
    rows = [("ngram", None)]
    if not smoke:
        dcfg = reduced_config(get_config("smollm2-135m"), layers=1)
        dm = build_model(dcfg, RunConfig(param_dtype="float32",
                                         compute_dtype="float32",
                                         remat=False),
                         ShapeSpec("serve", model.shape.seq_len, slots,
                                   "decode"))
        dparams = dm.init(jax.random.PRNGKey(7))
        rows.append(("draft-model",
                     lambda: DraftModelDrafter(dm, dparams)))
    for label, mk in rows:
        drafter = mk() if mk else None
        eng, outs, dt, steps = run_spec(model, params, reqs, slots,
                                        spec_tokens=spec_tokens,
                                        drafter=drafter)
        assert outs == base_out, \
            f"speculative ({label}) greedy outputs diverged from baseline"
        drafter_s = mk() if mk else None
        _, outs_s, _, _ = run_spec(model, params, reqs, slots,
                                   spec_tokens=spec_tokens,
                                   drafter=drafter_s, greedy=False, seed=13)
        assert outs_s == base_out_s, \
            f"speculative ({label}) sampled outputs diverged from baseline"
        st = eng.stats()["speculative"]
        tps = st["decode_tokens_per_row_step"]
        record[label] = {
            "tok_per_s": total_new / dt, "steps": steps,
            "acceptance_rate": st["acceptance_rate"],
            "accepted_per_step": st["accepted_per_step"],
            "decode_tokens_per_row_step": tps,
            "step_ratio": base_steps / steps,
            "wall_ratio": base_dt / dt,
            "draft_overhead": st["draft_overhead"],
            "rollback_pages": st["rollback_pages"],
        }
        tag = ("OK (>= 1.3x)" if label == "ngram" and tps >= 1.3
               else "" if label != "ngram" else "BELOW 1.3x TARGET")
        print(f"  {label:<12} accept={st['acceptance_rate']:.2f}  "
              f"decode tok/row-step={tps:.2f}  steps {base_steps}->{steps} "
              f"({base_steps / steps:.2f}x)  wall {base_dt / dt:.2f}x  "
              f"draft overhead {st['draft_overhead']:.2f}  {tag}")
    print(f"  outputs token-identical to non-speculative decode "
          f"(greedy + sampled) for all {len(rows)} drafters")
    return record


def bench_attrib(model, params, reqs, slots, chunk_tokens):
    """Attribution section (repro.obs.attrib): two telemetry-on drains —
    flat token-level and dense chunked — with the warmup-built roofline
    cost model attached, recording MFU/MBU, padding-waste ratio and the
    per-family predicted-vs-measured ratio.  These land in
    ``BENCH_serving.json`` and are regression-gated by
    ``scripts/bench_check.py`` (MFU/MBU dropping or the padding-waste
    ratio rising by >15% vs the history median fails the gate)."""
    out = {}
    for mode, flat in (("flat", True), ("chunked", False)):
        eng = Engine(model, params, max_slots=slots,
                     chunk_tokens=chunk_tokens, flat=flat, telemetry=True)
        eng.warmup()
        for p, n in reqs:
            eng.add_request(p, n)
        eng.drain()
        at = eng.telemetry()["attribution"]
        tot = at["totals"]
        out[mode] = {
            "mfu": at["mfu"],
            "mbu": at["mbu"],
            "padding_waste_ratio": at["padding_waste_ratio"],
            "roofline_fraction": at["roofline_fraction"],
            "achieved_tokens_per_s": at["achieved_tokens_per_s"],
            "device_fraction": tot["device_s"] / max(tot["wall_s"], 1e-12),
            "families": {
                label: {"steps": f["steps"], "fill": f["fill"],
                        "predicted_vs_measured": f["predicted_vs_measured"]}
                for label, f in sorted(at["families"].items())},
        }
        print(f"  attribution / {mode:<8} mfu {at['mfu']:.2e}  "
              f"mbu {at['mbu']:.2e}  padding waste "
              f"{at['padding_waste_ratio']:.3f} of device  "
              f"roofline fraction {at['roofline_fraction']:.3f}  "
              f"({len(at['families'])} families)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", default="scalable,fixed",
                    help="comma-separated layout policies to sweep")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="fused-step chunk size for the chunked sections "
                    "(rounded up to the layout m_r; smaller chunks bound "
                    "ITL tighter, larger ones amortize per-step dispatch "
                    "— 16 balances both on a CPU host via the geometric "
                    "shape ladder)")
    ap.add_argument("--spec-tokens", type=int, default=3,
                    help="draft tokens per verify step for the speculative "
                    "section (k drafts ride one fused row per step)")
    ap.add_argument("--skip-longtail", action="store_true")
    ap.add_argument("--skip-throughput", action="store_true")
    ap.add_argument("--skip-itl", action="store_true",
                    help="skip the chunked-vs-monolithic latency section")
    ap.add_argument("--skip-spec", action="store_true",
                    help="skip the speculative-decoding section")
    ap.add_argument("--skip-overload", action="store_true",
                    help="skip the overload/admission-control section")
    ap.add_argument("--skip-prefix", action="store_true",
                    help="skip the prefix-cache section")
    ap.add_argument("--sys-tokens", type=int, default=48,
                    help="shared system-prompt length for the prefix-cache "
                    "trace (3 pages at the default page size: long enough "
                    "that sharing dominates, short enough for CPU smoke)")
    ap.add_argument("--json-out", default=None,
                    help="write machine-readable results here (default: "
                    "BENCH_serving.json at the repo root; '-' disables)")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (2 slots, tiny pool) for CI smoke: "
                    "surfaces allocator and chunked-vs-monolithic output "
                    "regressions, not perf numbers")
    args = ap.parse_args(argv)
    if args.smoke:
        # 8 requests → two long-tail requests overlap on the 2 slots, so
        # the 50% pool provably forces a preemption even at toy sizes
        args.requests, args.slots = 8, 2
        args.max_prompt, args.max_new, args.max_len = 10, 6, 48
        args.policies = "scalable"
        args.chunk_tokens = 8

    cfg = reduced_config(get_config(args.arch))
    shape = ShapeSpec("serve", args.max_len, args.slots, "decode")
    reqs = make_workload(cfg, args.requests, args.max_prompt, args.max_new,
                         args.seed)
    total_prompt = sum(p.shape[0] for p, _ in reqs)
    total_new = sum(n for _, n in reqs)
    policies = [p for p in args.policies.split(",") if p]
    print(f"[bench_serving] {cfg.name}: {len(reqs)} requests, "
          f"prompts 2..{args.max_prompt} ({total_prompt} tok), "
          f"budgets 2..{args.max_new} ({total_new} tok), {args.slots} slots")

    results = {}
    models = {}
    for policy in policies:
        if args.skip_throughput and policy != policies[0]:
            continue        # only policies[0] feeds the long-tail section
        run = RunConfig(layout_policy=policy, param_dtype="float32",
                        compute_dtype="float32", remat=False)
        model = build_model(cfg, run, shape)
        params = model.init(jax.random.PRNGKey(args.seed))
        models[policy] = (model, params)
        if args.skip_throughput:
            continue
        for mode in ("static", "continuous"):
            dt, useful = bench(model, params, reqs, args.slots, mode)
            assert useful == total_new, (useful, total_new)
            results[(policy, mode)] = total_new / dt
            print(f"  {policy:>8} / {mode:<10} {total_new / dt:8.1f} tok/s "
                  f"({dt:.2f}s)")

    if not args.skip_throughput:
        for policy in policies:
            ratio = results[(policy, "continuous")] / results[(policy, "static")]
            tag = "OK (>= 1.3x)" if ratio >= 1.3 else "BELOW 1.3x TARGET"
            print(f"  {policy:>8}: continuous/static = {ratio:.2f}x  [{tag}]")
        if "scalable" in policies and "fixed" in policies:
            ps = (results[("scalable", "continuous")]
                  / results[("fixed", "continuous")])
            print(f"  continuous: scalable/fixed = {ps:.2f}x")

    report = {"arch": cfg.name, "slots": args.slots,
              "requests": args.requests, "max_len": args.max_len,
              "chunk_tokens": args.chunk_tokens, "smoke": args.smoke}
    if not args.skip_throughput:
        report["throughput"] = {f"{p}/{m}": v
                                for (p, m), v in results.items()}

    if not args.skip_longtail:
        model, params = models[policies[0]]
        # 2x the request count: the admission gap needs a sustained stream
        # of short requests contending with the long tail, not a drain-down
        lt = make_longtail_workload(cfg, 2 * args.requests, args.max_prompt,
                                    args.max_new, args.max_len, args.seed)
        report["longtail"] = bench_longtail(model, params, lt, args.slots,
                                            args.chunk_tokens)
        results["longtail_concurrency_ratio"] = \
            report["longtail"]["lazy_vs_eager_concurrency"]

    if not args.skip_itl and all(t == "attn" for t in cfg.layer_types):
        model, params = models[policies[0]]
        mixed = make_mixed_trace(cfg,
                                 args.requests if args.smoke
                                 else 2 * args.requests,
                                 args.max_len, args.seed)
        report["chunked"] = bench_chunked(model, params, mixed, args.slots,
                                          args.chunk_tokens,
                                          repeats=0 if args.smoke else 4)
        if "itl_p95_improvement" in report["chunked"]:
            results["itl_p95_improvement"] = \
                report["chunked"]["itl_p95_improvement"]
        report["flat"] = bench_flat(model, params, mixed, args.slots,
                                    args.chunk_tokens, args.smoke)
        results["flat_offline_throughput_ratio"] = \
            report["flat"]["offline_throughput_ratio"]

    if all(t == "attn" for t in cfg.layer_types):
        model, params = models[policies[0]]
        report["attribution"] = bench_attrib(model, params, reqs,
                                             args.slots, args.chunk_tokens)
        results["attrib_flat_mfu"] = report["attribution"]["flat"]["mfu"]

    if not args.skip_spec and all(t == "attn" for t in cfg.layer_types):
        model, params = models[policies[0]]
        spec_reqs = make_spec_trace(cfg, 6 if args.smoke else 16,
                                    12 if args.smoke else 32, args.seed)
        report["speculative"] = bench_spec(model, params, spec_reqs,
                                           args.slots, args.spec_tokens,
                                           args.smoke)
        results["spec_decode_tokens_per_row_step"] = \
            report["speculative"]["ngram"]["decode_tokens_per_row_step"]

    if not args.skip_overload and all(t == "attn" for t in cfg.layer_types):
        model, params = models[policies[0]]
        ov = make_workload(cfg, args.requests if args.smoke
                           else 2 * args.requests, args.max_prompt,
                           args.max_new, args.seed + 1)
        report["overload"] = bench_overload(model, params, ov, args.slots,
                                            args.chunk_tokens,
                                            repeats=1 if args.smoke else 3)
        results["overload_ttft_ratio"] = \
            report["overload"]["ttft_p95_bounded_vs_unbounded"]

    if not args.skip_prefix and all(t == "attn" for t in cfg.layer_types):
        model, params = models[policies[0]]
        prefix_reqs = make_prefix_trace(cfg, 6 if args.smoke else 12,
                                        32 if args.smoke else args.sys_tokens,
                                        6 if args.smoke else args.max_new,
                                        args.seed)
        report["prefix_cache"] = bench_prefix(model, params, prefix_reqs,
                                              args.slots, args.chunk_tokens,
                                              args.spec_tokens, args.smoke)
        results["prefix_prefill_ratio"] = \
            report["prefix_cache"]["mono/greedy"]["prefill_ratio"]

    if args.json_out != "-" and not (args.smoke and args.json_out is None):
        # smoke runs don't clobber the tracked perf trajectory unless asked.
        # The file keeps the trajectory, not just the last run: "latest" is
        # the rolling merged view (partial --skip-* runs update only their
        # sections), "history" appends one timestamped entry per invocation
        # so perf across PRs stays recoverable
        path = args.json_out or os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_serving.json")
        data = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
            except (OSError, ValueError):
                data = {}
        if not isinstance(data, dict):
            data = {}
        if "history" not in data:
            # legacy layout: a flat section dict — keep it as the seed of
            # the trajectory rather than losing it
            data = {"latest": data,
                    "history": ([{"timestamp": None, "report": data}]
                                if data else [])}
        data.setdefault("latest", {}).update(report)
        data["history"].append({
            "timestamp": datetime.now(timezone.utc).isoformat(
                timespec="seconds"),
            "report": report,
        })
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        print(f"[bench_serving] wrote {path} "
              f"({len(data['history'])} history entries)")
    return results


if __name__ == "__main__":
    main()
