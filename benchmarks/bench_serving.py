"""Serving throughput: continuous batching vs static batching at mixed
prompt lengths / token budgets; scalable vs fixed layout policy.

Workload: N requests with mixed prompt lengths and per-request budgets,
all available at t=0 (offline throughput).

  - static: requests are grouped into arrival-order batches of ``--slots``;
    each batch pads every prompt to the batch max and decodes lock-step to
    the batch-max budget (tokens past a request's own budget are waste —
    that, plus prompt padding, is exactly the cost continuous batching
    removes).  Padded prompts make static outputs approximate; this is a
    throughput comparison, correctness equivalence is proven in
    tests/test_scheduler.py.
  - continuous: every request is admitted into a paged-KV slot as one frees,
    prefilled at its own (m_r-bucketed) length, and retired the step its own
    budget completes.

Useful tokens are identical in both modes (each request's own budget), so
throughput ratios are directly comparable.  Each mode runs once untimed
(compile warmup) and once timed.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine


def make_workload(cfg, n, max_prompt, max_new, seed=0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        reqs.append((prompt, int(rng.integers(2, max_new + 1))))
    return reqs


def run_static(engine: Engine, reqs, slots: int) -> int:
    """Arrival-order batches, prompts padded to the batch max, lock-step
    decode to the batch-max budget.  Returns useful token count."""
    useful = 0
    for i in range(0, len(reqs), slots):
        chunk = reqs[i:i + slots]
        plen = max(p.shape[0] for p, _ in chunk)
        budget = max(n for _, n in chunk)
        toks = np.zeros((len(chunk), plen), np.int32)
        for j, (p, _) in enumerate(chunk):
            toks[j, :p.shape[0]] = p
        engine.generate_static({"tokens": toks}, budget)
        useful += sum(n for _, n in chunk)
    return useful


def run_continuous(engine: Engine, reqs) -> int:
    for p, n in reqs:
        engine.add_request(p, n)
    finished = engine.drain()
    return sum(len(r.out_tokens) for r in finished)


def bench(model, params, reqs, slots, mode) -> tuple[float, int]:
    runner = {"static": lambda e: run_static(e, reqs, slots),
              "continuous": lambda e: run_continuous(e, reqs)}[mode]
    runner(Engine(model, params, max_slots=slots))      # compile warmup
    eng = Engine(model, params, max_slots=slots)
    t0 = time.perf_counter()
    useful = runner(eng)
    return time.perf_counter() - t0, useful


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    shape = ShapeSpec("serve", args.max_len, args.slots, "decode")
    reqs = make_workload(cfg, args.requests, args.max_prompt, args.max_new,
                         args.seed)
    total_prompt = sum(p.shape[0] for p, _ in reqs)
    total_new = sum(n for _, n in reqs)
    print(f"[bench_serving] {cfg.name}: {len(reqs)} requests, "
          f"prompts 2..{args.max_prompt} ({total_prompt} tok), "
          f"budgets 2..{args.max_new} ({total_new} tok), {args.slots} slots")

    results = {}
    for policy in ("scalable", "fixed"):
        run = RunConfig(layout_policy=policy, param_dtype="float32",
                        compute_dtype="float32", remat=False)
        model = build_model(cfg, run, shape)
        params = model.init(jax.random.PRNGKey(args.seed))
        for mode in ("static", "continuous"):
            dt, useful = bench(model, params, reqs, args.slots, mode)
            assert useful == total_new, (useful, total_new)
            results[(policy, mode)] = total_new / dt
            print(f"  {policy:>8} / {mode:<10} {total_new / dt:8.1f} tok/s "
                  f"({dt:.2f}s)")

    for policy in ("scalable", "fixed"):
        ratio = results[(policy, "continuous")] / results[(policy, "static")]
        tag = "OK (>= 1.3x)" if ratio >= 1.3 else "BELOW 1.3x TARGET"
        print(f"  {policy:>8}: continuous/static = {ratio:.2f}x  [{tag}]")
    ps = results[("scalable", "continuous")] / results[("fixed", "continuous")]
    print(f"  continuous: scalable/fixed = {ps:.2f}x")
    return results


if __name__ == "__main__":
    main()
