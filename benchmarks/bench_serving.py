"""Serving throughput: continuous batching vs static batching at mixed
prompt lengths / token budgets; scalable vs fixed layout policy; lazy page
allocation vs eager full-lifetime reservation on a long-tail trace.

Workload: N requests with mixed prompt lengths and per-request budgets,
all available at t=0 (offline throughput).

  - static: requests are grouped into arrival-order batches of ``--slots``;
    each batch pads every prompt to the batch max and decodes lock-step to
    the batch-max budget (tokens past a request's own budget are waste —
    that, plus prompt padding, is exactly the cost continuous batching
    removes).  Padded prompts make static outputs approximate; this is a
    throughput comparison, correctness equivalence is proven in
    tests/test_scheduler.py.
  - continuous: every request is admitted into a paged-KV slot as one frees,
    prefilled at its own (m_r-bucketed) length, and retired the step its own
    budget completes.

Useful tokens are identical in both modes (each request's own budget), so
throughput ratios are directly comparable.  Each mode runs once untimed
(compile warmup) and once timed.

The **long-tail section** replays a trace where most requests have short
output budgets and a tail runs to the context limit, against a KV pool
sized at 50% of what eager reservation would need to keep every slot busy.
Eager admission serializes behind the tail's reservations; lazy allocation
admits by actual prompt size, grows pages per decode step, and preempts
(by recomputation) when the pool runs dry — same pool, higher mean slot
occupancy and 1.4-2x the throughput at the default sizes (CPU-host timing
is noisy; the occupancy gap is the stable signal), with bit-identical
greedy outputs (asserted against the eager baseline).

Run:  PYTHONPATH=src python benchmarks/bench_serving.py
Toy:  PYTHONPATH=src python benchmarks/bench_serving.py --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core.layout import ceil_div, round_up
from repro.models.model import build_model
from repro.serving.engine import Engine


def make_workload(cfg, n, max_prompt, max_new, seed=0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        reqs.append((prompt, int(rng.integers(2, max_new + 1))))
    return reqs


def run_static(engine: Engine, reqs, slots: int) -> int:
    """Arrival-order batches, prompts padded to the batch max, lock-step
    decode to the batch-max budget.  Returns useful token count."""
    useful = 0
    for i in range(0, len(reqs), slots):
        chunk = reqs[i:i + slots]
        plen = max(p.shape[0] for p, _ in chunk)
        budget = max(n for _, n in chunk)
        toks = np.zeros((len(chunk), plen), np.int32)
        for j, (p, _) in enumerate(chunk):
            toks[j, :p.shape[0]] = p
        engine.generate_static({"tokens": toks}, budget)
        useful += sum(n for _, n in chunk)
    return useful


def run_continuous(engine: Engine, reqs) -> int:
    for p, n in reqs:
        engine.add_request(p, n)
    finished = engine.drain()
    return sum(len(r.out_tokens) for r in finished)


def bench(model, params, reqs, slots, mode) -> tuple[float, int]:
    runner = {"static": lambda e: run_static(e, reqs, slots),
              "continuous": lambda e: run_continuous(e, reqs)}[mode]
    runner(Engine(model, params, max_slots=slots))      # compile warmup
    eng = Engine(model, params, max_slots=slots)
    t0 = time.perf_counter()
    useful = runner(eng)
    return time.perf_counter() - t0, useful


# ---------------------------------------------------------------------------
# long-tail trace: lazy allocation vs eager reservation at the same pool size
# ---------------------------------------------------------------------------

def make_longtail_workload(cfg, n, max_prompt, max_new, max_len, seed=0):
    """Short prompts; most requests want a short continuation but every 4th
    runs to the context limit — the output-length distribution where eager
    full-lifetime reservation idles most of a pool sized for the average
    (the reservation is all *future* tokens, which lazy allocation defers)."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(2, max(3, max_prompt // 4) + 1))
        prompt = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                               (plen,), 0, cfg.vocab))
        budget = (max_len - plen) if i % 4 == 3 \
            else int(rng.integers(2, max_new + 1))
        reqs.append((prompt, budget))
    return reqs


def run_longtail(model, params, reqs, slots, *, eager, num_pages,
                 page_tokens=16):
    eng = Engine(model, params, max_slots=slots, eager=eager,
                 num_pages=num_pages, page_tokens=page_tokens)
    eng.warmup()       # compile decode + every prefill bucket before timing
    rids = [eng.add_request(p, n) for p, n in reqs]
    t0 = time.perf_counter()
    fin, steps = {}, 0
    while eng.scheduler.has_work:
        fin.update((r.rid, r) for r in eng.step())
        steps += 1
    dt = time.perf_counter() - t0
    assert sorted(fin) == sorted(rids), "drain lost requests"
    outs = [fin[rid].out_tokens for rid in rids]
    return eng, outs, dt, steps


def bench_longtail(model, params, reqs, slots):
    # page size the engine will actually use (16 rounded up to the layout m_r)
    pt = round_up(16, model.ctx.layout(model.compute_dtype).m_r)
    per_req = [ceil_div(p.shape[0] + n - 1, pt) for p, n in reqs]
    eager_pages = slots * max(per_req)     # eager never page-blocked
    half = 1 + eager_pages // 2            # +1: trash page
    total_new = sum(n for _, n in reqs)
    print(f"[bench_serving] long-tail: {len(reqs)} requests, "
          f"{total_new} tokens, {slots} slots, page={pt} tok; "
          f"eager requirement {eager_pages} pages, pool capped at "
          f"{half - 1} (50%)")

    base_eng, base_out, base_dt, base_steps = run_longtail(
        model, params, reqs, slots, eager=True, num_pages=1 + eager_pages,
        page_tokens=pt)
    rows = [("eager/full", base_eng, base_out, base_dt, base_steps,
             1 + eager_pages)]
    for label, eager in (("eager/half", True), ("lazy/half", False)):
        eng, outs, dt, steps = run_longtail(model, params, reqs, slots,
                                            eager=eager, num_pages=half,
                                            page_tokens=pt)
        rows.append((label, eng, outs, dt, steps, half))
    for label, eng, outs, dt, steps, pages in rows:
        s = eng.scheduler
        # mean slot occupancy: tokens produced per engine step — eager
        # reservation idles slots behind long-tail page reservations
        print(f"  {label:<10} {total_new / dt:8.1f} tok/s ({dt:.2f}s)  "
              f"concurrency={total_new / steps:.2f} avg / "
              f"{s.peak_running} peak  "
              f"preemptions={s.num_preemptions}  "
              f"peak_pages={eng.pool.peak_used}/{pages - 1}")
        assert outs == base_out, \
            f"{label}: outputs diverged from the eager baseline"
        assert eng.pool.num_used == 0, f"{label}: leaked pages"
    lazy_eng, lazy_steps = rows[2][1], rows[2][4]
    eager_half_steps = rows[1][4]
    assert lazy_eng.scheduler.num_preemptions >= 1, \
        "long-tail trace at 50% pool should force at least one preemption"
    ratio = eager_half_steps / lazy_steps
    print(f"  lazy/eager mean concurrency at the same pool = {ratio:.2f}x; "
          f"outputs token-identical across all three runs")
    return ratio


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=40)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", default="scalable,fixed",
                    help="comma-separated layout policies to sweep")
    ap.add_argument("--skip-longtail", action="store_true")
    ap.add_argument("--skip-throughput", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (2 slots, tiny pool) for CI smoke: "
                    "surfaces allocator regressions, not perf numbers")
    args = ap.parse_args(argv)
    if args.smoke:
        # 8 requests → two long-tail requests overlap on the 2 slots, so
        # the 50% pool provably forces a preemption even at toy sizes
        args.requests, args.slots = 8, 2
        args.max_prompt, args.max_new, args.max_len = 10, 6, 48
        args.policies = "scalable"

    cfg = reduced_config(get_config(args.arch))
    shape = ShapeSpec("serve", args.max_len, args.slots, "decode")
    reqs = make_workload(cfg, args.requests, args.max_prompt, args.max_new,
                         args.seed)
    total_prompt = sum(p.shape[0] for p, _ in reqs)
    total_new = sum(n for _, n in reqs)
    policies = [p for p in args.policies.split(",") if p]
    print(f"[bench_serving] {cfg.name}: {len(reqs)} requests, "
          f"prompts 2..{args.max_prompt} ({total_prompt} tok), "
          f"budgets 2..{args.max_new} ({total_new} tok), {args.slots} slots")

    results = {}
    models = {}
    for policy in policies:
        if args.skip_throughput and policy != policies[0]:
            continue        # only policies[0] feeds the long-tail section
        run = RunConfig(layout_policy=policy, param_dtype="float32",
                        compute_dtype="float32", remat=False)
        model = build_model(cfg, run, shape)
        params = model.init(jax.random.PRNGKey(args.seed))
        models[policy] = (model, params)
        if args.skip_throughput:
            continue
        for mode in ("static", "continuous"):
            dt, useful = bench(model, params, reqs, args.slots, mode)
            assert useful == total_new, (useful, total_new)
            results[(policy, mode)] = total_new / dt
            print(f"  {policy:>8} / {mode:<10} {total_new / dt:8.1f} tok/s "
                  f"({dt:.2f}s)")

    if not args.skip_throughput:
        for policy in policies:
            ratio = results[(policy, "continuous")] / results[(policy, "static")]
            tag = "OK (>= 1.3x)" if ratio >= 1.3 else "BELOW 1.3x TARGET"
            print(f"  {policy:>8}: continuous/static = {ratio:.2f}x  [{tag}]")
        if "scalable" in policies and "fixed" in policies:
            ps = (results[("scalable", "continuous")]
                  / results[("fixed", "continuous")])
            print(f"  continuous: scalable/fixed = {ps:.2f}x")

    if not args.skip_longtail:
        model, params = models[policies[0]]
        # 2x the request count: the admission gap needs a sustained stream
        # of short requests contending with the long tail, not a drain-down
        lt = make_longtail_workload(cfg, 2 * args.requests, args.max_prompt,
                                    args.max_new, args.max_len, args.seed)
        results["longtail_concurrency_ratio"] = bench_longtail(
            model, params, lt, args.slots)
    return results


if __name__ == "__main__":
    main()
