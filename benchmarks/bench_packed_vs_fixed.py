"""Paper Table 3 / Fig 2a analogue: scalable (SVE) vs fixed (NEON) vs
unpacked codegen on matmul shapes drawn from the evaluated models.

The paper compares IREE(SVE) vs IREE(NEON) latency on the same chip: same
compiler stack, different code-generation strategy.  Here: same JAX/XLA
stack, the three layout policies of ``repro.core.layout``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import make_layout, matmul, packed_matmul, presets
from repro.core.layout import LayoutPolicy

# (M, K, N) matmul shapes from the evaluated models (batch 1 x seq 128
# tokens against the model's projection matrices — the consumer-inference
# regime of the paper).
MODEL_MATMULS = {
    "smollm2_mlp": (128, 576, 1536),
    "smollm2_logits": (128, 576, 49152),
    "qwen2_qkv": (128, 3584, 4608),
    "qwen2_mlp": (128, 3584, 18944),
    "whisper_mlp": (128, 768, 3072),
    "square_512": (512, 512, 512),
    "square_1024": (1024, 1024, 1024),
    "skinny_k": (2048, 512, 2048),
}


def run(iters: int = 5) -> None:
    hw = presets["tpu_v5e"]
    for name, (m, k, n) in MODEL_MATMULS.items():
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        times = {}
        for pol in ("scalable", "fixed", "unpacked"):
            lay = make_layout(pol, hw, jnp.float32)
            fn = jax.jit(lambda a_, b_, lay_=lay: matmul(a_, b_, lay_))
            times[pol] = time_fn(fn, a, b, iters=iters)
        speedup_vs_fixed = times["fixed"] / times["scalable"]
        speedup_vs_unpacked = times["unpacked"] / times["scalable"]
        emit(f"t3_scalable_{name}", times["scalable"],
             f"fixed/scalable={speedup_vs_fixed:.2f}x;"
             f"unpacked/scalable={speedup_vs_unpacked:.2f}x")
        emit(f"t3_fixed_{name}", times["fixed"], "")
        emit(f"t3_unpacked_{name}", times["unpacked"], "")


if __name__ == "__main__":
    run()
