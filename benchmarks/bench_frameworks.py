"""Paper Tables 4-5 / Fig 2b-c analogue: our packed compilation pipeline vs
execution-strategy baselines, per model forward pass.

The paper compares IREE(SVE) against ExecuTorch / TorchInductor / eager —
i.e. whole-graph packed compilation vs library dispatch vs plain graph
compilation vs op-by-op execution.  The analogues here (same host, same
model weights, reduced configs):

  - packed      : jit, scalable packed layouts + propagation  (IREE-SVE)
  - compiled    : jit, unpacked XLA default                   (Inductor)
  - eager       : un-jitted op-by-op dispatch, unpacked       (PyTorch eager)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model

# model roster mirrors the paper's Tab. 2 (consumer-inference regime:
# batch 1, modest sequence), reduced configs for CPU execution.
ROSTER = ["smollm2-135m", "qwen2-7b", "qwen3-8b", "whisper-small",
          "rwkv6-1.6b", "internvl2-26b"]


def _batch(m, cfg, b, s):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    out = {"tokens": jax.random.randint(ks[0], (b, m.text_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(ks[1], (b, m.enc_len, cfg.d_model))
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(ks[1], (b, cfg.vision_tokens,
                                                   cfg.d_model))
    return out


def run(iters: int = 3, seq: int = 128) -> None:
    base = RunConfig(param_dtype="float32", compute_dtype="float32",
                     remat=False)
    shape = ShapeSpec("bench", seq, 1, "prefill")
    for arch in ROSTER:
        cfg = reduced_config(get_config(arch))
        runs = {
            "packed": dataclasses.replace(base, layout_policy="scalable"),
            "compiled": dataclasses.replace(base, layout_policy="unpacked"),
        }
        params = None
        times = {}
        for name, run_cfg in runs.items():
            m = build_model(cfg, run_cfg, shape)
            if params is None:
                params = m.init(jax.random.PRNGKey(0))
            batch = _batch(m, cfg, 1, seq)
            fwd = jax.jit(lambda p, b_, m_=m: m_.forward(p, b_)[0])
            times[name] = time_fn(fwd, params, batch, iters=iters)
        # eager: same ops, dispatched without jit (op-by-op)
        m = build_model(cfg, runs["compiled"], shape)
        batch = _batch(m, cfg, 1, seq)
        with jax.disable_jit():
            times["eager"] = time_fn(lambda p, b_: m.forward(p, b_)[0],
                                     params, batch, warmup=1, iters=1)
        emit(f"t45_packed_{arch}", times["packed"],
             f"compiled/packed={times['compiled']/times['packed']:.2f}x;"
             f"eager/packed={times['eager']/times['packed']:.2f}x")
        emit(f"t45_compiled_{arch}", times["compiled"], "")
        emit(f"t45_eager_{arch}", times["eager"], "")


if __name__ == "__main__":
    run()
