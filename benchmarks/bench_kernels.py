"""Kernel-level benchmark: Pallas mmt4d (paper Listing 2 analogue) block-size
sweep + pack/unpack overhead vs matmul (paper §4.1 amortization argument).

Pallas timings are interpret-mode on CPU (semantics, not TPU wall-time);
the structural numbers — VMEM working set per block config, arithmetic
intensity of the packed tiles — are the TPU-relevant output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import make_layout, packing, presets
from repro.kernels.mmt4d.ops import pick_blocks
from repro.kernels.mmt4d.ref import mmt4d_ref


def run(iters: int = 3) -> None:
    hw = presets["tpu_v5e"]
    lay = make_layout("scalable", hw, jnp.float32)

    m, k, n = 512, 512, 512
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))

    # pack overhead vs compute (paper: packing amortized over matmul)
    t_pack = time_fn(jax.jit(lambda x: packing.pack_lhs(x, lay)), a,
                     iters=iters)
    ap = packing.pack_lhs(a, lay)
    bp = packing.pack_rhs(b, lay)
    t_mm = time_fn(jax.jit(mmt4d_ref), ap, bp, iters=iters)
    emit("kern_pack_512", t_pack, f"pack/matmul={t_pack / t_mm:.3f}")
    emit("kern_mmt4d_512", t_mm, "")

    # BlockSpec working-set sweep: VMEM bytes per (TM, TN) config
    m_o, _, m_r, k_r = ap.shape
    n_o, _, n_r, _ = bp.shape
    for tm, tn in [(4, 4), (8, 8), (16, 4), (16, 8)]:
        a_b = tm * m_r * k_r * 4
        b_b = tn * n_r * k_r * 4
        acc = tm * m_r * tn * n_r * 4
        tot = a_b + b_b + 2 * acc
        flops_per_byte = (2 * tm * m_r * tn * n_r * k_r) / (a_b + b_b)
        emit(f"kern_blockspec_{tm}x{tn}", float(tot),
             f"vmem_bytes={tot};ai={flops_per_byte:.1f}flops/B;"
             f"fits={'yes' if tot < hw.vmem_bytes // 4 else 'no'}")
    tm, tn = pick_blocks(m_o, n_o, m_r, n_r, k_r, 4, hw)
    emit("kern_blockspec_auto", 0.0, f"picked TM={tm},TN={tn}")


if __name__ == "__main__":
    run()
