"""Benchmark utilities: timing and CSV emission.

All wall-clock numbers are CPU-host measurements (TPU is the modelled
target); they compare *code-generation strategies* against each other on
identical hardware, which is exactly the paper's Table 3/4/5 methodology
(same device, different codegen).
"""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "emit"]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw) -> float:
    """Median wall-time (us) of ``fn(*args)`` with jit warmup."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
