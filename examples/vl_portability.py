"""The paper's headline demo: vector-length-agnostic execution.

One model, one set of weights, one code path — executed under hardware
descriptors whose vector width differs 4x.  The layouts (and kernels built
on them) adapt at instantiation time; outputs agree bitwise-ish (fp32
reduction order only).  This is Fig. 1 + Fig. 3's premise as a runnable
script, plus the NEON-analogue counterexample: the FIXED layout keeps its
compile-time tiles and simply cannot exploit the wider unit.

Run:  PYTHONPATH=src python examples/vl_portability.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.core import make_layout, presets
from repro.models.model import build_model


def main():
    cfg = reduced_config(get_config("smollm2-135m"), layers=2)
    shape = ShapeSpec("demo", 32, 2, "train")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat=False)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab)}

    params, ref = None, None
    print(f"{'hardware':10s} {'scalable tiles':>18s} {'fixed tiles':>14s} "
          f"{'max |Δlogits|':>14s}")
    for hwname in ("tpu_vl128", "tpu_vl256", "tpu_vl512"):
        hw = presets[hwname]
        model = build_model(cfg, run, shape, hw=hw)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        logits, _ = model.forward(params, batch)
        if ref is None:
            ref = np.asarray(logits)
        err = float(np.max(np.abs(np.asarray(logits) - ref)))
        s = make_layout("scalable", hw, jnp.float32)
        f = make_layout("fixed", hw, jnp.float32)
        print(f"{hwname:10s} {f'{s.m_r}x{s.n_r}x{s.k_r}':>18s} "
              f"{f'{f.m_r}x{f.n_r}x{f.k_r}':>14s} {err:14.2e}")

    print("\nsame weights, same code; scalable tiles follow the hardware, "
          "fixed tiles do not (the paper's SVE-vs-NEON dichotomy).")


if __name__ == "__main__":
    main()
