"""Batched serving example: prefill + KV-cache decode with pre-packed
weights (the paper's amortized standalone packing, §4.1).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch smollm2-135m
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeSpec, get_config, reduced_config
from repro.models.model import build_model
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm2-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    shape = ShapeSpec("serve", args.max_len, args.batch, "decode")
    run = RunConfig(param_dtype="float32", compute_dtype="float32",
                    remat=False)
    model = build_model(cfg, run, shape)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                          0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.max_len // cfg.audio_downsample, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vision_tokens, cfg.d_model))

    engine = Engine(model, params)           # weights pre-packed here
    t0 = time.perf_counter()
    out = engine.generate(batch, args.new_tokens, greedy=not args.sample)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {out.shape} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU host)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
